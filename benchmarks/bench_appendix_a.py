"""Appendix A validation: the sample-size bound n = z^2 (1-a) / (delta^2 a).

Monte-Carlo: draw n (from Eq. 5) scores, pick the (1-a)-quantile threshold,
measure the realized alert rate on fresh traffic; the relative deviation
should be within delta with ~95% coverage (z = 1.96).  Also reports how the
required n scales with the alert rate — the paper's operational guidance for
when a client-specific T^Q becomes trustworthy.
"""
from __future__ import annotations

import numpy as np

from repro.core.quantiles import required_sample_size


def _coverage(a: float, delta: float, n: int, trials: int, rng) -> float:
    """Fit scores are Uniform(0,1) (probability integral transform — exactly
    the Appendix-A setting), so the realized alert rate at threshold thr is
    exactly 1 - thr: no evaluation-side Monte-Carlo noise."""
    hits = 0
    for _ in range(trials):
        fit = rng.random(n)
        thr = np.quantile(fit, 1.0 - a)
        realized = 1.0 - thr
        if abs(realized - a) <= delta * a:
            hits += 1
    return hits / trials


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    trials = 120 if quick else 400
    rows = []
    for a in (0.001, 0.005, 0.01, 0.05):
        for delta in (0.1, 0.2):
            n = required_sample_size(a, delta)
            if n > 3_000_000 and quick:
                continue
            cov = _coverage(a, delta, n, trials, rng)
            # halving n should break coverage noticeably below nominal
            cov_half = _coverage(a, delta, max(n // 4, 10), trials, rng)
            rows.append({
                "alert_rate": a, "delta": delta, "n_required": n,
                "coverage_at_n": cov, "coverage_at_n_over_4": cov_half,
            })
    return {"rows": rows, "nominal": 0.95}


def main() -> None:
    res = run()
    print(f"{'a':>7} {'delta':>6} {'n (Eq.5)':>10} {'coverage@n':>11} "
          f"{'coverage@n/4':>13}")
    for r in res["rows"]:
        print(f"{r['alert_rate']:7.3f} {r['delta']:6.2f} {r['n_required']:10d} "
              f"{r['coverage_at_n']:11.3f} {r['coverage_at_n_over_4']:13.3f}")
    print(f"\nnominal coverage {res['nominal']}: Eq. 5 sample sizes achieve it; "
          "n/4 visibly undershoots (bound is tight, not loose)")


if __name__ == "__main__":
    main()
