"""Async banked dispatch engine vs the synchronous ``ServerBatcher``.

The MUSE claim under test (Sec. 4: >1k events/s at low latency while the
control plane stays live): decoupling window arrival from dispatch beats
flushing windows synchronously.  The ``AsyncDispatchEngine`` wins twice on
the same mixed-tenant traffic:

  * **stage pipelining** — window *N*'s expert models execute while window
    *N−1* runs the banked transform kernel and window *N−2*'s estimator
    updates land (three single-worker stage executors);
  * **adaptive batching** — while the model stage is busy, arrivals keep
    accumulating and the next dispatch takes the whole backlog as ONE
    size-quantized window, amortizing per-window dispatch costs the
    synchronous batcher must pay per fixed-size window (it is blocked
    inside ``score_batch`` and cannot see later arrivals).

Both paths serve identical request streams on identically built servers
(same seeds), with every serving shape warmed first, and must produce
identical scores (parity asserted).

The tracking A/B (ROADMAP "fuse quantile tracking into the device
program"): the same adaptive engine is run with quantile tracking OFF and
with the fused device tracker ON (``ServerConfig.track_device`` —
score -> transform -> track as one device dispatch, host estimators
materialize only at calibration pulls).  The headline
``tracking_on_off_ratio`` is the acceptance metric: ON throughput must
approach OFF (>= 0.9x).

  PYTHONPATH=src python -m benchmarks.bench_async_engine [--quick]
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.serving import (
    AsyncDispatchEngine,
    MicroBatcher,
    MuseServer,
    ServerBatcher,
    ServerConfig,
)
from repro.serving.types import ScoringRequest

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_async_engine.json")

DIM = 64
HIDDEN = 512
N_EXPERTS = 3


def _mlp_model(seed: int, hidden: int = HIDDEN, dim: int = DIM):
    """A jitted 3-layer scorer: enough XLA work per window that the model
    stage genuinely overlaps the (GIL-holding) Python of the other stages."""
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.normal(0, 0.3, (dim, hidden)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.3, (hidden, hidden)), jnp.float32)
    w3 = jnp.asarray(rng.normal(0, 0.3, (hidden, 1)), jnp.float32)

    @jax.jit
    def f(x):
        h = jnp.tanh(x @ w1)
        h = jnp.tanh(h @ w2)
        return jax.nn.sigmoid((h @ w3)[..., 0])

    return lambda x: f(jnp.asarray(np.asarray(x, np.float32)))


def _build_server(n_tenants: int,
                  config: ServerConfig | None = None) -> MuseServer:
    """One predictor per tenant over a shared expert group: mixed-tenant
    windows hit ONE model call + ONE banked kernel dispatch each."""
    factories = {f"m{k}": (lambda k=k: _mlp_model(k))
                 for k in range(N_EXPERTS)}
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    qs = jnp.linspace(0.0, 1.0, 128)
    server = MuseServer(RoutingTable(rules, version="v1"), config)
    group = tuple(f"m{k}" for k in range(N_EXPERTS))
    for i in range(n_tenants):
        server.deploy(
            PredictorSpec(f"p{i}", group, (0.2, 0.3, 0.1),
                          (1.0,) * N_EXPERTS, QuantileMap(qs, qs ** 2)),
            factories)
    return server


def _requests(feats: np.ndarray, n_tenants: int) -> list[ScoringRequest]:
    return [ScoringRequest(intent=Intent(tenant=f"t{i % n_tenants}"),
                           features=feats[i])
            for i in range(len(feats))]


def _warm(server: MuseServer, n_tenants: int, sizes: list[int]) -> None:
    """Compile every serving shape (base window + each adaptive growth
    bucket) before the clock starts — rollout warm-up discipline."""
    rng = np.random.default_rng(9)
    for s in sizes:
        feats = rng.normal(0, 1, (s, DIM)).astype(np.float32)
        server.score_batch(_requests(feats, n_tenants))


def run(quick: bool = False) -> dict:
    n_tenants = 16 if quick else 32
    n_events = 12288 if quick else 16384
    base_batch = 128
    cap = 2048
    sizes = [base_batch]
    while sizes[-1] * 2 <= cap:
        sizes.append(sizes[-1] * 2)

    rng = np.random.default_rng(0)
    feats = rng.normal(0, 1, (n_events, DIM)).astype(np.float32)

    # --- synchronous baseline: ServerBatcher flushes fixed-size windows ----
    server_sync = _build_server(n_tenants)
    _warm(server_sync, n_tenants, sizes)
    sb = ServerBatcher(server_sync,
                       MicroBatcher(max_batch=base_batch, max_wait_ms=1e9))
    reqs = _requests(feats, n_tenants)
    out_sync: list = []
    t0 = time.perf_counter()
    for r in reqs:
        done = sb.submit(r)
        if done:
            out_sync.extend(done)
    out_sync.extend(sb.drain())
    t_sync = time.perf_counter() - t0

    # --- pipelined engine, fixed-size windows (pure stage overlap) ---------
    server_fixed = _build_server(n_tenants)
    _warm(server_fixed, n_tenants, sizes)
    engine = AsyncDispatchEngine(server_fixed, max_batch=base_batch,
                                 max_wait_ms=1e9)
    engine.submit_many(_requests(feats[:base_batch], n_tenants))
    engine.drain(timeout=300.0)
    reqs_fixed = _requests(feats, n_tenants)
    t0 = time.perf_counter()
    engine.submit_many(reqs_fixed)
    out_fixed = engine.drain(timeout=600.0)
    t_fixed = time.perf_counter() - t0
    engine.close()

    # --- pipelined engine + adaptive batching (the full design) ------------
    server_async = _build_server(n_tenants)
    _warm(server_async, n_tenants, sizes)
    engine = AsyncDispatchEngine(server_async, max_batch=base_batch,
                                 max_wait_ms=1e9, adaptive_batch_cap=cap)
    engine.submit_many(_requests(feats[:base_batch], n_tenants))
    engine.drain(timeout=300.0)
    reqs_async = _requests(feats, n_tenants)
    t0 = time.perf_counter()
    engine.submit_many(reqs_async)
    out_async = engine.drain(timeout=600.0)
    t_async = time.perf_counter() - t0
    window_sizes = sorted({w["size"] for w in engine.window_log})
    engine.close()

    # --- tracking A/B: OFF vs fused device tracker ON ----------------------
    # same adaptive engine config; the only variable is the track stage.
    # ON stages score -> transform -> track as ONE device dispatch and
    # never pulls estimator state to host inside the timed region.
    def _adaptive_run(config: ServerConfig | None):
        server = _build_server(n_tenants, config)
        _warm(server, n_tenants, sizes)
        eng = AsyncDispatchEngine(server, max_batch=base_batch,
                                  max_wait_ms=1e9, adaptive_batch_cap=cap)
        eng.submit_many(_requests(feats[:base_batch], n_tenants))
        eng.drain(timeout=300.0)
        rq = _requests(feats, n_tenants)
        t0 = time.perf_counter()
        eng.submit_many(rq)
        out = eng.drain(timeout=600.0)
        dt = time.perf_counter() - t0
        eng.close()
        return server, rq, out, dt

    server_off, reqs_off, out_off, t_off = _adaptive_run(
        ServerConfig(track_quantiles=False))
    server_on, reqs_on, out_on, t_on = _adaptive_run(
        ServerConfig(track_device=True))
    assert server_on.metrics["track_staged_windows"] > 0
    # estimator_streams() is the host-pull boundary: everything staged on
    # device (warm-up + timed stream) must materialize, nothing lost
    tracked = sum(e.count for e in server_on.estimator_streams().values())
    assert tracked == n_events + sum(sizes) + base_batch, tracked

    # --- parity: identical scores for identical traffic --------------------
    assert len(out_sync) == len(out_fixed) == len(out_async) == n_events
    assert len(out_off) == len(out_on) == n_events
    by_id_off = {r.request_id: r.score for r in out_off}
    by_id_on = {r.request_id: r.score for r in out_on}
    err_ab = max(abs(by_id_on[a.request_id] - by_id_off[b.request_id])
                 for a, b in zip(reqs_on, reqs_off))
    assert err_ab == 0.0, err_ab   # tracking must never touch the scores
    by_id_sync = {r.request_id: r.score for r in out_sync}
    by_id_fixed = {r.request_id: r.score for r in out_fixed}
    by_id_async = {r.request_id: r.score for r in out_async}
    err = max(
        max(abs(by_id_fixed[a.request_id] - by_id_sync[s.request_id])
            for a, s in zip(reqs_fixed, reqs)),
        max(abs(by_id_async[a.request_id] - by_id_sync[s.request_id])
            for a, s in zip(reqs_async, reqs)),
    )

    result = {
        "tenants": n_tenants,
        "events": n_events,
        "base_batch": base_batch,
        "adaptive_cap": cap,
        "adaptive_window_sizes": window_sizes,
        "s_sync": t_sync,
        "s_engine_fixed": t_fixed,
        "s_engine_adaptive": t_async,
        "us_per_event_sync": t_sync / n_events * 1e6,
        "us_per_event_async": t_async / n_events * 1e6,
        "events_per_s_sync": n_events / t_sync,
        "events_per_s_async": n_events / t_async,
        "speedup_fixed_vs_sync": t_sync / t_fixed,
        "speedup_vs_sync": t_sync / t_async,
        "max_abs_err": float(err),
        # tracking A/B (acceptance: ON >= 0.9x OFF on the mixed workload)
        "events_per_s_track_off": n_events / t_off,
        "events_per_s_track_on": n_events / t_on,
        "tracking_on_off_ratio": t_off / t_on,
        "track_staged_windows": int(
            server_on.metrics["track_staged_windows"]),
        "track_spills": int(server_on._tracker.spills),
    }
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=1)
        f.write("\n")
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    r = run(quick=args.quick)
    for key, v in r.items():
        print(f"{key}: {v:.4f}" if isinstance(v, float) else f"{key}: {v}")
