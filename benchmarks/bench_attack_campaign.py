"""Dispatch latency under adversarial attack traffic, full client stack ON.

The adversarial-campaign suite proves the closed loop keeps the alert-rate
SLO; this benchmark prices it.  Every served window runs the ENTIRE
production path — fenced ``ReplicaSet.dispatch`` (tracking included), the
client :class:`~repro.serving.decision_loop.DecisionLoop`, hash-chained
:class:`~repro.serving.audit.AuditLog` appends, and the drift controller's
``observe``/``tick`` — and we compare per-window dispatch latency between

  * **quiet** — stationary benign traffic (no wave active), and
  * **attack** — an :class:`AttackWave` burst on the measured tenant
    (fraud share x24, boundary-drifted malicious mass), which is also what
    makes the drift controller actually alarm + refresh mid-measurement.

Headline numbers: p50/p99 window latency and us/event for both regimes,
the attack/quiet p99 ratio (the "does an attack DoS the data plane?"
question — it must stay near 1), and the amortized audit append cost.
Emits ``benchmarks/results/BENCH_attack_campaign.json``.
"""
from __future__ import annotations

import itertools
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.experiments.fraud_world import AttackCampaign, AttackWave
from repro.serving import (
    AuditLog,
    DecisionLoop,
    DecisionPolicy,
    FleetCalibrationController,
    GenerationLedger,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    ServerConfig,
)
from repro.serving.drift import CalibrationRefreshController
from repro.serving.types import ScoringRequest
from repro.training.data import TenantProfile

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_attack_campaign.json")
DIM = 8
ALERT_RATE = 0.05
REF = np.linspace(0.0, 1.0, 64)
TENANTS = ("t0", "t1")
WINDOW = 128


def _campaign() -> AttackCampaign:
    wave = AttackWave(name="burst", targets=("t0",), start_day=1,
                      duration=30, fraud_multiplier=24.0,
                      separation_scale=0.6, drift_per_day=0.02,
                      boundary_mass=0.25, boundary_scale=0.55)
    tenants = {t: TenantProfile(t, fraud_rate=0.01,
                                feature_shift=0.25 + 0.05 * i, seed=900 + i)
               for i, t in enumerate(TENANTS)}
    return AttackCampaign(tenants=tenants, waves=(wave,), promotion_days=(),
                          n_days=31, dim=DIM, seed=7)


def _expert(direction: np.ndarray):
    w = np.asarray(direction, np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))), jnp.float32)

    return score


def _server(campaign: AttackCampaign) -> MuseServer:
    factories = {f"e{i}": (lambda d=campaign._direction(t): _expert(d))
                 for i, t in enumerate(TENANTS)}
    rules = tuple(ScoringRule(Condition(tenants=(t,)), f"p{i}")
                  for i, t in enumerate(TENANTS)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version="v1"),
        ServerConfig(quantile_capacity=8192, recent_capacity=512,
                     refresh_alert_rate=ALERT_RATE, refresh_rel_error=0.5))
    for i, t in enumerate(TENANTS):
        server.deploy(PredictorSpec(f"p{i}", (f"e{i}",), (0.2,), (1.0,),
                                    QuantileMap.identity(64)), factories)
    return server


def _measure(campaign: AttackCampaign, days: range, n_windows: int,
             warm_windows: int) -> dict:
    """Serve ``n_windows`` of traffic drawn from ``days``; full stack ON."""
    reps = [Replica(i, _server(campaign), "v1", ready=True) for i in range(2)]
    rs = ReplicaSet(reps)
    fleet = FleetCalibrationController(
        rs, REF, RefreshPolicy(alert_rate=ALERT_RATE, rel_error=0.5,
                               n_levels=64, fit_window="recent"))
    ctrl = CalibrationRefreshController(None, REF, psi_alarm=0.08,
                                        window=768, reject_cooldown=2,
                                        fleet=fleet)
    audit, ledger = AuditLog(), GenerationLedger()
    loop = DecisionLoop(DecisionPolicy(alert_rate=ALERT_RATE,
                                       block_rate=0.001), REF, audit=audit)
    rid = itertools.count()
    day_cycle = itertools.cycle(days)
    lat_ms: list[float] = []
    audit_s = 0.0
    for w in range(warm_windows + n_windows):
        day = next(day_cycle)
        for ti, t in enumerate(TENANTS):
            x, _ = campaign.sample(t, day, WINDOW)
            reqs = [ScoringRequest(intent=Intent(tenant=t), features=f,
                                   request_id=next(rid)) for f in x]
            t0 = time.perf_counter()
            resps = rs.dispatch(reqs, stream=t)
            dt = time.perf_counter() - t0
            ta = time.perf_counter()
            loop.process(reqs, resps)
            audit_s += time.perf_counter() - ta
            ctrl.observe(t, resps[0].predictor,
                         np.asarray([r.score for r in resps]))
            ctrl.tick()
            if w >= warm_windows and ti == 0:   # measure the attacked tenant
                lat_ms.append(dt * 1e3)
        if w == 0:
            fleet.refresh_fleet()
    ledger.record_replicas(rs)
    lat = np.asarray(lat_ms)
    return {
        "windows": len(lat_ms),
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "us_per_event": float(lat.mean() * 1e3 / WINDOW),
        "audit_us_per_event": float(
            audit_s * 1e6 / max(len(audit), 1)),
        "audit_entries": len(audit),
        "refreshes": len(ctrl.refreshes),
        "ledger_generations": sorted(ledger.generations()),
    }


def run(quick: bool = False) -> dict:
    campaign = _campaign()
    n_windows = 30 if quick else 120
    warm = 4 if quick else 8
    quiet = _measure(campaign, range(0, 1), n_windows, warm)
    attack = _measure(campaign, range(1, campaign.n_days), n_windows, warm)
    result = {
        "window": WINDOW,
        "tenants": list(TENANTS),
        "quiet": quiet,
        "attack": attack,
        "p99_ms_quiet": quiet["p99_ms"],
        "p99_ms_attack": attack["p99_ms"],
        "p99_ratio_attack_vs_quiet": attack["p99_ms"] /
        max(quiet["p99_ms"], 1e-9),
        "us_per_event_attack": attack["us_per_event"],
        "audit_us_per_event": attack["audit_us_per_event"],
        "attack_refreshes": attack["refreshes"],
    }
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    r = run()
    for label in ("quiet", "attack"):
        row = r[label]
        print(f"{label:>6}: p50={row['p50_ms']:.2f}ms  "
              f"p99={row['p99_ms']:.2f}ms  "
              f"us/event={row['us_per_event']:.1f}  "
              f"audit_us/event={row['audit_us_per_event']:.2f}  "
              f"refreshes={row['refreshes']}")
    print(f"p99 attack/quiet ratio: {r['p99_ratio_attack_vs_quiet']:.2f}")
    print(f"results -> {RESULTS_PATH}")


if __name__ == "__main__":
    main()
