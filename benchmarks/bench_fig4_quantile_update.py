"""Fig. 4 reproduction: quantile-transformation update for a cold-start client.

Three predictors over the same 8-model ensemble, evaluated on live client
traffic against the target (reference) distribution, per score bin:

  predictor raw — no quantile transformation (scores collapse near 0);
  predictor v0  — cold-start default T^Q_v0 (Beta-mixture prior on training
                  scores) — bounded low-bin error, drifts in high bins;
  predictor v1  — custom client-specific T^Q_v1 fit on live traffic —
                  restores alignment.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import bin_relative_error
from repro.core.transforms import quantile_map
from repro.experiments.fraud_world import FraudWorld

ENSEMBLE = tuple(f"m{i+1}" for i in range(8))


def run(quick: bool = False) -> dict:
    n_live = 120_000 if quick else 400_000
    world = FraudWorld.build(
        n_experts=8, betas=(0.18, 0.18, 0.02, 0.1, 0.18, 0.05, 0.18, 0.02),
        client_shift=0.4, seed=2,
    )

    # live client traffic (the 15-day onboarding window)
    x_live, _ = world.client.sample(n_live)
    agg_live = world.ensemble_aggregated(ENSEMBLE, x_live)

    # --- predictor raw: no T^Q
    res_raw = bin_relative_error(agg_live, world.ref_quantiles, n_bins=10)

    # --- predictor v0: cold-start default transformation (training prior)
    qm_v0 = world.coldstart_quantile_map(ENSEMBLE, n_trials=2)
    scores_v0 = np.asarray(qm_v0(jnp.asarray(agg_live, jnp.float32)))
    res_v0 = bin_relative_error(scores_v0, world.ref_quantiles, n_bins=10)

    # --- predictor v1: custom transformation fit on the first half of live
    # traffic, evaluated on the second half (the paper's week-before /
    # week-after protocol)
    half = n_live // 2
    qm_v1 = world.custom_quantile_map(ENSEMBLE, x_live[:half])
    scores_v1 = np.asarray(qm_v1(jnp.asarray(agg_live[half:], jnp.float32)))
    res_v1 = bin_relative_error(scores_v1, world.ref_quantiles, n_bins=10)

    def _errs(res):
        return [None if np.isnan(v) else float(v) for v in res["rel_err"]]

    # paper-claim scalars
    raw_first_bin = float(res_raw["observed"][0])
    v0_max_high_bin = float(np.nanmax(np.abs(res_v0["rel_err"][5:])))
    v1_max_high_bin = float(np.nanmax(np.abs(res_v1["rel_err"][5:8])))
    return {
        "bins": [f"[{i/10:.1f},{(i+1)/10:.1f})" for i in range(10)],
        "raw": _errs(res_raw),
        "v0": _errs(res_v0),
        "v1": _errs(res_v1),
        "raw_mass_in_first_bin": raw_first_bin,
        "v0_max_abs_rel_err_high_bins": v0_max_high_bin,
        "v1_max_abs_rel_err_mid_bins": v1_max_high_bin,
    }


def main() -> None:
    res = run()
    print(f"{'bin':<12} {'raw %':>10} {'v0 (default) %':>15} {'v1 (custom) %':>15}")
    for i, b in enumerate(res["bins"]):
        def fmt(v):
            return f"{100*v:10.1f}" if v is not None else "       nan"
        print(f"{b:<12} {fmt(res['raw'][i])} {fmt(res['v0'][i]):>15} "
              f"{fmt(res['v1'][i]):>15}")
    print(f"\nraw: {100*res['raw_mass_in_first_bin']:.1f}% of scores in [0,0.1) "
          "(paper: 100%, 43% rel err)")
    print(f"v0 max |rel err| in bins >=0.5: {100*res['v0_max_abs_rel_err_high_bins']:.0f}% "
          "(paper: up to 1691%)")
    print(f"v1 max |rel err| in bins [0.5,0.8): {100*res['v1_max_abs_rel_err_mid_bins']:.1f}% "
          "(paper: 7.1-11%)")


if __name__ == "__main__":
    main()
