"""Fig. 4 reproduction: quantile-transformation update for a cold-start client.

Three predictors over the same 8-model ensemble, evaluated on live client
traffic against the target (reference) distribution, per score bin:

  predictor raw — no quantile transformation (scores collapse near 0);
  predictor v0  — cold-start default T^Q_v0 (Beta-mixture prior on training
                  scores) — bounded low-bin error, drifts in high bins;
  predictor v1  — custom client-specific T^Q_v1 fit on live traffic —
                  restores alignment.

Also benchmarks the FLEET-WIDE refresh path (``run_refresh``): the
CalibrationController refits every ready (tenant, predictor) stream and
publishes one atomic transform-bank generation; wall time is reported vs.
tenant count (the paper's "swap T^Q in minutes, fleet-wide" claim, here
milliseconds at 64+ tenants).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import bin_relative_error
from repro.core.transforms import quantile_map
from repro.experiments.fraud_world import FraudWorld

ENSEMBLE = tuple(f"m{i+1}" for i in range(8))


def _fleet_server(n_tenants: int, n_samples: int, rng: np.random.Generator):
    """A server with one predictor per tenant (shared 2-model group), a
    warm T-row transform bank, and injected per-tenant live streams."""
    from repro.core.predictor import PredictorSpec
    from repro.core.quantiles import StreamingQuantileEstimator
    from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
    from repro.core.transforms import QuantileMap
    from repro.serving import MuseServer, ServerConfig
    from repro.serving.types import ScoringRequest

    dim = 8
    weights = [rng.normal(0, 1, dim).astype(np.float32) for _ in range(2)]

    def _model(w):
        return lambda x: jnp.asarray(
            1.0 / (1.0 + np.exp(-(np.asarray(x, np.float32) @ w))))

    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants))
    server = MuseServer(RoutingTable(rules, version="v1"),
                        ServerConfig(track_quantiles=False))
    factories = {"m1": lambda: _model(weights[0]),
                 "m2": lambda: _model(weights[1])}
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.3),
                                    (1.0, 1.0), QuantileMap.identity(256)),
                      factories)
    # one mixed batch spanning every tenant warms the T-row bank
    server.score_batch([
        ScoringRequest(intent=Intent(tenant=f"t{i}"),
                       features=rng.normal(0, 1, dim).astype(np.float32))
        for i in range(n_tenants)
    ])
    # per-tenant live streams: shifted Beta draws (distinct distributions)
    for i in range(n_tenants):
        est = StreamingQuantileEstimator(capacity=131072, seed=i)
        est.update(rng.beta(0.6 + 0.02 * (i % 8), 6.0 + 0.5 * (i % 5),
                            n_samples))
        server._estimators[(f"t{i}", f"p{i}")] = est
    return server


def run_refresh(quick: bool = False) -> dict:
    """refresh_fleet() wall time vs tenant count (refit + validate + publish)."""
    from repro.core.transforms import fraud_reference_quantiles
    from repro.serving import CalibrationController, RefreshPolicy

    tenant_counts = (4, 16, 64) if quick else (4, 16, 64, 128)
    # Eq.-5 gate: a=1%, delta=50% (quick) needs ~1.5k samples, delta=20%
    # needs ~9.5k — streams are injected just past the gate.
    rel_error = 0.5 if quick else 0.2
    n_samples = 2_000 if quick else 10_000
    ref = np.asarray(fraud_reference_quantiles(256))
    rows = []
    for t in tenant_counts:
        rng = np.random.default_rng(t)
        server = _fleet_server(t, n_samples, rng)
        ctrl = CalibrationController(
            server, ref, RefreshPolicy(alert_rate=0.01, rel_error=rel_error))
        t0 = time.perf_counter()
        res = ctrl.refresh_fleet()
        wall_ms = (time.perf_counter() - t0) * 1000.0
        assert len(res.refreshed) == t, (
            f"expected {t} refreshed streams, got {len(res.refreshed)} "
            f"(rejected: {[r.reasons for r in res.rejected]})")
        assert server.bank_generation == res.generation > 0
        rows.append({
            "tenants": t,
            "samples_per_stream": n_samples,
            "wall_ms": wall_ms,
            "refit_ms": res.refit_seconds * 1000.0,
            "validate_ms": res.validate_seconds * 1000.0,
            "publish_ms": res.publish_seconds * 1000.0,
            "us_per_tenant": wall_ms * 1000.0 / t,
            "generation": res.generation,
        })
    largest = rows[-1]
    return {
        "rows": rows,
        "max_tenants": largest["tenants"],
        "wall_ms_at_max": largest["wall_ms"],
        "us_per_tenant_at_max": largest["us_per_tenant"],
    }


def run(quick: bool = False) -> dict:
    n_live = 120_000 if quick else 400_000
    world = FraudWorld.build(
        n_experts=8, betas=(0.18, 0.18, 0.02, 0.1, 0.18, 0.05, 0.18, 0.02),
        client_shift=0.4, seed=2,
    )

    # live client traffic (the 15-day onboarding window)
    x_live, _ = world.client.sample(n_live)
    agg_live = world.ensemble_aggregated(ENSEMBLE, x_live)

    # --- predictor raw: no T^Q
    res_raw = bin_relative_error(agg_live, world.ref_quantiles, n_bins=10)

    # --- predictor v0: cold-start default transformation (training prior)
    qm_v0 = world.coldstart_quantile_map(ENSEMBLE, n_trials=2)
    scores_v0 = np.asarray(qm_v0(jnp.asarray(agg_live, jnp.float32)))
    res_v0 = bin_relative_error(scores_v0, world.ref_quantiles, n_bins=10)

    # --- predictor v1: custom transformation fit on the first half of live
    # traffic, evaluated on the second half (the paper's week-before /
    # week-after protocol)
    half = n_live // 2
    qm_v1 = world.custom_quantile_map(ENSEMBLE, x_live[:half])
    scores_v1 = np.asarray(qm_v1(jnp.asarray(agg_live[half:], jnp.float32)))
    res_v1 = bin_relative_error(scores_v1, world.ref_quantiles, n_bins=10)

    def _errs(res):
        return [None if np.isnan(v) else float(v) for v in res["rel_err"]]

    # paper-claim scalars
    raw_first_bin = float(res_raw["observed"][0])
    v0_max_high_bin = float(np.nanmax(np.abs(res_v0["rel_err"][5:])))
    v1_max_high_bin = float(np.nanmax(np.abs(res_v1["rel_err"][5:8])))
    return {
        "bins": [f"[{i/10:.1f},{(i+1)/10:.1f})" for i in range(10)],
        "raw": _errs(res_raw),
        "v0": _errs(res_v0),
        "v1": _errs(res_v1),
        "raw_mass_in_first_bin": raw_first_bin,
        "v0_max_abs_rel_err_high_bins": v0_max_high_bin,
        "v1_max_abs_rel_err_mid_bins": v1_max_high_bin,
    }


def main() -> None:
    res = run()
    res["refresh"] = run_refresh()
    print(f"{'bin':<12} {'raw %':>10} {'v0 (default) %':>15} {'v1 (custom) %':>15}")
    for i, b in enumerate(res["bins"]):
        def fmt(v):
            return f"{100*v:10.1f}" if v is not None else "       nan"
        print(f"{b:<12} {fmt(res['raw'][i])} {fmt(res['v0'][i]):>15} "
              f"{fmt(res['v1'][i]):>15}")
    print(f"\nraw: {100*res['raw_mass_in_first_bin']:.1f}% of scores in [0,0.1) "
          "(paper: 100%, 43% rel err)")
    print(f"v0 max |rel err| in bins >=0.5: {100*res['v0_max_abs_rel_err_high_bins']:.0f}% "
          "(paper: up to 1691%)")
    print(f"v1 max |rel err| in bins [0.5,0.8): {100*res['v1_max_abs_rel_err_mid_bins']:.1f}% "
          "(paper: 7.1-11%)")
    print("\nfleet-wide atomic calibration refresh (refresh_fleet):")
    print(f"{'tenants':>8} {'wall ms':>9} {'refit ms':>9} {'validate ms':>12} "
          f"{'publish ms':>11} {'us/tenant':>10}")
    for row in res["refresh"]["rows"]:
        print(f"{row['tenants']:>8} {row['wall_ms']:>9.2f} "
              f"{row['refit_ms']:>9.2f} {row['validate_ms']:>12.2f} "
              f"{row['publish_ms']:>11.2f} {row['us_per_tenant']:>10.1f}")


if __name__ == "__main__":
    main()
