"""Fig. 5 reproduction: operational stability during a rolling
transformation update (T^Q_v0 -> T^Q_v1).

Simulates the Kubernetes rolling update over 3 replicas with warm-up before
readiness (the JVM-JIT analogue is XLA compilation), while live traffic flows
continuously.  Reports the pod-count timeline and latency percentiles, and
checks the paper's claims: pod count surges then returns to baseline;
latencies stay bounded throughout the transition (no cold replica ever
serves); warm-up itself is visible as off-path work.
"""
from __future__ import annotations

import numpy as np

from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.experiments.fraud_world import DIM, FraudWorld
from repro.serving.rollout import Replica, ReplicaSet, RollingUpdate
from repro.serving.server import MuseServer
from repro.serving.types import ScoringRequest

ENSEMBLE = ("m1", "m2", "m3")


def _make_server(world: FraudWorld, qm: QuantileMap, version: str) -> MuseServer:
    table = RoutingTable(
        (ScoringRule(Condition(), "bank1-predictor"),), version=version
    )
    server = MuseServer(table)
    spec = world.predictor_spec("bank1-predictor", ENSEMBLE, qm)
    server.deploy(spec, world.model_factories())
    return server


def run(quick: bool = False) -> dict:
    world = FraudWorld.build(seed=4)
    x_fit, _ = world.client.sample(50_000)
    qm_v0 = world.coldstart_quantile_map(ENSEMBLE, n_trials=1)
    qm_v1 = world.custom_quantile_map(ENSEMBLE, x_fit)

    n_replicas = 3
    replicas = []
    for i in range(n_replicas):
        srv = _make_server(world, qm_v0, "v0")
        from repro.serving.warmup import warm_up
        warm_up(srv, DIM, batch_sizes=(16,))
        replicas.append(Replica(i, srv, "v0", ready=True))
    rs = ReplicaSet(replicas)

    update = RollingUpdate(
        rs, lambda: _make_server(world, qm_v1, "v1"), "v1",
        schema_dim=DIM, warmup_batch_sizes=(16,),
    )

    rng = np.random.default_rng(0)

    def traffic():
        while True:
            feats = rng.normal(0, 1, (16, DIM)).astype(np.float32)
            yield [ScoringRequest(intent=Intent(tenant="bank1"), features=f)
                   for f in feats]

    batches = 4 if quick else 8
    timeline = update.run_with_traffic(traffic(), batches_per_transition=batches)

    lats = np.array([t["latency_ms"] for t in timeline])
    pods = [t["pod_count"] for t in timeline]
    warmups = [r.warmup_seconds for r in rs.replicas]
    return {
        "samples": len(timeline),
        "pod_baseline": n_replicas,
        "pod_peak": max(pods),
        "pod_final": pods[-1],
        "latency_p50_ms": float(np.percentile(lats, 50)),
        "latency_p99_ms": float(np.percentile(lats, 99)),
        "latency_max_ms": float(lats.max()),
        "min_ready": min(t["ready_count"] for t in timeline),
        "final_version": timeline[-1]["version"],
        "warmup_seconds_per_replica": [round(w, 3) for w in warmups],
        "versions_seen": sorted({t["version"] for t in timeline}),
    }


def main() -> None:
    res = run()
    for k, v in res.items():
        print(f"{k:>28}: {v}")
    ok = (res["pod_peak"] == res["pod_baseline"] + 1
          and res["pod_final"] == res["pod_baseline"]
          and res["min_ready"] >= res["pod_baseline"]
          and res["final_version"] == "v1")
    print(f"\nrolling-update invariants (surge=1, maxUnavailable=0, "
          f"full promotion): {'OK' if ok else 'VIOLATED'}")


if __name__ == "__main__":
    main()
