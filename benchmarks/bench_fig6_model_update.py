"""Fig. 6 reproduction: live model update {m1,m2} -> {m1,m2,m3}.

  p1   — old ensemble {m1,m2} with its custom T^Q_v1 (aligned);
  p1.5 — new ensemble {m1,m2,m3} with the OLD T^Q_v1 (hypothetical:
         transformation not refreshed — misaligned, under-alerting);
  p2   — new ensemble with refreshed T^Q_v2 (aligned again).

Also checks the paper's Sec.-3.2 claims: recall@1%FPR identical between
p1.5 and p2 (quantile map is monotone), and p2 >= p1 (new expert adds
discriminative power for the shifted fraud pattern m3 was trained on).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import bin_relative_error, recall_at_fpr
from repro.experiments.fraud_world import FraudWorld
from repro.training.data import FraudEventStream, TenantProfile
from repro.experiments.fraud_world import train_expert


def run(quick: bool = False) -> dict:
    n_live = 120_000 if quick else 400_000
    world = FraudWorld.build(n_experts=2, betas=(0.18, 0.18),
                             client_shift=0.3, seed=3)
    # This client sees the SAME fraud pattern family as the training pool
    # (same generative direction) with a moderate covariate shift, so the
    # legacy experts retain most of their signal — the paper's setting where
    # the update brings an incremental (+1.1pp) recall gain.
    world.client = FraudEventStream(
        TenantProfile("train-pool", fraud_rate=0.008, feature_shift=0.35,
                      seed=9000)
    )
    # m3: new expert trained on the client's current distribution at
    # aggressive undersampling (beta = 2%) — the paper's new fraud pattern
    # specialist.
    recent = FraudEventStream(
        TenantProfile("train-pool", fraud_rate=0.01, feature_shift=0.35,
                      seed=303)
    )
    world.experts["m3"] = train_expert(recent, "m3", 0.02, mask_seed=33)

    old, new = ("m1", "m2"), ("m1", "m2", "m3")

    x_pre, y_pre = world.client.sample(n_live)     # pre-deployment period
    x_post, y_post = world.client.sample(n_live)   # post-deployment period

    # p1: old ensemble + its custom transformation (fit pre-deployment)
    qm_v1 = world.custom_quantile_map(old, x_pre)
    agg_old_pre = world.ensemble_aggregated(old, x_pre)
    p1_scores = np.asarray(qm_v1(jnp.asarray(agg_old_pre, jnp.float32)))
    res_p1 = bin_relative_error(p1_scores, world.ref_quantiles, n_bins=10)

    # p1.5: NEW ensemble + OLD transformation, post-deployment
    agg_new_post = world.ensemble_aggregated(new, x_post)
    p15_scores = np.asarray(qm_v1(jnp.asarray(agg_new_post, jnp.float32)))
    res_p15 = bin_relative_error(p15_scores, world.ref_quantiles, n_bins=10)

    # p2: NEW ensemble + refreshed transformation (fit on recent data)
    qm_v2 = world.custom_quantile_map(new, x_post)
    p2_scores = np.asarray(qm_v2(jnp.asarray(agg_new_post, jnp.float32)))
    res_p2 = bin_relative_error(p2_scores, world.ref_quantiles, n_bins=10)

    # Sec.-3.2 claims
    r_p1 = recall_at_fpr(p1_scores, y_pre, 0.01)
    r_p15 = recall_at_fpr(p15_scores, y_post, 0.01)
    r_p2 = recall_at_fpr(p2_scores, y_post, 0.01)

    # The control-plane validation view (serving/calibration.py step 4):
    # PSI drift + realized alert rate at the fixed client threshold tau.
    # p1.5 (stale T^Q across the model update) drifts and shifts the alert
    # rate; p2 (refreshed T^Q) must sit back inside the drift/rate bounds —
    # the quantitative form of "the update is invisible to client thresholds".
    from repro.serving.drift import realized_alert_rate, transformed_stream_psi
    target_a = 0.01
    alert_p1 = realized_alert_rate(p1_scores, world.ref_quantiles, target_a)
    alert_p15 = realized_alert_rate(p15_scores, world.ref_quantiles, target_a)
    alert_p2 = realized_alert_rate(p2_scores, world.ref_quantiles, target_a)
    psi_p15 = transformed_stream_psi(p15_scores, world.ref_quantiles)
    psi_p2 = transformed_stream_psi(p2_scores, world.ref_quantiles)

    def _errs(res):
        return [None if np.isnan(v) else float(v) for v in res["rel_err"]]

    return {
        "bins": [f"[{i/10:.1f},{(i+1)/10:.1f})" for i in range(10)],
        "p1": _errs(res_p1), "p1.5": _errs(res_p15), "p2": _errs(res_p2),
        "recall_p1": r_p1, "recall_p1.5": r_p15, "recall_p2": r_p2,
        "recall_gain_pct_points": 100.0 * (r_p2 - r_p1),
        "p15_max_abs_err": float(np.nanmax(np.abs(res_p15["rel_err"]))),
        "p2_max_abs_err": float(np.nanmax(np.abs(res_p2["rel_err"][:8]))),
        "target_alert_rate": target_a,
        "alert_rate_p1": alert_p1,
        "alert_rate_p1.5": alert_p15,
        "alert_rate_p2": alert_p2,
        "psi_p1.5": psi_p15,
        "psi_p2": psi_p2,
    }


def main() -> None:
    res = run()
    print(f"{'bin':<12} {'p1 %':>9} {'p1.5 %':>9} {'p2 %':>9}")
    for i, b in enumerate(res["bins"]):
        def fmt(v):
            return f"{100*v:9.1f}" if v is not None else "      nan"
        print(f"{b:<12} {fmt(res['p1'][i])} {fmt(res['p1.5'][i])} {fmt(res['p2'][i])}")
    print(f"\nrecall@1%FPR: p1={res['recall_p1']:.4f}  "
          f"p1.5={res['recall_p1.5']:.4f}  p2={res['recall_p2']:.4f}")
    print(f"p1.5 == p2 recall (monotone T^Q): "
          f"{abs(res['recall_p1.5'] - res['recall_p2']) < 1e-9}")
    print(f"p2 - p1 recall gain: {res['recall_gain_pct_points']:+.2f} pct points "
          "(paper: +1.1)")
    a = res["target_alert_rate"]
    print(f"\nalert rate at fixed tau (target {100*a:.1f}%): "
          f"p1={100*res['alert_rate_p1']:.2f}%  "
          f"p1.5={100*res['alert_rate_p1.5']:.2f}%  "
          f"p2={100*res['alert_rate_p2']:.2f}%")
    print(f"PSI vs reference: p1.5={res['psi_p1.5']:.3f}  "
          f"p2={res['psi_p2']:.3f}  (refresh restores < 0.25 bound)")


if __name__ == "__main__":
    main()
