"""Fleet calibration plane: merged-fit + fenced-broadcast cost vs fleet size.

The refactor's cost claim, measured: lifting calibration out of the replica
means ONE pass does the pull + sketch merge + gate/refit/validate + fenced
broadcast for the whole fleet.  This benchmark scales the replica count
(2–16) at a fixed tenant population and measures where the wall time goes:

  * **pull+merge** — exact estimator checkpoints from every replica reduced
    per (tenant, predictor) via ``StreamingQuantileEstimator.merged`` (the
    Efraimidis–Spirakis weighted reselection; grows ~linearly with fleet
    size);
  * **refit+validate** — the ONE vectorized fit over the merged view (flat
    in fleet size — the point of merging: fit cost is per-stream, not
    per-replica-stream);
  * **publish** — the fenced per-replica broadcast
    (``publish_quantile_maps(..., generation=...)``; linear in fleet size,
    one bank rebuild per replica).

Also records the per-pass accuracy proxy: merged-fit rank error of the
published tables against the concatenated ground-truth stream, next to the
documented ``merge_rank_error_bound``.  Emits
``benchmarks/results/BENCH_fleet_refresh.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import merge_rank_error_bound, required_sample_size
from repro.core.quantiles import StreamingQuantileEstimator
from repro.core.routing import Condition, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.serving import (
    FleetCalibrationController,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    ServerConfig,
)

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_fleet_refresh.json")
DIM = 16
CAP = 8192


def _model(seed: int):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, DIM).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


def _server(n_tenants: int) -> MuseServer:
    factories = {"m1": lambda: _model(1), "m2": lambda: _model(2)}
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants))
    server = MuseServer(RoutingTable(rules, version="v1"),
                        ServerConfig(refresh_alert_rate=0.05,
                                     refresh_rel_error=0.5))
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      factories)
    return server


def run(quick: bool = False) -> dict:
    n_tenants = 4 if quick else 8
    replica_counts = (2, 4, 8) if quick else (2, 4, 8, 16)
    gate = required_sample_size(0.05, 0.5)
    per_stream = 4 * gate                     # fleet-total events per stream
    policy = RefreshPolicy(alert_rate=0.05, rel_error=0.5, n_levels=64)
    ref = np.linspace(0.0, 1.0, 64) ** 2
    rng = np.random.default_rng(0)
    streams = {i: rng.normal(0.5, 0.15, per_stream).clip(0, 1)
               for i in range(n_tenants)}

    rows: list[dict] = []
    for n_replicas in replica_counts:
        reps = [Replica(r, _server(n_tenants), "v1", ready=True)
                for r in range(n_replicas)]
        per_rep = per_stream // n_replicas
        for r, rep in enumerate(reps):
            for i, data in streams.items():
                est = StreamingQuantileEstimator(
                    capacity=CAP, seed=31 * r + i, recent_capacity=256)
                est.update(data[r * per_rep:(r + 1) * per_rep])
                rep.server._estimators[(f"t{i}", f"p{i}")] = est
        fleet = FleetCalibrationController(ReplicaSet(reps), ref, policy)

        t0 = time.perf_counter()
        res = fleet.refresh_fleet()
        wall_s = time.perf_counter() - t0
        assert len(res.refreshed) == n_tenants, \
            [rep.reasons for rep in res.reports]
        assert len(res.acked) == n_replicas and not res.nacked

        # accuracy proxy: worst published-table rank error vs ground truth
        worst = 0.0
        for i, data in streams.items():
            q = np.asarray(reps[0].server.predictors[f"p{i}"]
                           .pipeline.src_quantiles)
            levels = np.linspace(0.0, 1.0, len(q))
            ranks = np.searchsorted(np.sort(data), q,
                                    side="right") / len(data)
            worst = max(worst, float(
                np.max(np.abs(ranks - levels)[2:-2])))
        rows.append({
            "replicas": n_replicas,
            "streams": n_tenants,
            "events_per_stream": per_stream,
            "wall_ms": wall_s * 1e3,
            "merge_ms": res.merge_seconds * 1e3,
            "refit_ms": res.refit_seconds * 1e3,
            "validate_ms": res.validate_seconds * 1e3,
            "publish_ms": res.publish_seconds * 1e3,
            "publish_ms_per_replica": res.publish_seconds * 1e3 / n_replicas,
            "fleet_generation": res.fleet_generation,
            "worst_rank_error": worst,
            "rank_error_bound": merge_rank_error_bound(CAP, CAP),
        })

    first, last = rows[0], rows[-1]
    result = {
        "tenants": n_tenants,
        "replica_counts": list(replica_counts),
        "estimator_capacity": CAP,
        "rows": rows,
        "max_replicas": last["replicas"],
        "wall_ms_at_max": last["wall_ms"],
        "merge_ms_at_max": last["merge_ms"],
        "publish_ms_at_max": last["publish_ms"],
        # fit cost must be ~flat in fleet size (it runs on the MERGED view)
        "refit_ratio_max_vs_min": last["refit_ms"] / max(first["refit_ms"],
                                                         1e-9),
        "all_within_bound": all(r["worst_rank_error"]
                                <= max(r["rank_error_bound"], 0.02)
                                for r in rows),
    }
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    r = run()
    for row in r["rows"]:
        print(f"replicas={row['replicas']:>2}  wall={row['wall_ms']:8.1f}ms  "
              f"merge={row['merge_ms']:7.1f}ms  refit={row['refit_ms']:6.1f}ms  "
              f"publish={row['publish_ms']:7.1f}ms  "
              f"rank_err={row['worst_rank_error']:.4f} "
              f"(bound {row['rank_error_bound']:.4f})")
    print(f"results -> {RESULTS_PATH}")


if __name__ == "__main__":
    main()
