"""Kernel microbenchmarks: Pallas (interpret mode on CPU) vs pure-jnp oracle.

On this container Pallas runs in interpret mode, so wall-clock favors the
jnp path — the deliverable here is CORRECTNESS at benchmark scale plus the
op-count/fusion story (one fused kernel vs K+2 staged HBM round trips),
with per-call timings for the jnp reference implementations that the
serving/dry-run paths actually execute on CPU.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _timeit(fn, repeat=20):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    results = {}

    # quantile map @ 64k scores, 256-knot tables
    n, nq = (16_384 if quick else 65_536), 256
    scores = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    src = jnp.asarray(np.sort(rng.uniform(0, 1, nq)), jnp.float32)
    refq = jnp.asarray(np.sort(rng.uniform(0, 1, nq)), jnp.float32)
    jnp_qm = jax.jit(ref.quantile_map)
    t_ref = _timeit(lambda: jnp_qm(scores, src, refq))
    out_k = ops.quantile_map(scores, src, refq)
    np.testing.assert_allclose(np.asarray(out_k),
                               np.asarray(jnp_qm(scores, src, refq)),
                               rtol=1e-4, atol=1e-5)
    results["quantile_map_jnp_64k"] = {"us_per_call": t_ref * 1e6,
                                       "ns_per_score": t_ref / n * 1e9,
                                       "kernel_allclose": True}

    # fused score pipeline @ 64k x 8 experts
    k = 8
    raw = jnp.asarray(rng.uniform(0, 1, (n, k)), jnp.float32)
    betas = jnp.asarray(rng.uniform(0.02, 0.5, k), jnp.float32)
    weights = jnp.ones((k,), jnp.float32)
    jnp_sp = jax.jit(ref.score_pipeline)
    t_sp = _timeit(lambda: jnp_sp(raw, betas, weights, src, refq))
    out_k = ops.score_pipeline(raw, betas, weights, src, refq)
    np.testing.assert_allclose(
        np.asarray(out_k), np.asarray(jnp_sp(raw, betas, weights, src, refq)),
        rtol=1e-4, atol=1e-5)
    results["score_pipeline_jnp_64kx8"] = {"us_per_call": t_sp * 1e6,
                                           "ns_per_event": t_sp / n * 1e9,
                                           "kernel_allclose": True}

    # banked (tenant-indexed) pipeline: block-skip fast path story.  The
    # prefetched kernel skips the one-hot gather matmuls on all-one-tenant
    # blocks; a sorted-by-tenant layout (what shard-bucketing produces)
    # skips every block, the adversarial interleave skips none.
    from repro.core.transforms import banked_score_pipeline
    from repro.kernels.score_pipeline import banked_skip_stats
    t_bank = 64
    banked_betas = jnp.asarray(rng.uniform(0.05, 1.0, (t_bank, k)), jnp.float32)
    banked_w = jnp.asarray(rng.uniform(0.1, 2.0, (t_bank, k)), jnp.float32)
    banked_src = jnp.asarray(np.sort(rng.uniform(0, 1, (t_bank, nq)), -1),
                             jnp.float32)
    banked_ref = jnp.asarray(np.sort(rng.uniform(0, 1, (t_bank, nq)), -1),
                             jnp.float32)
    # sorted: equal block-aligned per-tenant runs (what shard-bucketed,
    # per-tenant-bursty windows look like); adversarial: row-interleaved
    tid_sorted = jnp.asarray(np.repeat(np.arange(t_bank, dtype=np.int32),
                                       n // t_bank))
    tid_adv = jnp.asarray((np.arange(n) % t_bank).astype(np.int32))
    block = 256

    def banked(tid):
        return ops.score_pipeline_banked(
            raw, tid, banked_betas, banked_w, banked_src, banked_ref,
            block=block)

    t_sorted = _timeit(lambda: banked(tid_sorted), repeat=3)
    t_adv = _timeit(lambda: banked(tid_adv), repeat=3)
    oracle = jax.jit(banked_score_pipeline)
    for tid in (tid_sorted, tid_adv):
        np.testing.assert_allclose(
            np.asarray(banked(tid)),
            np.asarray(oracle(raw, tid, banked_betas, banked_w, banked_src,
                              banked_ref)),
            rtol=1e-4, atol=1e-5)
    skip_sorted = banked_skip_stats(np.asarray(tid_sorted), block=block)
    skip_adv = banked_skip_stats(np.asarray(tid_adv), block=block)
    results[f"score_pipeline_banked_{n // 1024}kx{k}"] = {
        "us_per_call": t_sorted * 1e6,
        "us_per_call_adversarial": t_adv * 1e6,
        "skip_rate_sorted": skip_sorted["skip_rate"],
        "skip_rate_adversarial": skip_adv["skip_rate"],
        "kernel_allclose": True,
    }

    # flash attention 1k x 8h GQA vs oracle
    b, t, hq, hkv, d = 1, (256 if quick else 1024), 8, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (b, t, hq, d)), jnp.bfloat16)
    kk = jnp.asarray(rng.normal(0, 1, (b, t, hkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (b, t, hkv, d)), jnp.bfloat16)
    jnp_fa = jax.jit(lambda a, b_, c: ref.flash_attention(a, b_, c, causal=True))
    t_fa = _timeit(lambda: jnp_fa(q, kk, v), repeat=5)
    out_k = ops.flash_attention(q, kk, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(jnp_fa(q, kk, v), np.float32),
        rtol=3e-2, atol=3e-2)
    results[f"flash_attention_jnp_{t}"] = {"us_per_call": t_fa * 1e6,
                                           "kernel_allclose": True}

    # decode attention over 16k cache
    s = 4096 if quick else 16_384
    qd = jnp.asarray(rng.normal(0, 1, (4, hq, d)), jnp.bfloat16)
    kc = jnp.asarray(rng.normal(0, 1, (4, s, hkv, d)), jnp.bfloat16)
    vc = jnp.asarray(rng.normal(0, 1, (4, s, hkv, d)), jnp.bfloat16)
    vlen = jnp.full((4,), s, jnp.int32)
    jnp_da = jax.jit(ref.decode_attention)
    t_da = _timeit(lambda: jnp_da(qd, kc, vc, vlen), repeat=10)
    out_k = ops.decode_attention(qd, kc, vc, vlen)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32),
        np.asarray(jnp_da(qd, kc, vc, vlen), np.float32),
        rtol=3e-2, atol=3e-2)
    results[f"decode_attention_jnp_{s}"] = {"us_per_call": t_da * 1e6,
                                            "kernel_allclose": True}
    return results


def main() -> None:
    res = run()
    for k, v in res.items():
        print(f"{k:>30}: {v}")


if __name__ == "__main__":
    main()
