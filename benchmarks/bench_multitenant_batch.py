"""Mixed-tenant micro-batch throughput: banked kernel vs per-predictor loop.

The MUSE claim under test: one tenant-indexed ``pallas_call``
(``score_pipeline_banked``) scoring a 64-tenant x 1024-event batch beats the
seed's per-predictor Python loop (T separate fused-kernel dispatches over
masked row subsets), because dispatch overhead and the T small kernels'
launch latency dominate the actual transform math at serving batch sizes.
Also checks kernel/oracle parity at benchmark scale and times the batched
vs one-element-at-a-time StreamingQuantileEstimator update.

  PYTHONPATH=src python -m benchmarks.bench_multitenant_batch [--quick]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantiles import StreamingQuantileEstimator
from repro.core.transforms import banked_score_pipeline
from repro.kernels import ops


def _timeit(fn, repeat=20):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeat


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    t = 16 if quick else 64          # tenants
    b = 256 if quick else 1024       # events in the micro-batch
    k, n = 4, 256                    # experts, quantile knots
    repeat = 5 if quick else 20

    betas = jnp.asarray(rng.uniform(0.05, 1.0, (t, k)), jnp.float32)
    weights = jnp.asarray(rng.uniform(0.1, 2.0, (t, k)), jnp.float32)
    src = jnp.asarray(np.sort(rng.uniform(0, 1, (t, n)), axis=-1), jnp.float32)
    refq = jnp.asarray(np.sort(rng.uniform(0, 1, (t, n)), axis=-1), jnp.float32)
    scores = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    tid_np = rng.integers(0, t, b).astype(np.int32)
    tid = jnp.asarray(tid_np)

    # --- banked: ONE kernel dispatch for the whole mixed-tenant batch ------
    def banked():
        return ops.score_pipeline_banked(scores, tid, betas, weights, src,
                                         refq)

    t_banked = _timeit(banked, repeat)

    # --- seed path: per-predictor Python loop of T fused-kernel dispatches -
    rows_per_tenant = [np.flatnonzero(tid_np == i) for i in range(t)]
    score_rows = [scores[jnp.asarray(r)] for r in rows_per_tenant]

    def per_predictor_loop():
        outs = []
        for i in range(t):
            if len(rows_per_tenant[i]) == 0:
                continue
            outs.append(ops.score_pipeline(score_rows[i], betas[i],
                                           weights[i], src[i], refq[i]))
        return outs

    t_loop = _timeit(per_predictor_loop, repeat)

    # --- parity: banked kernel vs pure-jnp per-row oracle ------------------
    got = np.asarray(banked())
    want = np.asarray(banked_score_pipeline(scores, tid, betas, weights, src,
                                            refq))
    max_err = float(np.max(np.abs(got - want)))

    # --- quantile tracking: one batched update vs element-at-a-time --------
    agg = np.asarray(rng.uniform(0, 1, b))
    est_batched = StreamingQuantileEstimator(capacity=1 << 16)
    t_upd_batched = _timeit(lambda: est_batched.update(agg) or 0, repeat)
    est_scalar = StreamingQuantileEstimator(capacity=1 << 16)

    def scalar_updates():
        for x in agg:
            est_scalar.update(np.asarray([x]))
        return 0

    t_upd_scalar = _timeit(scalar_updates, max(1, repeat // 5))

    return {
        "tenants": t,
        "batch": b,
        "us_banked": t_banked * 1e6,
        "us_per_predictor_loop": t_loop * 1e6,
        "kernel_speedup": t_loop / t_banked,
        "events_per_s_banked": b / t_banked,
        "events_per_s_loop": b / t_loop,
        "max_abs_err_vs_oracle": max_err,
        "us_quantile_update_batched": t_upd_batched * 1e6,
        "us_quantile_update_scalar": t_upd_scalar * 1e6,
        "quantile_update_speedup": t_upd_scalar / t_upd_batched,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    r = run(quick=args.quick)
    for key, v in r.items():
        print(f"{key}: {v:.3f}" if isinstance(v, float) else f"{key}: {v}")
