"""Serving latency / throughput (Sec. 3 SLO claims, scaled to this host).

Measures the MUSE data-plane hot path end to end (routing -> enrichment ->
ensemble -> T^C -> A -> T^Q) at several batch sizes, plus the transformation
pipeline alone — validating the paper's 'negligible transformation overhead'
claim.  Absolute numbers are CPU wall-clock (the paper's 30 ms p99 is on
production hardware); the *ratios* are the reproducible claim.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import score_pipeline
from repro.experiments.fraud_world import DIM, FraudWorld
from repro.serving.server import MuseServer
from repro.serving.types import ScoringRequest
from repro.serving.warmup import warm_up

ENSEMBLE = ("m1", "m2", "m3")


def _timeit(fn, *args, repeat=50):
    fn(*args)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / repeat


def run(quick: bool = False) -> dict:
    world = FraudWorld.build(seed=5)
    table = RoutingTable((ScoringRule(Condition(), "p"),), version="v1")
    server = MuseServer(table)
    qm = world.coldstart_quantile_map(ENSEMBLE, n_trials=1)
    server.deploy(world.predictor_spec("p", ENSEMBLE, qm),
                  world.model_factories())
    warm_up(server, DIM, batch_sizes=(1, 16, 64, 256))

    rng = np.random.default_rng(0)
    results = {}
    for bs in (1, 16, 64, 256):
        reqs = [ScoringRequest(intent=Intent(tenant="t"),
                               features=rng.normal(0, 1, DIM).astype(np.float32))
                for _ in range(bs)]
        per_call = _timeit(server.score_batch, reqs,
                           repeat=20 if quick else 60)
        results[f"batch_{bs}"] = {
            "latency_ms": per_call * 1e3,
            "events_per_s": bs / per_call,
        }

    # transformation pipeline alone (jitted, on-device) — the paper's
    # 'negligible overhead' claim: compare vs the full serving path
    n = 4096
    raw = jnp.asarray(rng.uniform(0, 1, (n, len(ENSEMBLE))), jnp.float32)
    betas = jnp.asarray([world.experts[m].beta for m in ENSEMBLE])
    weights = jnp.ones((len(ENSEMBLE),))
    import jax
    pipe = jax.jit(score_pipeline)
    t_pipe = _timeit(
        lambda: pipe(raw, betas, weights, qm.src_quantiles, qm.ref_quantiles)
    )
    results["transform_pipeline_4096"] = {
        "latency_ms": t_pipe * 1e3,
        "ns_per_event": t_pipe / n * 1e9,
    }
    full_per_event_us = results["batch_256"]["latency_ms"] * 1e3 / 256
    tf_per_event_us = t_pipe / n * 1e6
    results["transform_share_of_path_pct"] = 100.0 * tf_per_event_us / full_per_event_us
    return results


def main() -> None:
    res = run()
    for k, v in res.items():
        print(f"{k:>28}: {v}")
    share = res["transform_share_of_path_pct"]
    print(f"\ntransformation pipeline = {share:.2f}% of the serving path "
          "(paper: 'negligible latency overhead')")


if __name__ == "__main__":
    main()
