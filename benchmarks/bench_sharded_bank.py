"""Tenant-sharded transform banks: per-shard residency + dispatch throughput.

The sharded topology's two headline claims, measured at 256–4096 tenants:

  * **residency** — a shard holds ``Tl·(2K+2N)·4`` bank bytes, shrinking
    ~1/S with shard count S at fixed tenant count (the scaling move past
    ~10k tenants the ROADMAP flags);
  * **throughput** — the shard-bucketed ``shard_map`` dispatch must not
    regress vs the dense single-replica banked kernel at S=1 (on this CPU
    container both run the interpret-mode kernel; the S>1 numbers document
    the host-bucketing + launch overhead, not real-device scaling).

Every configuration asserts BITWISE f32 parity against the dense kernel
before it is timed.  Emits ``benchmarks/results/BENCH_sharded_bank.json``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import ShardedTransformBank, TransformBank
from repro.kernels import ops
from repro.launch.mesh import make_tenant_mesh
from repro.serving.server import ShardedBankDispatcher

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_sharded_bank.json")


def _timeit(fn, repeat=10):
    fn()                                   # warm (trace/compile)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def _random_bank(rng, t, k, n) -> TransformBank:
    return TransformBank(
        betas=jnp.asarray(rng.uniform(0.05, 1.0, (t, k)), jnp.float32),
        weights=jnp.asarray(rng.uniform(0.1, 2.0, (t, k)), jnp.float32),
        src_quantiles=jnp.asarray(
            np.sort(rng.uniform(0, 1, (t, n)), -1), jnp.float32),
        ref_quantiles=jnp.asarray(
            np.sort(rng.uniform(0, 1, (t, n)), -1), jnp.float32))


def run(quick: bool = False) -> dict:
    k, n = 4, 256
    b = 2048 if quick else 8192
    tenant_counts = (256, 1024) if quick else (256, 1024, 4096)
    shard_counts = [s for s in (1, 2, 4, 8) if s <= jax.device_count()]
    repeat = 5 if quick else 10
    rng = np.random.default_rng(0)

    rows: list[dict] = []
    for t in tenant_counts:
        bank = _random_bank(rng, t, k, n)
        dense_bytes = t * (2 * k + 2 * n) * 4
        scores = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid = rng.integers(0, t, b)
        tid_j = jnp.asarray(tid.astype(np.int32))
        scores_j = jnp.asarray(scores)

        def dense_call():
            return jax.block_until_ready(ops.score_pipeline_banked(
                scores_j, tid_j, bank.betas, bank.weights,
                bank.src_quantiles, bank.ref_quantiles))

        dense_s = _timeit(dense_call, repeat)
        dense = np.asarray(dense_call())
        rows.append({
            "tenants": t, "shards": 0, "path": "dense",
            "us_per_batch": dense_s * 1e6,
            "events_per_s": b / dense_s,
            "resident_bytes": dense_bytes,
            "residency_ratio": 1.0,
            "bitwise_parity": True,
        })

        for s in shard_counts:
            sbank = ShardedTransformBank.from_dense(bank, s)
            disp = ShardedBankDispatcher(make_tenant_mesh(s))
            got = disp(scores, tid, sbank)
            parity = bool(np.array_equal(got.view(np.uint32),
                                         dense.view(np.uint32)))
            sh_s = _timeit(lambda: disp(scores, tid, sbank), repeat)
            rows.append({
                "tenants": t, "shards": s, "path": "sharded",
                "us_per_batch": sh_s * 1e6,
                "events_per_s": b / sh_s,
                "resident_bytes": sbank.per_shard_bytes,
                "residency_ratio": sbank.per_shard_bytes / dense_bytes,
                "bitwise_parity": parity,
            })

    t_max = tenant_counts[-1]
    s_max = shard_counts[-1]
    by = {(r["tenants"], r["shards"], r["path"]): r for r in rows}
    dense_row = by[(t_max, 0, "dense")]
    s1_row = by[(t_max, 1, "sharded")]
    smax_row = by[(t_max, s_max, "sharded")]
    result = {
        "batch": b, "experts": k, "knots": n,
        "tenant_counts": list(tenant_counts),
        "shard_counts": shard_counts,
        "rows": rows,
        "max_tenants": t_max,
        "max_shards": s_max,
        "residency_ratio_at_smax": smax_row["residency_ratio"],
        "per_shard_bytes_at_smax": smax_row["resident_bytes"],
        "us_per_batch_smax": smax_row["us_per_batch"],
        # >= 1.0 means the S=1 sharded path costs no more than dense
        "throughput_ratio_s1": (s1_row["events_per_s"]
                                / dense_row["events_per_s"]),
        "all_bitwise_parity": all(r["bitwise_parity"] for r in rows),
    }
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    r = run()
    print(f"# wrote {RESULTS_PATH}")
    print(f"{'tenants':>8} {'shards':>7} {'path':>8} {'us/batch':>10} "
          f"{'events/s':>12} {'resident_kb':>12} {'1/S ratio':>10}")
    for row in r["rows"]:
        print(f"{row['tenants']:>8} {row['shards']:>7} {row['path']:>8} "
              f"{row['us_per_batch']:>10.1f} {row['events_per_s']:>12.0f} "
              f"{row['resident_bytes'] / 1024:>12.1f} "
              f"{row['residency_ratio']:>10.3f}")
    print(f"# residency@S={r['max_shards']}: {r['residency_ratio_at_smax']:.3f}"
          f" of dense; throughput_ratio_s1={r['throughput_ratio_s1']:.2f}x;"
          f" bitwise_parity={r['all_bitwise_parity']}")


if __name__ == "__main__":
    main()
