"""Table 1 reproduction: ECE_SWEEP^EM + Brier with/without Posterior
Correction, per expert (beta in {18%, 2%}) on in-distribution validation data
and out-of-distribution live client data, plus the calibrated ensemble."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import brier_score, ece_sweep_em
from repro.core.transforms import posterior_correction
from repro.experiments.fraud_world import FraudWorld


def _row(tag, scores, labels, beta):
    corrected = np.asarray(posterior_correction(jnp.asarray(scores), beta))
    ece0 = ece_sweep_em(scores, labels)
    ece1 = ece_sweep_em(corrected, labels)
    b0 = brier_score(scores, labels)
    b1 = brier_score(corrected, labels)
    return {
        "dataset_predictor": tag, "beta": beta,
        "ece_without": ece0, "ece_with": ece1,
        "ece_change_pct": 100.0 * (ece1 - ece0) / ece0 if ece0 else 0.0,
        "brier_without": b0, "brier_with": b1,
        "brier_change_pct": 100.0 * (b1 - b0) / b0 if b0 else 0.0,
    }


def run(quick: bool = False) -> dict:
    n_val = 80_000 if quick else 250_000
    world = FraudWorld.build(seed=1)
    rows = []

    # -- in-distribution: each expert on training-pool validation data
    for name, expert in world.experts.items():
        x, y = world.train_tenant.sample(n_val)
        raw = expert.score(x)
        rows.append(_row(f"validation/{name}", raw, y, expert.beta))

    # -- out-of-distribution: live client data
    x_live, y_live = world.client.sample(n_val)
    for name, expert in world.experts.items():
        raw = expert.score(x_live)
        rows.append(_row(f"live/{name}", raw, y_live, expert.beta))

    # -- ensemble p2 = {m1, m2, m3} on live data: aggregate of corrected vs raw
    names = ("m1", "m2", "m3")
    agg_raw = world.ensemble_aggregated(names, x_live, corrected=False)
    agg_pc = world.ensemble_aggregated(names, x_live, corrected=True)
    rows.append({
        "dataset_predictor": "live/p2-ensemble", "beta": None,
        "ece_without": ece_sweep_em(agg_raw, y_live),
        "ece_with": ece_sweep_em(agg_pc, y_live),
        "brier_without": brier_score(agg_raw, y_live),
        "brier_with": brier_score(agg_pc, y_live),
    })
    for r in rows[-1:]:
        r["ece_change_pct"] = 100.0 * (r["ece_with"] - r["ece_without"]) / r["ece_without"]
        r["brier_change_pct"] = 100.0 * (r["brier_with"] - r["brier_without"]) / r["brier_without"]

    # paper claim checks (Table 1): large ECE reductions from PC
    expert_rows = [r for r in rows if r["beta"] is not None]
    mean_ece_drop = float(np.mean([r["ece_change_pct"] for r in expert_rows]))
    ens = rows[-1]
    return {
        "rows": rows,
        "mean_expert_ece_change_pct": mean_ece_drop,
        "ensemble_ece_change_pct": ens["ece_change_pct"],
        "ensemble_brier_change_pct": ens["brier_change_pct"],
    }


def main() -> None:
    res = run()
    print(f"{'dataset/predictor':<26} {'beta':>5} {'ECE w/o':>10} {'ECE w/':>10} "
          f"{'chg%':>7} {'Brier w/o':>10} {'Brier w/':>10} {'chg%':>7}")
    for r in res["rows"]:
        beta = f"{r['beta']:.2f}" if r["beta"] is not None else "  -  "
        print(f"{r['dataset_predictor']:<26} {beta:>5} "
              f"{r['ece_without']:10.2e} {r['ece_with']:10.2e} "
              f"{r['ece_change_pct']:7.1f} "
              f"{r['brier_without']:10.2e} {r['brier_with']:10.2e} "
              f"{r['brier_change_pct']:7.1f}")
    print(f"\nmean expert ECE change: {res['mean_expert_ece_change_pct']:.1f}% "
          f"(paper: -80%+); ensemble ECE change: {res['ensemble_ece_change_pct']:.1f}% "
          f"(paper: -90.8%)")


if __name__ == "__main__":
    main()
