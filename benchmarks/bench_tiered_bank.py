"""Tiered tenant-bank store: bounded device residency at 10^3..10^6 tenants.

The tiered topology's three headline claims, measured against the S=8
sharded dispatch baseline (``BENCH_sharded_bank.json``):

  * **residency** — device-resident bank bytes are
    ``(hot + victims + 1)·(2K+2N)·4``, CONSTANT across the tenant sweep
    (the host store grows linearly; the device footprint does not) —
    the scaling move past the sharded topology's ~1/S shrink;
  * **throughput** — the hot path (every referenced row in a hot slot:
    one slot remap + one banked kernel call) must stay within ~10% of
    the S=8 sharded events/s at the same batch/K/N;
  * **stalls** — a 95/5 hot/cold mixed workload pages cold rows through
    the victim cache synchronously (``cold_miss_stalls``); issuing the
    engine-style ``prefetch`` for the pending window first removes the
    stalls entirely;
  * **staging off the lock** — with a background prefetch churner running,
    p99 per-dispatch latency is measured twice: ``overlap_staging=False``
    (the original defect: the host->device victim copy runs under the
    dispatch lock, so every concurrent prefetch stalls the hot path for a
    full staging copy) vs the default ``True`` (copy double-buffered
    outside the lock, swapped in under it).  The A/B lands in the JSON as
    ``p99_ms_dispatch_{locked,overlap}_staging`` / ``stall_fix_p99_speedup``.

Bitwise f32 parity vs the dense bank is asserted at the smallest tenant
count before anything is timed.  Emits
``benchmarks/results/BENCH_tiered_bank.json``.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.transforms import TransformBank
from repro.kernels import ops
from repro.serving.tiering import HostBankStore, TieredBankStore, TieringConfig

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_tiered_bank.json")
SHARDED_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "BENCH_sharded_bank.json")


def _timeit(fn, repeat=10):
    fn()                                   # warm (trace/compile)
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn()
    return (time.perf_counter() - t0) / repeat


def _monotone_rows(rng, t, n) -> np.ndarray:
    """Sorted-row quantile tables without an O(t·n log n) sort (cumsum of
    positive increments) — 10^6 rows must build in seconds, not minutes."""
    inc = rng.uniform(1e-3, 1.0, (t, n)).astype(np.float32)
    q = np.cumsum(inc, axis=1, dtype=np.float32)
    return q / q[:, -1:]


def _host_store(rng, t, k, n) -> HostBankStore:
    return HostBankStore(
        rng.uniform(0.05, 1.0, (t, k)).astype(np.float32),
        rng.uniform(0.1, 2.0, (t, k)).astype(np.float32),
        _monotone_rows(rng, t, n),
        _monotone_rows(rng, t, n))


def _stall_rate(store, rng, t, hot_ids, batch, windows, *, prefetch):
    """Fraction of mixed-workload (95% hot / 5% uniform-cold) events that
    stalled on a synchronous host->device page-in."""
    ev0 = store.metrics["events"]
    st0 = store.metrics["stalled_events"]
    for _ in range(windows):
        mix = np.where(rng.random(batch) < 0.95,
                       rng.choice(hot_ids, batch),
                       rng.integers(0, t, batch))
        raws = rng.uniform(0, 1, (batch, 4)).astype(np.float32)
        if prefetch:
            store.prefetch(mix)            # the engine's anti-stall hook
        store.dispatch(raws, mix)
    ev = store.metrics["events"] - ev0
    st = store.metrics["stalled_events"] - st0
    return st / max(ev, 1)


def _p99_dispatch_under_churn(rng, t, k, n, *, overlap, hot_cap, victim_cap,
                              batch, windows) -> tuple[float, int]:
    """p99 per-dispatch latency (ms) on the 95/5 mix while a background
    thread churns the victim cache with engine-style prefetches.

    ``overlap=False`` reproduces the original defect: ``prefetch`` holds the
    dispatch lock across the whole host->device victim copy, so every churn
    iteration stalls a concurrently-arriving dispatch for a full staging
    copy.  ``overlap=True`` builds the staged view outside the lock and only
    swaps it in under the lock.  Returns (p99_ms, staging_conflicts).
    """
    host = _host_store(rng, t, k, n)
    store = TieredBankStore(host, TieringConfig(
        hot_capacity=hot_cap, victim_capacity=victim_cap,
        overlap_staging=overlap))
    hot_ids = np.arange(hot_cap)
    store.tracker.record(hot_ids)
    store.rebalance()
    raws = rng.uniform(0, 1, (batch, k)).astype(np.float32)
    mixes = [np.where(rng.random(batch) < 0.95,
                      rng.choice(hot_ids, batch),
                      rng.integers(0, t, batch))
             for _ in range(windows)]
    # np.random.Generator is not thread-safe: pre-draw the churner's targets.
    churn = [rng.integers(0, t, 64) for _ in range(512)]
    stop = threading.Event()

    def churner():
        i = 0
        while not stop.is_set():
            store.prefetch(churn[i % len(churn)])
            i += 1

    store.dispatch(raws, mixes[0])          # warm (trace/compile) untimed
    th = threading.Thread(target=churner, daemon=True)
    th.start()
    lat = []
    try:
        for mix in mixes:
            t0 = time.perf_counter()
            store.dispatch(raws, mix)
            lat.append(time.perf_counter() - t0)
    finally:
        stop.set()
        th.join()
    return (float(np.percentile(lat, 99) * 1e3),
            int(store.metrics["staging_conflicts"]))


def _s8_baseline(rng, k, n, b, repeat) -> tuple[float, str]:
    """events/s of the S=8 sharded dispatch at the same batch/K/N —
    from its results file when present, else a dense-kernel fallback
    (the sharded bench measured S=1 within ~3% of dense on this host)."""
    if os.path.exists(SHARDED_PATH):
        with open(SHARDED_PATH) as f:
            r = json.load(f)
        if r.get("batch") == b and r.get("experts") == k \
                and r.get("knots") == n:
            row = max((x for x in r["rows"] if x["path"] == "sharded"),
                      key=lambda x: (x["tenants"], x["shards"]))
            return row["events_per_s"], \
                f"BENCH_sharded_bank S={row['shards']} t={row['tenants']}"
    t = 4096
    bank = TransformBank(
        betas=jnp.asarray(rng.uniform(0.05, 1.0, (t, k)), jnp.float32),
        weights=jnp.asarray(rng.uniform(0.1, 2.0, (t, k)), jnp.float32),
        src_quantiles=jnp.asarray(_monotone_rows(rng, t, n)),
        ref_quantiles=jnp.asarray(_monotone_rows(rng, t, n)))
    raws = jnp.asarray(rng.uniform(0, 1, (b, k)), jnp.float32)
    tid = jnp.asarray(rng.integers(0, t, b), jnp.int32)

    def call():
        return np.asarray(ops.score_pipeline_banked(
            raws, tid, bank.betas, bank.weights,
            bank.src_quantiles, bank.ref_quantiles))

    return b / _timeit(call, repeat), "dense fallback t=4096"


def run(quick: bool = False) -> dict:
    k, n = 4, 256
    b = 2048 if quick else 8192            # matches BENCH_sharded_bank
    b_mix = 1024 if quick else 2048        # ~5% cold fits the victim cache
    tenant_counts = (1_024, 10_000) if quick \
        else (1_024, 10_000, 100_000, 1_000_000)
    repeat = 3 if quick else 10
    windows = 2 if quick else 4
    # hot + victims + prior = 512 device rows = 1,064,960 bytes — byte-for-
    # byte the S=8 baseline's per-shard residency at 4096 tenants, so the
    # throughput comparison is apples-to-apples (the banked kernel's
    # one-hot gather cost scales with device-table rows)
    hot_cap, victim_cap = 384, 127
    cfg = TieringConfig(hot_capacity=hot_cap, victim_capacity=victim_cap)
    rng = np.random.default_rng(0)

    # -- bitwise parity vs the dense bank (smallest sweep point, cold path)
    t0 = tenant_counts[0]
    host = _host_store(rng, t0, k, n)
    store = TieredBankStore(host, cfg)
    raws = rng.uniform(0, 1, (1024, k)).astype(np.float32)
    tid = rng.integers(0, t0, 1024)
    got, _ = store.dispatch(raws, tid)
    dense = host.dense_bank(0)
    want = np.asarray(ops.score_pipeline_banked(
        jnp.asarray(raws), jnp.asarray(tid, jnp.int32), dense.betas,
        dense.weights, dense.src_quantiles, dense.ref_quantiles))
    parity = bool(np.array_equal(got.view(np.uint32), want.view(np.uint32)))

    base_eps, base_src = _s8_baseline(rng, k, n, b, repeat)

    rows: list[dict] = []
    for t in tenant_counts:
        host = _host_store(rng, t, k, n)
        store = TieredBankStore(host, cfg)
        hot_ids = np.arange(min(hot_cap, t))
        store.tracker.record(hot_ids)      # declare the hot working set
        store.rebalance()                  # ... and promote it
        assert len(store.hot_rows()) == len(hot_ids)

        raws = rng.uniform(0, 1, (b, k)).astype(np.float32)
        tid_hot = rng.choice(hot_ids, b)
        hot_s = _timeit(lambda: store.dispatch(raws, tid_hot), repeat)
        assert store.metrics["cold_miss_stalls"] == 0  # pure hot path

        srate = _stall_rate(store, rng, t, hot_ids, b_mix, windows,
                            prefetch=False)
        store.rebalance()                  # re-pin the hot set
        prate = _stall_rate(store, rng, t, hot_ids, b_mix, windows,
                            prefetch=True)
        rows.append({
            "tenants": t,
            "device_bytes": store.device_bytes,
            "host_bytes": store.host_bytes,
            "us_per_batch_hot": hot_s * 1e6,
            "events_per_s_hot": b / hot_s,
            "stall_rate_mixed": srate,
            "stall_rate_prefetched": prate,
        })

    # -- stall-fix A/B: p99 dispatch latency under concurrent prefetch churn
    t_churn = 10_000 if quick else 100_000
    churn_w = 40 if quick else 200
    churn_b = 512
    p99_locked, _ = _p99_dispatch_under_churn(
        rng, t_churn, k, n, overlap=False, hot_cap=hot_cap,
        victim_cap=victim_cap, batch=churn_b, windows=churn_w)
    p99_overlap, conflicts = _p99_dispatch_under_churn(
        rng, t_churn, k, n, overlap=True, hot_cap=hot_cap,
        victim_cap=victim_cap, batch=churn_b, windows=churn_w)

    t_max = tenant_counts[-1]
    last = rows[-1]
    result = {
        "batch": b, "experts": k, "knots": n,
        "hot_capacity": hot_cap, "victim_capacity": victim_cap,
        "tenant_counts": list(tenant_counts),
        "rows": rows,
        "max_tenants": t_max,
        "device_bytes": last["device_bytes"],
        "device_bytes_bounded": len({r["device_bytes"] for r in rows}) == 1,
        "host_bytes_at_max": last["host_bytes"],
        "us_per_batch_hot_at_max": last["us_per_batch_hot"],
        "events_per_s_hot_at_max": last["events_per_s_hot"],
        "baseline_events_per_s_s8": base_eps,
        "baseline_source": base_src,
        "hot_vs_s8_ratio": last["events_per_s_hot"] / base_eps,
        "stall_rate_mixed_at_max": last["stall_rate_mixed"],
        "stall_rate_prefetched_at_max": last["stall_rate_prefetched"],
        "churn_tenants": t_churn,
        "churn_batch": churn_b,
        "churn_windows": churn_w,
        "p99_ms_dispatch_locked_staging": p99_locked,
        "p99_ms_dispatch_overlap_staging": p99_overlap,
        "stall_fix_p99_speedup": p99_locked / p99_overlap,
        "staging_conflicts_overlap": conflicts,
        "stall_fix": "victim host->device copy staged OUTSIDE the dispatch "
                     "lock (double-buffered view, swapped in under the lock "
                     "iff nothing invalidated it); the locked column is the "
                     "pre-fix behavior (overlap_staging=False), measured on "
                     "the 95/5 mix with a concurrent prefetch churner",
        "bitwise_parity": parity,
    }
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    r = run()
    print(f"# wrote {RESULTS_PATH}")
    print(f"{'tenants':>9} {'device_kb':>10} {'host_mb':>9} "
          f"{'us/batch':>10} {'hot_ev/s':>10} {'stall%':>8} {'pf_stall%':>10}")
    for row in r["rows"]:
        print(f"{row['tenants']:>9} {row['device_bytes'] / 1024:>10.1f} "
              f"{row['host_bytes'] / 2**20:>9.1f} "
              f"{row['us_per_batch_hot']:>10.1f} "
              f"{row['events_per_s_hot']:>10.0f} "
              f"{row['stall_rate_mixed'] * 100:>8.2f} "
              f"{row['stall_rate_prefetched'] * 100:>10.2f}")
    print(f"# device bytes bounded: {r['device_bytes_bounded']}; "
          f"hot/s8 throughput ratio: {r['hot_vs_s8_ratio']:.2f}x "
          f"({r['baseline_source']}); bitwise_parity={r['bitwise_parity']}")
    print(f"# stall fix: p99 dispatch under churn "
          f"{r['p99_ms_dispatch_locked_staging']:.2f}ms locked -> "
          f"{r['p99_ms_dispatch_overlap_staging']:.2f}ms overlapped "
          f"({r['stall_fix_p99_speedup']:.2f}x, "
          f"{r['staging_conflicts_overlap']} staged-view conflicts)")


if __name__ == "__main__":
    main()
