"""Benchmark harness entry point — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per the harness contract, one
row per headline metric of each benchmark, then a human-readable summary.

  python -m benchmarks.run [--quick]
  python -m benchmarks.run --check-mirrors   # no benches; verify repo-root
                                             # BENCH_*.json mirrors match
                                             # benchmarks/results/
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _csv(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def _scalars(d: dict) -> dict:
    return {k: v for k, v in d.items()
            if isinstance(v, (int, float, bool, str))}


def _mirror(name: str, us_per_call: float, result: dict) -> None:
    """Mirror a benchmark's headline (scalar) metrics to a repo-root
    ``BENCH_<name>.json`` — the full row-level results stay under
    ``benchmarks/results/``; the root copy is the at-a-glance summary
    (file names match the CSV row names)."""
    payload = {"benchmark": name, "us_per_call": us_per_call,
               **_scalars(result)}
    with open(os.path.join(ROOT, f"BENCH_{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")


def check_mirrors() -> int:
    """Verify every ``benchmarks/results/BENCH_<name>.json`` has a repo-root
    mirror whose scalar metrics match it exactly.

    The two copies are written from the same in-memory result dict (the
    bench module writes results/, ``_mirror`` writes the root summary), so
    any divergence means one side was regenerated without the other —
    exactly the drift this check exists to catch.  Returns a process exit
    code (0 = consistent).
    """
    results_dir = os.path.join(ROOT, "benchmarks", "results")
    problems: list[str] = []
    checked = 0
    for fn in sorted(os.listdir(results_dir)):
        if not (fn.startswith("BENCH_") and fn.endswith(".json")):
            continue
        checked += 1
        root_path = os.path.join(ROOT, fn)
        if not os.path.exists(root_path):
            problems.append(f"{fn}: repo-root mirror missing")
            continue
        with open(os.path.join(results_dir, fn)) as f:
            full = _scalars(json.load(f))
        with open(root_path) as f:
            mirror = json.load(f)
        missing = sorted(k for k in full if k not in mirror)
        drifted = sorted(k for k in full if k in mirror and mirror[k] != full[k])
        if missing:
            problems.append(f"{fn}: mirror missing keys {missing}")
        if drifted:
            for k in drifted:
                problems.append(
                    f"{fn}: {k} results={full[k]!r} mirror={mirror[k]!r}")
    if problems:
        for p in problems:
            print(f"MIRROR DRIFT {p}", file=sys.stderr)
        return 1
    print(f"# mirrors consistent: {checked} results files checked")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller sample sizes (CI mode)")
    ap.add_argument("--check-mirrors", action="store_true",
                    help="only verify repo-root BENCH_*.json mirrors match "
                         "benchmarks/results/; run no benchmarks")
    args = ap.parse_args()
    if args.check_mirrors:
        sys.exit(check_mirrors())
    quick = args.quick

    print("name,us_per_call,derived")
    t_all = time.perf_counter()

    # ---- Table 1: expert calibration --------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_table1_calibration
    r = bench_table1_calibration.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    _csv("table1_calibration", dt,
         f"mean_expert_ece_change_pct={r['mean_expert_ece_change_pct']:.1f};"
         f"ensemble_ece_change_pct={r['ensemble_ece_change_pct']:.1f};"
         f"paper=-80_to_-98_and_-90.8")
    _mirror("table1_calibration", dt, r)

    # ---- Fig. 4: quantile transformation update ---------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_fig4_quantile_update
    r = bench_fig4_quantile_update.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    _csv("fig4_quantile_update", dt,
         f"raw_mass_first_bin={r['raw_mass_in_first_bin']:.3f};"
         f"v0_max_high_bin_err={r['v0_max_abs_rel_err_high_bins']:.2f};"
         f"v1_max_mid_bin_err={r['v1_max_abs_rel_err_mid_bins']:.3f}")
    _mirror("fig4_quantile_update", dt, r)

    # ---- fleet-wide atomic calibration refresh (separate timing row) -------
    rr = bench_fig4_quantile_update.run_refresh(quick=quick)
    _csv("fig4_fleet_refresh", rr["wall_ms_at_max"] * 1e3,
         f"tenants={rr['max_tenants']};"
         f"us_per_tenant={rr['us_per_tenant_at_max']:.1f};"
         f"atomic_generations={rr['rows'][-1]['generation']}")
    _mirror("fig4_fleet_refresh", rr["wall_ms_at_max"] * 1e3, rr)

    # ---- Fig. 6: live model update -----------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_fig6_model_update
    r = bench_fig6_model_update.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    _csv("fig6_model_update", dt,
         f"recall_p1={r['recall_p1']:.4f};recall_p2={r['recall_p2']:.4f};"
         f"monotone_recall_invariant={abs(r['recall_p1.5'] - r['recall_p2']) < 1e-9};"
         f"p15_max_err={r['p15_max_abs_err']:.2f};p2_max_err={r['p2_max_abs_err']:.2f};"
         f"alert_rate_p15={r['alert_rate_p1.5']:.4f};"
         f"alert_rate_p2={r['alert_rate_p2']:.4f};psi_p2={r['psi_p2']:.3f}")
    _mirror("fig6_model_update", dt, r)

    # ---- Fig. 5: rollout stability -----------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_fig5_rollout
    r = bench_fig5_rollout.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    _csv("fig5_rollout", dt,
         f"pod_peak={r['pod_peak']};min_ready={r['min_ready']};"
         f"p99_latency_ms={r['latency_p99_ms']:.2f};"
         f"final_version={r['final_version']}")
    _mirror("fig5_rollout", dt, r)

    # ---- Appendix A: sample-size bound -------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_appendix_a
    r = bench_appendix_a.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    worst = min(row["coverage_at_n"] for row in r["rows"])
    _csv("appendix_a_samplesize", dt,
         f"worst_coverage_at_n={worst:.3f};nominal=0.95;"
         f"rows={len(r['rows'])}")
    _mirror("appendix_a_samplesize", dt,
            {**r, "worst_coverage_at_n": worst, "nominal": 0.95})

    # ---- serving latency/throughput ----------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_serving_latency
    r = bench_serving_latency.run(quick=quick)
    dt = (time.perf_counter() - t0) * 1e6
    _csv("serving_latency", r["batch_1"]["latency_ms"] * 1e3,
         f"events_per_s_b256={r['batch_256']['events_per_s']:.0f};"
         f"transform_share_pct={r['transform_share_of_path_pct']:.2f}")
    _mirror("serving_latency", r["batch_1"]["latency_ms"] * 1e3,
            {**r, "latency_ms_b1": r["batch_1"]["latency_ms"],
             "events_per_s_b256": r["batch_256"]["events_per_s"]})

    # ---- mixed-tenant banked batch vs per-predictor loop --------------------
    from benchmarks import bench_multitenant_batch
    r = bench_multitenant_batch.run(quick=quick)
    _csv("multitenant_batch", r["us_banked"],
         f"kernel_speedup={r['kernel_speedup']:.1f}x;"
         f"events_per_s_banked={r['events_per_s_banked']:.0f};"
         f"quantile_update_speedup={r['quantile_update_speedup']:.1f}x;"
         f"max_abs_err={r['max_abs_err_vs_oracle']:.2e}")
    _mirror("multitenant_batch", r["us_banked"], r)

    # ---- tenant-sharded banks: per-shard residency + dispatch throughput ----
    from benchmarks import bench_sharded_bank
    r = bench_sharded_bank.run(quick=quick)
    _csv("sharded_bank", r["us_per_batch_smax"],
         f"tenants={r['max_tenants']};shards={r['max_shards']};"
         f"residency_ratio={r['residency_ratio_at_smax']:.3f};"
         f"throughput_ratio_s1={r['throughput_ratio_s1']:.2f}x;"
         f"bitwise_parity={r['all_bitwise_parity']}")
    _mirror("sharded_bank", r["us_per_batch_smax"], r)

    # ---- tiered bank store: bounded device residency + hot-path throughput --
    from benchmarks import bench_tiered_bank
    r = bench_tiered_bank.run(quick=quick)
    _csv("tiered_bank", r["us_per_batch_hot_at_max"],
         f"tenants={r['max_tenants']};"
         f"device_kb={r['device_bytes'] / 1024:.0f};"
         f"device_bytes_bounded={r['device_bytes_bounded']};"
         f"hot_events_per_s={r['events_per_s_hot_at_max']:.0f};"
         f"hot_vs_s8={r['hot_vs_s8_ratio']:.2f}x;"
         f"stall_rate_mixed={r['stall_rate_mixed_at_max']:.4f};"
         f"stall_rate_prefetched={r['stall_rate_prefetched_at_max']:.4f};"
         f"p99_ms_locked_staging={r['p99_ms_dispatch_locked_staging']:.2f};"
         f"p99_ms_overlap_staging={r['p99_ms_dispatch_overlap_staging']:.2f};"
         f"stall_fix_p99_speedup={r['stall_fix_p99_speedup']:.2f}x;"
         f"bitwise_parity={r['bitwise_parity']}")
    _mirror("tiered_bank", r["us_per_batch_hot_at_max"], r)

    # ---- fleet calibration: merged-fit + fenced broadcast vs fleet size -----
    from benchmarks import bench_fleet_refresh
    r = bench_fleet_refresh.run(quick=quick)
    _csv("fleet_refresh", r["wall_ms_at_max"] * 1e3,
         f"replicas={r['max_replicas']};streams={r['tenants']};"
         f"merge_ms={r['merge_ms_at_max']:.1f};"
         f"publish_ms={r['publish_ms_at_max']:.1f};"
         f"refit_ratio_max_vs_min={r['refit_ratio_max_vs_min']:.2f};"
         f"all_within_bound={r['all_within_bound']}")
    _mirror("fleet_refresh", r["wall_ms_at_max"] * 1e3, r)

    # ---- adversarial campaign: dispatch latency with full client stack on --
    from benchmarks import bench_attack_campaign
    r = bench_attack_campaign.run(quick=quick)
    _csv("attack_campaign", r["us_per_event_attack"],
         f"p99_quiet_ms={r['p99_ms_quiet']:.2f};"
         f"p99_attack_ms={r['p99_ms_attack']:.2f};"
         f"p99_ratio={r['p99_ratio_attack_vs_quiet']:.2f};"
         f"audit_us_per_event={r['audit_us_per_event']:.2f};"
         f"attack_refreshes={r['attack_refreshes']}")
    _mirror("attack_campaign", r["us_per_event_attack"], r)

    # ---- async banked dispatch engine vs synchronous ServerBatcher ----------
    from benchmarks import bench_async_engine
    r = bench_async_engine.run(quick=quick)
    _csv("async_engine", r["us_per_event_async"],
         f"speedup={r['speedup_vs_sync']:.2f}x;"
         f"speedup_fixed_windows={r['speedup_fixed_vs_sync']:.2f}x;"
         f"events_per_s_async={r['events_per_s_async']:.0f};"
         f"events_per_s_sync={r['events_per_s_sync']:.0f};"
         f"tracking_on_off_ratio={r['tracking_on_off_ratio']:.2f};"
         f"events_per_s_track_on={r['events_per_s_track_on']:.0f};"
         f"events_per_s_track_off={r['events_per_s_track_off']:.0f};"
         f"tenants={r['tenants']};max_abs_err={r['max_abs_err']:.2e}")
    _mirror("async_engine", r["us_per_event_async"], r)

    # ---- kernels -------------------------------------------------------------
    t0 = time.perf_counter()
    from benchmarks import bench_kernels
    r = bench_kernels.run(quick=quick)
    for name, row in r.items():
        derived = f"allclose={row.get('kernel_allclose', True)}"
        if "skip_rate_sorted" in row:
            derived += (f";skip_rate_sorted={row['skip_rate_sorted']:.2f}"
                        f";skip_rate_adversarial="
                        f"{row['skip_rate_adversarial']:.2f}")
        _csv(f"kernel_{name}", row["us_per_call"], derived)
    with open(os.path.join(ROOT, "BENCH_kernels.json"), "w") as f:
        json.dump({"benchmark": "kernels",
                   **{name: _scalars(row) for name, row in r.items()}},
                  f, indent=1, sort_keys=True)
        f.write("\n")

    print(f"\n# total bench time: {time.perf_counter() - t_all:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
