"""The full Fig.-3 model lifecycle, end to end:

  train experts -> deploy {m1,m2} live -> deploy {m1,m2,m3} in SHADOW ->
  validate on live traffic (distribution alignment + discriminative power)
  -> refresh T^Q for the candidate -> rolling promotion -> decommission.

Everything happens server-side; the "client" sends the same intent from the
first request to the last.

  PYTHONPATH=src python examples/model_update_lifecycle.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core.metrics import bin_relative_error, recall_at_fpr
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule, ShadowRule
from repro.experiments.fraud_world import DIM, FraudWorld, train_expert
from repro.serving.rollout import Replica, ReplicaSet, RollingUpdate
from repro.serving.server import MuseServer
from repro.serving.types import ScoringRequest
from repro.training.data import FraudEventStream, TenantProfile

OLD, NEW = ("m1", "m2"), ("m1", "m2", "m3")

world = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=11)
world.client = FraudEventStream(
    TenantProfile("train-pool", fraud_rate=0.008, feature_shift=0.3, seed=77))
world.experts["m3"] = train_expert(
    FraudEventStream(TenantProfile("train-pool", fraud_rate=0.01,
                                   feature_shift=0.3, seed=78)),
    "m3", beta=0.02, mask_seed=5)

# ---- 1. live {m1,m2} + shadow {m1,m2,m3} ---------------------------------
x_hist, _ = world.client.sample(60_000)
qm_v1 = world.custom_quantile_map(OLD, x_hist)
table = RoutingTable(
    (ScoringRule(Condition(tenants=("bank1",)), "p1"),
     ScoringRule(Condition(), "p1")),
    (ShadowRule(Condition(tenants=("bank1",)), ("p2-candidate",)),),
    version="v1",
)
server = MuseServer(table)
server.deploy(world.predictor_spec("p1", OLD, qm_v1), world.model_factories())
server.deploy(world.predictor_spec("p2-candidate", NEW, qm_v1),
              world.model_factories())
print(f"[deploy] 2 predictors, {server.pool.provision_events} models "
      "provisioned (m1,m2 shared; only m3 new)")

# ---- 2. live traffic; shadow records accumulate ---------------------------
x_live, y_live = world.client.sample(40_000)
for i in range(0, len(x_live), 512):
    reqs = [ScoringRequest(intent=Intent(tenant="bank1"), features=f)
            for f in x_live[i : i + 512].astype(np.float32)]
    server.score_batch(reqs)
print(f"[shadow] {len(server.sink)} candidate evaluations recorded")

# ---- 3. offline validation from the data lake -----------------------------
shadow_raw = server.sink.raw_aggregated_scores("p2-candidate", "bank1")
qm_v2 = world.custom_quantile_map(NEW, x_live)  # refreshed transformation
cand_scores = np.asarray(qm_v2(jnp.asarray(
    world.ensemble_aggregated(NEW, x_live), jnp.float32)))
live_scores = np.asarray(qm_v1(jnp.asarray(
    world.ensemble_aggregated(OLD, x_live), jnp.float32)))
err_cand = bin_relative_error(cand_scores, world.ref_quantiles)["rel_err"]
r_old = recall_at_fpr(live_scores, y_live, 0.01)
r_new = recall_at_fpr(cand_scores, y_live, 0.01)
print(f"[validate] candidate max |bin err| = {np.nanmax(np.abs(err_cand)):.2%};"
      f" recall@1%FPR {r_old:.3f} -> {r_new:.3f}")

# ---- 4. rolling promotion (surge 1, maxUnavailable 0) ----------------------
def make_v2_server():
    s = MuseServer(RoutingTable(
        (ScoringRule(Condition(), "p2"),), version="v2"))
    s.deploy(world.predictor_spec("p2", NEW, qm_v2), world.model_factories())
    return s

replicas = [Replica(i, server, "v1", ready=True) for i in range(2)]
rs = ReplicaSet(replicas)
update = RollingUpdate(rs, make_v2_server, "v2", schema_dim=DIM,
                       warmup_batch_sizes=(16,))

def traffic():
    rng = np.random.default_rng(1)
    while True:
        yield [ScoringRequest(intent=Intent(tenant="bank1"),
                              features=rng.normal(0, 1, DIM).astype(np.float32))
               for _ in range(16)]

timeline = update.run_with_traffic(traffic(), batches_per_transition=3)
print(f"[rollout] pods {min(t['pod_count'] for t in timeline)}->"
      f"{max(t['pod_count'] for t in timeline)}->{timeline[-1]['pod_count']}, "
      f"min ready={min(t['ready_count'] for t in timeline)}, "
      f"final version={timeline[-1]['version']}")
print("[done] client intent never changed; v1 decommissioned")
