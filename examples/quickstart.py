"""Quickstart: the MUSE core in five minutes.

Builds two tiny expert models, composes the paper's Eq.-2 predictor
(posterior correction -> aggregation -> quantile mapping), routes an intent
to it, and performs a zero-downtime transformation swap.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    Intent, ModelPool, PredictorSpec, QuantileMap, RoutingTable,
)
from repro.core.routing import Condition, ScoringRule, ShadowRule
from repro.core.transforms import fraud_reference_quantiles
from repro.serving.server import MuseServer
from repro.serving.types import ScoringRequest

rng = np.random.default_rng(0)
DIM = 8

# -- 1. two "expert models" (stand-ins for anything that scores) -----------
w1, w2 = rng.normal(0, 1, DIM), rng.normal(0, 1, DIM)
m1 = lambda x: jnp.asarray(1 / (1 + np.exp(-(np.asarray(x) @ w1))))
m2 = lambda x: jnp.asarray(1 / (1 + np.exp(-(np.asarray(x) @ w2))))

# -- 2. routing: clients send intents, never model names -------------------
table = RoutingTable(
    scoring_rules=(
        ScoringRule(Condition(tenants=("bank1",)), "bank1-predictor-v1",
                    description="Custom DAG for bank1"),
        ScoringRule(Condition(), "global-predictor", description="catch-all"),
    ),
    shadow_rules=(
        ShadowRule(Condition(tenants=("bank1",)), ("bank1-predictor-v2",),
                   description="evaluate v2 in shadow"),
    ),
    version="v1",
)
server = MuseServer(table)

# -- 3. predictors: ensemble with per-expert posterior correction ----------
ref_q = fraud_reference_quantiles(128)          # the stable reference R
qm = QuantileMap(jnp.linspace(0, 1, 128), ref_q)
factories = {"m1": lambda: m1, "m2": lambda: m2}

server.deploy(PredictorSpec(
    "bank1-predictor-v1", ("m1", "m2"),
    betas=(0.18, 0.02),          # each expert's training undersampling ratio
    weights=(1.0, 1.0), quantile_map=qm,
), factories)
server.deploy(PredictorSpec.single("global-predictor", "m1", qm), factories)
server.deploy(PredictorSpec(
    "bank1-predictor-v2", ("m1", "m2"), (0.18, 0.02), (1.0, 3.0), qm,
), factories)
print(f"models provisioned: {server.pool.provision_events} "
      "(3 predictors share 2 physical models)")

# -- 4. score: live + shadow ------------------------------------------------
req = ScoringRequest(intent=Intent(tenant="bank1"),
                     features=rng.normal(0, 1, DIM).astype(np.float32))
resp = server.score(req)
print(f"live score via {resp.predictor}: {resp.score:.4f} "
      f"(raw expert scores: {[round(s, 3) for s in resp.raw_scores]})")
print(f"shadow records written: {len(server.sink)}")

# -- 5. seamless update: swap T^Q without touching models -------------------
new_qm = QuantileMap(jnp.linspace(0, 1, 128), jnp.linspace(0, 1, 128) ** 2)
server.swap_transformation("bank1-predictor-v1", new_qm)
resp2 = server.score(req)
print(f"after T^Q swap (no model re-provisioning): {resp2.score:.4f}")

# -- 6. transparent model switching: one routing-table update ---------------
server.publish_routing(table.with_rule_update(
    "bank1-predictor-v1", "bank1-predictor-v2", version="v2"))
resp3 = server.score(req)
print(f"after promotion, same intent now served by {resp3.predictor} "
      f"(routing {resp3.routing_version}): {resp3.score:.4f}")
