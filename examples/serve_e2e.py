"""End-to-end serving driver (the paper's kind: Score-as-a-Service).

Serves REAL transformer experts (reduced same-family configs from the
assigned pool) behind the full MUSE stack with batched requests:

  token events -> intent routing -> predictor (2-transformer ensemble,
  T^C -> A -> T^Q) -> business-ready scores,  with shadow scoring of a
  candidate 3-model ensemble, streaming quantile tracking, an Eq.-5
  readiness gate, and a live calibration refresh — the full model
  lifecycle of Fig. 3, no client changes anywhere.

  PYTHONPATH=src python examples/serve_e2e.py [--batches 30] [--batch 32]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule, ShadowRule
from repro.core.predictor import PredictorSpec
from repro.core.transforms import QuantileMap, fraud_reference_quantiles
from repro.models.model import Model
from repro.serving.server import MuseServer, ServerConfig
from repro.serving.types import ScoringRequest


def make_transformer_expert(arch: str, seed: int, seq_len: int = 32):
    """A real transformer with a risk-score head, jit-compiled for serving.

    Features arriving from the client are hashed into token ids — the
    'schema' of this toy deployment.
    """
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))

    @jax.jit
    def scorer(tokens):
        out = model.forward(params, tokens=tokens, logits_mode="last")
        return out.risk_score

    vocab = cfg.vocab_size

    def score_fn(features):
        feats = np.asarray(features, np.float32)
        tokens = (np.abs(feats[..., :seq_len] * 1000).astype(np.int64) % vocab)
        if tokens.shape[-1] < seq_len:
            tokens = np.pad(tokens, ((0, 0), (0, seq_len - tokens.shape[-1])))
        return scorer(jnp.asarray(tokens, jnp.int32))

    return score_fn, cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=30)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    dim = 32
    ref_q = fraud_reference_quantiles(128)
    qm0 = QuantileMap(jnp.linspace(0, 1, 128), ref_q)

    table = RoutingTable(
        scoring_rules=(
            ScoringRule(Condition(tenants=("bank1",)), "bank1-ensemble-v1"),
            ScoringRule(Condition(), "global-v1"),
        ),
        shadow_rules=(
            ShadowRule(Condition(tenants=("bank1",)), ("bank1-ensemble-v2",)),
        ),
        version="v1",
    )
    server = MuseServer(table, ServerConfig(
        refresh_alert_rate=0.05, refresh_rel_error=0.5))

    factories = {
        "internlm2-expert": lambda: make_transformer_expert("internlm2-1.8b", 0)[0],
        "qwen3-expert": lambda: make_transformer_expert("qwen3-8b", 1)[0],
        "olmoe-expert": lambda: make_transformer_expert("olmoe-1b-7b", 2)[0],
    }
    t0 = time.perf_counter()
    server.deploy(PredictorSpec(
        "bank1-ensemble-v1", ("internlm2-expert", "qwen3-expert"),
        betas=(0.18, 0.18), weights=(1.0, 1.0), quantile_map=qm0,
    ), factories)
    server.deploy(PredictorSpec.single("global-v1", "internlm2-expert", qm0),
                  factories)
    # candidate: adds an MoE expert — dedup provisions only the new model
    server.deploy(PredictorSpec(
        "bank1-ensemble-v2",
        ("internlm2-expert", "qwen3-expert", "olmoe-expert"),
        betas=(0.18, 0.18, 0.02), weights=(1.0, 1.0, 1.0), quantile_map=qm0,
    ), factories)
    print(f"deployed 3 predictors over {server.pool.provision_events} physical "
          f"models in {time.perf_counter() - t0:.1f}s "
          "(ensemble-v2 provisioned only the MoE expert)")

    from repro.serving.warmup import warm_up
    t0 = time.perf_counter()
    # warm every batch shape the tenant-grouping can produce (the paper's
    # point: a replica must never compile on live traffic)
    warm_up(server, dim, batch_sizes=(1, args.batch // 4, args.batch // 2,
                                      args.batch))
    print(f"warm-up (XLA compile of every predictor at serving shapes): "
          f"{time.perf_counter() - t0:.1f}s")

    rng = np.random.default_rng(0)
    tenants = ["bank1", "bank1", "bank2", "fintechX"]
    lat = []
    t0 = time.perf_counter()
    for i in range(args.batches):
        reqs = [
            ScoringRequest(
                intent=Intent(tenant=tenants[j % len(tenants)]),
                features=rng.normal(0, 1, dim).astype(np.float32),
            )
            for j in range(args.batch)
        ]
        t1 = time.perf_counter()
        resps = server.score_batch(reqs)
        lat.append((time.perf_counter() - t1) * 1e3)
        assert all(0.0 <= r.score <= 1.0 for r in resps)
    total = args.batches * args.batch
    dt = time.perf_counter() - t0
    print(f"served {total} events in {dt:.2f}s "
          f"({total / dt:.0f} events/s); latency p50={np.percentile(lat, 50):.1f}ms "
          f"p99={np.percentile(lat, 99):.1f}ms per batch of {args.batch}")
    print(f"shadow evaluations recorded: {len(server.sink)} "
          f"(candidate ensemble scored on live bank1 traffic)")

    # calibration refresh once the Eq.-5 gate opens
    ready = server.calibration_ready("bank1", "bank1-ensemble-v1")
    print(f"calibration refresh gate (Eq. 5) open: {ready}")
    if ready:
        qm1 = server.fit_custom_quantile_map("bank1", "bank1-ensemble-v1",
                                             np.asarray(ref_q))
        server.swap_transformation("bank1-ensemble-v1", qm1)
        r = server.score(ScoringRequest(
            intent=Intent(tenant="bank1"),
            features=rng.normal(0, 1, dim).astype(np.float32)))
        print(f"after live T^Q refresh: score={r.score:.4f} via {r.predictor}")

    # promote the shadow candidate — pure routing change
    server.publish_routing(server.routing.with_rule_update(
        "bank1-ensemble-v1", "bank1-ensemble-v2", "v2"))
    r = server.score(ScoringRequest(
        intent=Intent(tenant="bank1"),
        features=rng.normal(0, 1, dim).astype(np.float32)))
    print(f"after promotion: bank1 served by {r.predictor} "
          f"(routing {r.routing_version}) — client unchanged")


if __name__ == "__main__":
    main()
