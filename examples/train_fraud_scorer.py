"""Training driver: train a transformer risk-scorer end to end on CPU.

Trains a reduced-family architecture from the assigned pool (selectable via
--arch) on the synthetic token stream with the full substrate: AdamW +
cosine schedule, remat, checkpointing, resume. Defaults are sized for
minutes on CPU; --layers/--d-model scale it up (the same code lowers onto
the 256-chip mesh via repro.launch.train semantics).

  PYTHONPATH=src python examples/train_fraud_scorer.py --steps 200
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.training.checkpoint import latest_step, restore_checkpoint
from repro.training.data import TokenStream
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--layers", type=int, default=0, help="override depth")
    ap.add_argument("--d-model", type=int, default=0, help="override width")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    overrides = {}
    if args.layers:
        overrides["n_layers"] = args.layers
    if args.d_model:
        overrides["d_model"] = args.d_model
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = Model(cfg)
    print(f"arch={cfg.name}  params~{cfg.param_count()/1e6:.1f}M  "
          f"steps={args.steps}")

    opt = AdamW(learning_rate=cosine_schedule(args.lr, warmup_steps=20,
                                              total_steps=args.steps))
    trainer = Trainer(model, opt, remat=True, compute_dtype=jnp.float32,
                      checkpoint_dir=args.ckpt_dir,
                      checkpoint_every=max(args.steps // 2, 1))
    state = trainer.init_state(jax.random.key(0))

    resume = latest_step(args.ckpt_dir)
    if resume:
        state = state._replace(params=restore_checkpoint(
            args.ckpt_dir, resume, state.params))
        print(f"resumed params from checkpoint step {resume}")

    stream = iter(TokenStream(cfg.vocab_size, args.seq, args.batch))
    state, history = trainer.fit(state, stream, num_steps=args.steps,
                                 log_every=max(args.steps // 10, 1))
    print(f"\nloss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"({history[-1]['elapsed_s']:.0f}s); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
