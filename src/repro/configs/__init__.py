"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Every entry cites its source paper / model card; smoke variants are reduced
same-family configs (2 layers, d_model <= 512, <= 4 experts) for CPU tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig
from repro.configs.shapes import SHAPES, InputShape  # re-export

_MODULES = {
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "llama3-405b": "repro.configs.llama3_405b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
}

ARCH_IDS: tuple[str, ...] = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).config()


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch]).smoke_config()


def applicable_shapes(arch: str) -> tuple[str, ...]:
    """Which of the four assigned shapes run for this architecture.

    Skips (recorded in DESIGN.md §4):
      * encoder-only (hubert): no decode step -> decode_32k, long_500k skipped.
      * long_500k needs sub-quadratic attention: SSM/hybrid run natively;
        dense/MoE/VLM decoders run it via the sliding-window variant (we
        implement it, so they are NOT skipped).
    """
    cfg = get_config(arch)
    if cfg.is_encoder_only:
        return ("train_4k", "prefill_32k")
    return ("train_4k", "prefill_32k", "decode_32k", "long_500k")
