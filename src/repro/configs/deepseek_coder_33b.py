"""deepseek-coder-33b — dense GQA decoder, llama-arch [arXiv:2401.14196]."""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "deepseek-coder-33b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=62,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=19200,
        vocab_size=32256,
        head_dim=128,
        rope_theta=100_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2401.14196",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=224,
        n_heads=7,
        n_kv_heads=1,
        d_ff=448,
        vocab_size=384,
        head_dim=32,
        rope_theta=100_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2401.14196",
    )
