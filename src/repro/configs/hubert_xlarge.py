"""hubert-xlarge — audio encoder-only backbone [arXiv:2106.07447].

The mel-spectrogram + conv feature extractor is a stub frontend: frame
embeddings (B, T, 1280) arrive precomputed.  Encoder-only (bidirectional,
non-causal) — no decode step, so decode_32k / long_500k are skipped for this
architecture (recorded in DESIGN.md §4).  The LM head predicts the 504
discrete HuBERT cluster units per frame (masked prediction objective).
"""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        d_ff=5120,
        vocab_size=504,
        head_dim=80,
        causal=False,
        embeds_input=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2106.07447",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="audio",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=4,
        d_ff=512,
        vocab_size=104,
        head_dim=64,
        causal=False,
        embeds_input=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2106.07447",
    )
