"""internlm2-1.8b — dense GQA decoder [arXiv:2403.17297]."""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "internlm2-1.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=92544,
        head_dim=128,
        rope_theta=1_000_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2403.17297",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        rope_theta=1_000_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2403.17297",
    )
