"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887].

Block structure (period 8): attention at index 4 of each 8-layer block
(1 attn : 7 mamba), MoE replacing the dense MLP on every other layer.
"""
from repro.models.config import BlockSpec, MambaConfig, ModelConfig, MoEConfig

ARCH_ID = "jamba-1.5-large-398b"

_PATTERN = tuple(
    BlockSpec(
        mixer=("attn" if i == 4 else "mamba"),
        ffn=("moe" if i % 2 == 1 else "mlp"),
    )
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        head_dim=128,
        rope_theta=10_000.0,
        layer_pattern=_PATTERN,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                      capacity_factor=1.25),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        source="arXiv:2403.19887",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="hybrid",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        layer_pattern=(BlockSpec("mamba", "mlp"), BlockSpec("attn", "moe")),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256,
                      capacity_factor=2.0),  # = E/top_k: drop-free for tests
        mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
        source="arXiv:2403.19887",
    )
