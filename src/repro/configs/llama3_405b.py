"""llama3-405b — dense GQA decoder, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "llama3-405b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_ff=53248,
        vocab_size=128256,
        head_dim=128,
        rope_theta=500_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2407.21783",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        n_heads=8,
        n_kv_heads=2,
        d_ff=768,
        vocab_size=640,
        head_dim=32,
        rope_theta=500_000.0,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2407.21783",
    )
