"""llama4-maverick-400b-a17b — MoE 128e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

MoE interleaved every other layer (dense SwiGLU otherwise), one shared
expert always active on MoE layers.  The early-fusion multimodal frontend is
stubbed like the VLM configs (text path exercised; embeds accepted directly).
"""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig

ARCH_ID = "llama4-maverick-400b-a17b"

_PATTERN = (BlockSpec("attn", "mlp"), BlockSpec("attn", "moe"))


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        head_dim=128,
        rope_theta=500_000.0,
        layer_pattern=_PATTERN,
        moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                      capacity_factor=1.25, shared_expert=True,
                      d_ff_shared=8192),
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="moe",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        layer_pattern=(BlockSpec("attn", "mlp"), BlockSpec("attn", "moe")),
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=256,
                      capacity_factor=4.0, shared_expert=True,  # E/top_k: drop-free
                      d_ff_shared=256),
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
    )
