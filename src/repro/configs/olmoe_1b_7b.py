"""olmoe-1b-7b — MoE decoder, 64 experts top-8 [arXiv:2409.02060]."""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig

ARCH_ID = "olmoe-1b-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        head_dim=128,
        rope_theta=10_000.0,
        qk_norm=True,  # OLMoE uses QK-Norm
        layer_pattern=(BlockSpec("attn", "moe"),),
        moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024,
                      capacity_factor=1.25),
        source="arXiv:2409.02060",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=512,
        head_dim=32,
        qk_norm=True,
        layer_pattern=(BlockSpec("attn", "moe"),),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=2.0),  # = E/top_k: drop-free for tests
        source="arXiv:2409.02060",
    )
