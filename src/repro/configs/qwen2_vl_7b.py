"""qwen2-vl-7b — VLM language backbone with M-RoPE [arXiv:2409.12191].

The ViT vision encoder + projector are a stub frontend (DESIGN.md §4):
``input_specs()`` supplies fused patch/text embeddings of shape (B, T, d);
the backbone implements M-RoPE (t/h/w rotary sections) and dynamic-resolution
semantics via explicit (3, B, T) position ids.
"""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "qwen2-vl-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        head_dim=128,
        rope_theta=1_000_000.0,
        mrope=True,
        mrope_sections=(16, 24, 24),
        embeds_input=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2409.12191",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="vlm",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        mrope=True,
        mrope_sections=(8, 12, 12),
        embeds_input=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="arXiv:2409.12191",
    )
