"""qwen3-8b — dense GQA decoder with per-head QK-Norm [hf:Qwen/Qwen3-8B]."""
from repro.models.config import BlockSpec, ModelConfig

ARCH_ID = "qwen3-8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab_size=151936,
        head_dim=128,
        rope_theta=1_000_000.0,
        qk_norm=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="hf:Qwen/Qwen3-8B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="dense",
        n_layers=2,
        d_model=256,
        n_heads=4,
        n_kv_heads=2,
        d_ff=512,
        vocab_size=512,
        head_dim=64,
        qk_norm=True,
        layer_pattern=(BlockSpec("attn", "mlp"),),
        source="hf:Qwen/Qwen3-8B",
    )
