"""Assigned input shapes (public pool) + shape-kind semantics.

  train_4k     — training step          (seq 4,096,   global batch 256)
  prefill_32k  — inference prefill      (seq 32,768,  global batch 32)
  decode_32k   — inference decode: ONE new token, KV cache of seq_len
                 (seq 32,768, global batch 128)
  long_500k    — long-context decode    (seq 524,288, global batch 1);
                 requires sub-quadratic attention: native for SSM/hybrid,
                 sliding-window variant for dense decoders, skipped for
                 encoder-only models.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

ShapeKind = Literal["train", "prefill", "decode"]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: ShapeKind


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: dict[str, InputShape] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}
