"""xlstm-1.3b — sLSTM + mLSTM blocks (xLSTM[7:1]) [arXiv:2405.04517].

Pattern period 8: one sLSTM block followed by seven mLSTM blocks; no separate
FFN (the xLSTM blocks carry their own up/down projections, hence d_ff = 0).
"""
from repro.models.config import BlockSpec, ModelConfig, XLSTMConfig

ARCH_ID = "xlstm-1.3b"

_PATTERN = tuple(
    BlockSpec(mixer=("slstm" if i == 0 else "mlstm"), ffn="none")
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        head_dim=512,
        layer_pattern=_PATTERN,
        xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
                          chunk_size=128),
        source="arXiv:2405.04517",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke",
        arch_type="ssm",
        n_layers=2,
        d_model=128,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        head_dim=64,
        layer_pattern=(BlockSpec("slstm", "none"), BlockSpec("mlstm", "none")),
        xlstm=XLSTMConfig(mlstm_proj_factor=2.0, slstm_proj_factor=4.0 / 3.0,
                          chunk_size=32),
        source="arXiv:2405.04517",
    )
