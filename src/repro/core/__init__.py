"""MUSE core: the paper's primary contribution as composable JAX modules.

Sub-modules:
  transforms  — T^C (posterior correction), A (aggregation), T^Q (quantile map)
  coldstart   — Beta-mixture default transformation (Sec. 2.4)
  quantiles   — quantile estimation + Appendix-A sample-size bound
  predictor   — the p = <M, A, T^Q> abstraction (Eq. 2)
  routing     — intent-based routing tables (Sec. 2.5)
  registry    — deduplicated model pool (Sec. 2.2.1)
  metrics     — ECE_SWEEP^EM, Brier, recall@FPR, Wilson intervals
"""
from repro.core.transforms import (
    Aggregation,
    PosteriorCorrection,
    QuantileMap,
    ShardedTransformBank,
    TENANT_AXIS,
    TransformBank,
    banked_score_pipeline,
    posterior_correction,
    quantile_map,
    score_pipeline,
)
from repro.core.predictor import Predictor, PredictorSpec, TransformPipeline, deploy_predictor
from repro.core.routing import Condition, Intent, Resolution, RoutingTable, ScoringRule, ShadowRule
from repro.core.registry import ModelPool

__all__ = [
    "Aggregation", "PosteriorCorrection", "QuantileMap",
    "ShardedTransformBank", "TENANT_AXIS", "TransformBank",
    "banked_score_pipeline", "posterior_correction", "quantile_map",
    "score_pipeline",
    "Predictor", "PredictorSpec", "TransformPipeline", "deploy_predictor",
    "Condition", "Intent", "Resolution", "RoutingTable", "ScoringRule", "ShadowRule",
    "ModelPool",
]
