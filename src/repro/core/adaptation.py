"""Label-based ensemble adaptation (paper Sec. 2.3.2 + roadmap item 2).

Sec. 2.3.2: "this design enables a lightweight but effective form of model
adaptation ... MUSE supports rapid, low-cost optimization of ensemble
behavior once labeled data becomes available, while preserving the benefits
of expert reuse."  The paper leaves the fitting procedure unspecified and
names *generalized posterior correction* as future work; both are
implemented here:

* :func:`fit_aggregation_weights` — convex log-loss fit of the aggregation
  weights over posterior-corrected expert scores (simplex-constrained so the
  aggregate stays a probability), mirroring the paper's weighted average.
* :func:`generalized_correction_betas` — per-expert *effective* beta fit to
  labeled data: instead of trusting the recorded undersampling ratio, find
  the beta whose posterior correction minimizes the expert's log loss
  (handles experts whose bias deviates from the nominal ratio — e.g. drifted
  deployments), the paper's "dynamically balance the experts ... based not
  only on the undersampling rate".
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.transforms import posterior_correction


def _log_loss(p: jnp.ndarray, y: jnp.ndarray, eps: float = 1e-7) -> jnp.ndarray:
    p = jnp.clip(p, eps, 1 - eps)
    return -jnp.mean(y * jnp.log(p) + (1 - y) * jnp.log1p(-p))


def fit_aggregation_weights(
    corrected_scores: np.ndarray,
    labels: np.ndarray,
    *,
    steps: int = 400,
    lr: float = 0.5,
) -> np.ndarray:
    """Fit simplex weights w minimizing log loss of  w · scores.

    ``corrected_scores``: (n, K) posterior-corrected expert scores.
    Parameterized through a softmax so the constraint w >= 0, sum w = 1 is
    structural; optimized by full-batch gradient descent (closed, convex-ish
    problem at MUSE's K <= 10 scale — sub-second).
    """
    s = jnp.asarray(corrected_scores, jnp.float32)
    y = jnp.asarray(labels, jnp.float32)
    k = s.shape[-1]

    def loss(theta):
        w = jax.nn.softmax(theta)
        return _log_loss(s @ w, y)

    grad = jax.jit(jax.grad(loss))
    theta = jnp.zeros((k,))
    for _ in range(steps):
        theta = theta - lr * grad(theta)
    return np.asarray(jax.nn.softmax(theta))


def generalized_correction_betas(
    raw_scores: np.ndarray,
    labels: np.ndarray,
    *,
    nominal_betas: np.ndarray | None = None,
    steps: int = 300,
    lr: float = 0.3,
) -> np.ndarray:
    """Per-expert effective undersampling ratio from labeled data.

    Optimizes log-beta (positivity structural) of Eq. 3 per expert by log
    loss.  With perfectly recorded training ratios this recovers them; when
    an expert's real-world bias drifts, the fitted beta compensates.
    """
    s = jnp.asarray(raw_scores, jnp.float32)
    y = jnp.asarray(labels, jnp.float32)
    k = s.shape[-1]
    init = (np.log(nominal_betas) if nominal_betas is not None
            else np.zeros(k))
    log_beta = jnp.asarray(init, jnp.float32)

    def loss(lb):
        beta = jnp.exp(lb)
        corrected = posterior_correction(s, beta[None, :])
        # independent per-expert losses, summed (no cross terms)
        return sum(_log_loss(corrected[:, i], y) for i in range(k))

    grad = jax.jit(jax.grad(loss))
    for _ in range(steps):
        log_beta = log_beta - lr * grad(log_beta)
    return np.asarray(jnp.clip(jnp.exp(log_beta), 1e-4, 1.0))
