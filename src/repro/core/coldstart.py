"""Cold-start default transformation (paper Sec. 2.4).

With no client history, the source score distribution S is replaced by a
smooth bimodal Beta mixture fit to the predictor's score distribution on its
experts' combined *training* data:

    f_S(y) = (1-w)·Beta(y; a0, b0) + w·Beta(y; a1, b1)        (Eq. 6)

Shape parameters minimize the moment-matching loss

    L = sum_{r=1..4} ((mu_r - ybar_r)^2)^(1/r)                 (Eq. 7)

via a stochastic search (differential evolution, Storn & Price — the paper's
citation [40]); the best of N_trial runs by Jensen–Shannon divergence against
the empirical distribution is kept (Eq. 8).  The fitted mixture's CDF then
yields the default source quantiles for ``T^Q_{v0}``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.transforms import QuantileMap

import jax.numpy as jnp

# scipy is an OFFLINE-fitting dependency only: serving-only deployments (and
# the tier-1 test lane) import this module for BetaMixtureFit / the fitted
# prior's quantiles — pure numpy — without ever touching the DE optimizer.
# The import is therefore lazy, guarded inside the functions that fit or
# evaluate the mixture densities.


def _scipy_stats():
    from scipy import stats  # lazy: offline fitting path only

    return stats


def beta_mixture_pdf(y: np.ndarray, w: float, a0: float, b0: float,
                     a1: float, b1: float) -> np.ndarray:
    stats = _scipy_stats()
    return (1.0 - w) * stats.beta.pdf(y, a0, b0) + w * stats.beta.pdf(y, a1, b1)


def beta_mixture_cdf(y: np.ndarray, w: float, a0: float, b0: float,
                     a1: float, b1: float) -> np.ndarray:
    stats = _scipy_stats()
    return (1.0 - w) * stats.beta.cdf(y, a0, b0) + w * stats.beta.cdf(y, a1, b1)


def _beta_raw_moment(a: float | np.ndarray, b: float | np.ndarray, r: int):
    """E[X^r] for Beta(a,b) = prod_{j<r} (a+j)/(a+b+j)."""
    m = 1.0
    for j in range(r):
        m = m * (a + j) / (a + b + j)
    return m


def mixture_raw_moments(w: float, a0, b0, a1, b1, r_max: int = 4) -> np.ndarray:
    return np.array(
        [
            (1.0 - w) * _beta_raw_moment(a0, b0, r) + w * _beta_raw_moment(a1, b1, r)
            for r in range(1, r_max + 1)
        ]
    )


def moment_loss(params: np.ndarray, w: float, empirical_moments: np.ndarray) -> float:
    """Eq. 7 — r-th-rooted squared moment discrepancies, summed over r=1..4."""
    a0, b0, a1, b1 = params
    mu = mixture_raw_moments(w, a0, b0, a1, b1, r_max=len(empirical_moments))
    total = 0.0
    for r, (m, e) in enumerate(zip(mu, empirical_moments), start=1):
        total += float(((m - e) ** 2) ** (1.0 / r))
    return total


def jensen_shannon_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """JSD between two discrete distributions (natural log)."""
    p = np.asarray(p, dtype=np.float64) + eps
    q = np.asarray(q, dtype=np.float64) + eps
    p /= p.sum()
    q /= q.sum()
    m = 0.5 * (p + q)
    kl_pm = np.sum(p * np.log(p / m))
    kl_qm = np.sum(q * np.log(q / m))
    return float(0.5 * kl_pm + 0.5 * kl_qm)


@dataclasses.dataclass(frozen=True)
class BetaMixtureFit:
    w: float
    a0: float
    b0: float
    a1: float
    b1: float
    jsd: float
    moment_loss: float

    def pdf(self, y: np.ndarray) -> np.ndarray:
        return beta_mixture_pdf(y, self.w, self.a0, self.b0, self.a1, self.b1)

    def cdf(self, y: np.ndarray) -> np.ndarray:
        return beta_mixture_cdf(y, self.w, self.a0, self.b0, self.a1, self.b1)

    def quantiles(self, levels: np.ndarray) -> np.ndarray:
        """Invert the mixture CDF numerically on a dense grid."""
        grid = np.linspace(1e-6, 1.0 - 1e-6, 65537)
        cdf = self.cdf(grid)
        cdf = np.maximum.accumulate(cdf)
        q = np.interp(np.asarray(levels), cdf, grid, left=0.0, right=1.0)
        return np.maximum.accumulate(q)


def fit_beta_mixture(
    train_scores: np.ndarray,
    fraud_prior: float,
    *,
    n_trials: int = 4,
    n_bins: int = 64,
    seed: int = 0,
    maxiter: int = 200,
) -> BetaMixtureFit:
    """Eqs. 6–8: DE moment-matching, best-of-N_trial by JSD vs empirical hist.

    ``fraud_prior`` is w = P(y=1) on the combined training data; the two Beta
    components approximate the class-conditional densities.
    """
    from scipy import optimize  # lazy: offline fitting path only

    y = np.clip(np.asarray(train_scores, dtype=np.float64).ravel(), 1e-6, 1 - 1e-6)
    emp_moments = np.array([np.mean(y**r) for r in range(1, 5)])
    hist, edges = np.histogram(y, bins=n_bins, range=(0.0, 1.0), density=True)
    centers = 0.5 * (edges[:-1] + edges[1:])

    bounds = [(0.05, 200.0)] * 4
    best: BetaMixtureFit | None = None
    for trial in range(n_trials):
        res = optimize.differential_evolution(
            moment_loss,
            bounds=bounds,
            args=(fraud_prior, emp_moments),
            seed=seed + trial,
            maxiter=maxiter,
            tol=1e-10,
            polish=True,
            updating="deferred",
        )
        a0, b0, a1, b1 = res.x
        model_pdf = beta_mixture_pdf(centers, fraud_prior, a0, b0, a1, b1)
        jsd = jensen_shannon_divergence(hist, model_pdf)
        cand = BetaMixtureFit(fraud_prior, a0, b0, a1, b1, jsd, float(res.fun))
        if best is None or cand.jsd < best.jsd:
            best = cand
    assert best is not None
    return best


def default_quantile_map(
    fit: BetaMixtureFit,
    ref_quantiles,
    levels: np.ndarray | None = None,
) -> QuantileMap:
    """Build ``T^Q_{v0}`` from the fitted prior f_S (no client data needed)."""
    ref_q = np.asarray(ref_quantiles, dtype=np.float64)
    if levels is None:
        levels = np.linspace(0.0, 1.0, ref_q.shape[-1])
    src = fit.quantiles(levels)
    return QuantileMap(
        src_quantiles=jnp.asarray(src, dtype=jnp.float32),
        ref_quantiles=jnp.asarray(ref_q, dtype=jnp.float32),
    )
