"""Per-tenant hotness tracking for the tiered bank store.

The tiered store (``serving/tiering.py``) keeps only the hottest tenants'
transform rows device-resident; everything else pages in from the host
store on demand.  "Hot" is defined here: an exponentially decayed access
count per tenant, decayed once per *dispatch window* (not per wall-clock
second — a tenant that dominates every recent window is hot regardless of
how fast windows arrive).

The tracker is array-backed and O(batch) per recorded window at ANY tenant
count: decay is applied lazily through one global scale factor (recording
``+1`` now writes ``1/scale`` into the raw count array, and ``scale``
shrinks by ``decay`` per tick), so a tick never touches the (possibly
10^6-wide) count vector.  The raw counts are renormalized only when the
scale underflows — an O(T) sweep every ~10^4 ticks at the default decay.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# renormalize the raw counts once the lazy scale factor drops below this —
# far above f64 underflow, so effective scores stay exact to ~1e-15
_RESCALE_FLOOR = 1e-100


@dataclasses.dataclass
class HotnessTracker:
    """Decayed per-key access counts over dispatch windows.

    ``decay`` is the per-window multiplier: after ``w`` windows with no
    access a key's score is ``score * decay**w``.  ``decay=1.0`` degrades
    to plain cumulative counts.  ``record`` takes the key vector of one
    dispatch window; ``tick`` marks a window boundary.  The tiered store
    calls ``record`` per dispatch and ``tick`` from its (explicit,
    control-plane) ``rebalance`` — scores therefore compare windows since
    the last rebalance against the decayed history before it.
    """

    num_keys: int
    decay: float = 0.98

    def __post_init__(self) -> None:
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {self.decay}")
        self._raw = np.zeros(self.num_keys, np.float64)
        self._scale = 1.0
        self._windows = 0

    # ------------------------------------------------------------- recording
    def record(self, keys: np.ndarray, weight: float = 1.0) -> None:
        """Count one dispatch window's accesses (duplicate keys add up)."""
        keys = np.asarray(keys, np.int64).ravel()
        if len(keys):
            np.add.at(self._raw, keys, weight / self._scale)

    def tick(self, windows: int = 1) -> None:
        """Advance ``windows`` dispatch-window boundaries (decay the past)."""
        if windows < 0:
            raise ValueError("windows must be >= 0")
        self._windows += windows
        self._scale *= self.decay ** windows
        if self._scale < _RESCALE_FLOOR:
            self._raw *= self._scale
            self._scale = 1.0

    # --------------------------------------------------------------- queries
    @property
    def windows(self) -> int:
        return self._windows

    def scores(self) -> np.ndarray:
        """Effective decayed counts, (num_keys,) — a fresh array."""
        return self._raw * self._scale

    def score(self, key: int) -> float:
        return float(self._raw[key] * self._scale)

    def top(self, n: int, mask: np.ndarray | None = None) -> np.ndarray:
        """The up-to-``n`` hottest keys with a nonzero score, hot-first.

        ``mask`` (optional, (num_keys,) bool) restricts eligibility — the
        tiered store passes its admitted set so un-admitted (cold-start)
        tenants can never claim a hot slot.
        """
        raw = self._raw if mask is None else np.where(mask, self._raw, 0.0)
        nz = np.flatnonzero(raw > 0.0)
        if len(nz) > n:
            part = nz[np.argpartition(-raw[nz], n - 1)[:n]]
        else:
            part = nz
        return part[np.argsort(-raw[part], kind="stable")]

    # ----------------------------------------------------------- persistence
    def snapshot(self) -> dict:
        """Portable state — adopted by a surged replica's fresh tracker so
        it starts with the victim's hot set instead of a cold one."""
        return {"num_keys": int(self.num_keys), "decay": float(self.decay),
                "scores": self.scores(), "windows": int(self._windows)}

    def adopt(self, snap: dict) -> None:
        """Overwrite this tracker's state with a snapshot's effective scores
        (sizes may differ — the common prefix is adopted)."""
        scores = np.asarray(snap["scores"], np.float64)
        n = min(len(scores), self.num_keys)
        self._raw[:] = 0.0
        self._raw[:n] = scores[:n]
        self._scale = 1.0
        self._windows = int(snap.get("windows", 0))
