"""Calibration / evaluation metrics used by the paper's evaluation (Sec. 3).

* Brier score (MSE of probabilities vs labels).
* ECE_SWEEP^EM  (Roelofs et al. 2022): equal-mass binning, sweeping the number
  of bins upward while the per-bin empirical positive rate stays monotone —
  the least-biased standard ECE estimator, the one the paper uses (Table 1).
* recall @ FPR (Sec. 3.2's "Recall at 1% FPR").
* Wilson score intervals (Fig. 4 error bars).
* Per-bin relative error against a target distribution (Figs. 4 and 6).
"""
from __future__ import annotations

import numpy as np


def brier_score(scores: np.ndarray, labels: np.ndarray) -> float:
    scores = np.asarray(scores, dtype=np.float64).ravel()
    labels = np.asarray(labels, dtype=np.float64).ravel()
    return float(np.mean((scores - labels) ** 2))


def _ece_equal_mass(scores_sorted: np.ndarray, labels_sorted: np.ndarray,
                    n_bins: int) -> tuple[float, bool]:
    """ECE with equal-mass bins on pre-sorted data.

    Returns (ece, monotone) where monotone indicates whether per-bin empirical
    positive rates are non-decreasing with confidence.
    """
    n = len(scores_sorted)
    edges = (np.arange(1, n_bins) * n) // n_bins
    score_bins = np.split(scores_sorted, edges)
    label_bins = np.split(labels_sorted, edges)
    ece = 0.0
    prev = -np.inf
    monotone = True
    for sb, lb in zip(score_bins, label_bins):
        if len(sb) == 0:
            continue
        conf = float(np.mean(sb))
        acc = float(np.mean(lb))
        ece += (len(sb) / n) * abs(conf - acc)
        if acc < prev - 1e-12:
            monotone = False
        prev = acc
    return ece, monotone


def ece_sweep_em(scores: np.ndarray, labels: np.ndarray, max_bins: int | None = None) -> float:
    """ECE_SWEEP^EM: the largest equal-mass bin count preserving monotonicity.

    Sweeps b = 1, 2, ... while the binned empirical positive rate remains
    non-decreasing in confidence; returns the ECE at the largest monotone b
    (Roelofs et al., 2022, "Mitigating Bias in Calibration Error Estimation").
    """
    scores = np.asarray(scores, dtype=np.float64).ravel()
    labels = np.asarray(labels, dtype=np.float64).ravel()
    order = np.argsort(scores, kind="stable")
    s, l = scores[order], labels[order]
    n = len(s)
    if max_bins is None:
        max_bins = n
    best_ece = abs(float(np.mean(s)) - float(np.mean(l)))  # b = 1
    for b in range(2, max_bins + 1):
        ece, monotone = _ece_equal_mass(s, l, b)
        if not monotone:
            break
        best_ece = ece
    return best_ece


def recall_at_fpr(scores: np.ndarray, labels: np.ndarray, fpr: float = 0.01) -> float:
    """Recall at the threshold whose false-positive rate is ``fpr``."""
    scores = np.asarray(scores, dtype=np.float64).ravel()
    labels = np.asarray(labels, dtype=np.int64).ravel()
    neg = np.sort(scores[labels == 0])
    if len(neg) == 0:
        return float("nan")
    # threshold = (1-fpr) quantile of negative scores
    thr = np.quantile(neg, 1.0 - fpr)
    pos = scores[labels == 1]
    if len(pos) == 0:
        return float("nan")
    return float(np.mean(pos > thr))


def wilson_interval(successes: int, total: int, z: float = 1.96) -> tuple[float, float]:
    """Wilson score interval for a binomial proportion (Fig. 4 error bars)."""
    if total == 0:
        return (0.0, 1.0)
    p = successes / total
    denom = 1.0 + z * z / total
    center = (p + z * z / (2 * total)) / denom
    half = (z / denom) * np.sqrt(p * (1 - p) / total + z * z / (4 * total * total))
    return (max(0.0, center - half), min(1.0, center + half))


def bin_relative_error(
    scores: np.ndarray,
    target_quantiles: np.ndarray,
    n_bins: int = 10,
    *,
    levels: np.ndarray | None = None,
) -> dict[str, np.ndarray]:
    """Figs. 4/6 metric: per-score-bin relative error vs the target distribution.

    The target bin mass is derived from the reference quantile table
    (CDF of R); observed mass is the empirical histogram of served scores.
    relative error = (observed - expected) / expected, per bin [i/n, (i+1)/n).
    Also returns Wilson interval half-widths on the observed proportions.
    """
    scores = np.asarray(scores, dtype=np.float64).ravel()
    tq = np.asarray(target_quantiles, dtype=np.float64)
    if levels is None:
        levels = np.linspace(0.0, 1.0, len(tq))
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    # CDF of R at bin edges: invert quantile table (levels as function of value)
    cdf_at_edges = np.interp(edges, tq, levels, left=0.0, right=1.0)
    expected = np.diff(cdf_at_edges)
    counts, _ = np.histogram(scores, bins=edges)
    n = len(scores)
    observed = counts / max(n, 1)
    with np.errstate(divide="ignore", invalid="ignore"):
        rel_err = np.where(expected > 0, (observed - expected) / expected, np.nan)
    lo = np.empty(n_bins)
    hi = np.empty(n_bins)
    for i, c in enumerate(counts):
        lo[i], hi[i] = wilson_interval(int(c), n)
    return {
        "edges": edges,
        "expected": expected,
        "observed": observed,
        "rel_err": rel_err,
        "wilson_lo": lo,
        "wilson_hi": hi,
        "counts": counts,
    }


def expected_calibration_error_fixed(scores: np.ndarray, labels: np.ndarray,
                                     n_bins: int = 15) -> float:
    """Plain fixed-width ECE (for cross-checks against ECE_SWEEP^EM)."""
    scores = np.asarray(scores, dtype=np.float64).ravel()
    labels = np.asarray(labels, dtype=np.float64).ravel()
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    idx = np.clip(np.digitize(scores, edges) - 1, 0, n_bins - 1)
    ece = 0.0
    n = len(scores)
    for b in range(n_bins):
        mask = idx == b
        if not mask.any():
            continue
        ece += (mask.sum() / n) * abs(scores[mask].mean() - labels[mask].mean())
    return float(ece)
