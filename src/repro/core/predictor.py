"""The predictor abstraction (paper Sec. 2.2, Eq. 2).

A predictor is the tuple ``p = <M, A, T^Q>``:

  * ``M``  — subset of expert models, each paired with its posterior
             correction ``T^C_k`` (a beta ratio from its training config);
  * ``A``  — aggregation (weighted average);
  * ``T^Q`` — quantile map to the stable reference distribution.

``PredictorSpec`` is the declarative half (model names + transform params —
what lives in the control plane / routing config).  ``Predictor`` is the bound
half: specs resolved against a :class:`~repro.core.registry.ModelPool`, with
the whole Eq. 2 pipeline jit-compiled.  Single-model predictors skip ``T^C``
and use identity aggregation, per the paper.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import transforms
from repro.core.registry import ModelPool
from repro.core.transforms import Aggregation, PosteriorCorrection, QuantileMap

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TransformPipeline:
    """The post-model half of Eq. 2 as one pytree (swap = model update)."""

    betas: Array          # (K,) per-expert undersampling ratios
    weights: Array        # (K,) aggregation weights
    src_quantiles: Array  # (N,)
    ref_quantiles: Array  # (N,)

    def __call__(self, expert_scores: Array) -> Array:
        """expert_scores: (..., K) raw scores -> (...) business-ready score."""
        return transforms.score_pipeline(
            expert_scores, self.betas, self.weights,
            self.src_quantiles, self.ref_quantiles,
        )

    def pre_quantile(self, expert_scores: Array) -> Array:
        """The T^Q *input*: posterior-corrected weighted aggregate.

        This is the distribution whose quantiles a refreshed T^Q must be
        fitted on (fitting on raw scores would mismatch the pipeline)."""
        corrected = transforms.posterior_correction(expert_scores, self.betas)
        w = self.weights / jnp.sum(self.weights)
        return jnp.einsum("...k,k->...", corrected, w)

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    def with_quantile_map(self, qm: QuantileMap) -> "TransformPipeline":
        return dataclasses.replace(
            self, src_quantiles=qm.src_quantiles, ref_quantiles=qm.ref_quantiles
        )

    def with_weights(self, weights: Array) -> "TransformPipeline":
        return dataclasses.replace(self, weights=jnp.asarray(weights, jnp.float32))


@dataclasses.dataclass(frozen=True)
class PredictorSpec:
    """Declarative predictor definition (control-plane object)."""

    name: str
    model_names: tuple[str, ...]
    betas: tuple[float, ...]          # per-model undersampling ratio (1.0 = none)
    weights: tuple[float, ...]        # aggregation weights
    quantile_map: QuantileMap
    metadata: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        k = len(self.model_names)
        if len(self.betas) != k or len(self.weights) != k:
            raise ValueError(
                f"predictor {self.name}: {k} models but "
                f"{len(self.betas)} betas / {len(self.weights)} weights"
            )

    @property
    def is_ensemble(self) -> bool:
        return len(self.model_names) > 1

    def pipeline(self) -> TransformPipeline:
        # Single-model predictors skip posterior correction (Sec. 2.2.2):
        # beta is forced to 1.0 (identity) and aggregation is identity.
        betas = self.betas if self.is_ensemble else (1.0,) * len(self.betas)
        return TransformPipeline(
            betas=jnp.asarray(betas, jnp.float32),
            weights=jnp.asarray(self.weights, jnp.float32),
            src_quantiles=self.quantile_map.src_quantiles,
            ref_quantiles=self.quantile_map.ref_quantiles,
        )

    @staticmethod
    def single(name: str, model_name: str, quantile_map: QuantileMap,
               **metadata: Any) -> "PredictorSpec":
        return PredictorSpec(
            name=name, model_names=(model_name,), betas=(1.0,), weights=(1.0,),
            quantile_map=quantile_map, metadata=metadata,
        )


class Predictor:
    """Spec bound to a model pool; callable on feature batches.

    Scoring (Eq. 2): run every expert, stack raw scores on the last axis,
    then apply the jitted transformation pipeline.  Raw scores are also
    returned for shadow logging / calibration analysis.
    """

    def __init__(self, spec: PredictorSpec, pool: ModelPool) -> None:
        self.spec = spec
        self._handles = [pool.acquire(n) for n in spec.model_names]
        self.pipeline = spec.pipeline()
        self._apply = jax.jit(lambda pipe, raw: pipe(raw))

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def model_names(self) -> tuple[str, ...]:
        return self.spec.model_names

    def raw_scores(self, features: Any) -> Array:
        """(..., K) stack of raw expert scores."""
        outs = [h.score_fn(features) for h in self._handles]
        return jnp.stack([jnp.asarray(o) for o in outs], axis=-1)

    def __call__(self, features: Any) -> Array:
        return self._apply(self.pipeline, self.raw_scores(features))

    def score_with_raw(self, features: Any) -> tuple[Array, Array]:
        raw = self.raw_scores(features)
        return self._apply(self.pipeline, raw), raw

    # -- seamless updates ----------------------------------------------------
    def with_updated_pipeline(self, pipeline: TransformPipeline) -> "Predictor":
        """Hot-swap the transformation pipeline (e.g. T^Q_v0 -> T^Q_v1).

        Returns a new predictor sharing the same model handles — no model
        re-provisioning, which is exactly the paper's cheap-update path.
        """
        clone = object.__new__(Predictor)
        clone.spec = self.spec
        clone._handles = self._handles
        clone.pipeline = pipeline
        clone._apply = self._apply
        return clone

    def release(self, pool: ModelPool) -> None:
        for n in self.spec.model_names:
            pool.release(n)


def deploy_predictor(spec: PredictorSpec, pool: ModelPool,
                     model_factories: Mapping[str, Callable[[], Any]],
                     model_costs: Mapping[str, float] | None = None) -> Predictor:
    """Deploy a predictor, provisioning only the models the pool lacks.

    ``model_factories`` maps model name -> zero-arg callable building the
    scoring fn (expensive: loads weights / compiles).  The factory is invoked
    only for models not already in the pool — Sec. 2.2.1's marginal-cost
    deployment.
    """
    costs = dict(model_costs or {})
    for name in spec.model_names:
        if name not in pool:
            pool.deploy(name, model_factories[name](),
                        resource_cost=costs.get(name, 1.0))
    return Predictor(spec, pool)
