"""Quantile estimation machinery + the Appendix-A sample-size bound.

Two estimation paths:
  * Offline batch fit (``np.quantile``) — used when enough history exists.
  * Streaming reservoir estimator — the serving layer feeds live scores into
    it per (tenant, predictor) pair; once ``required_sample_size`` is met the
    control plane can trigger a transformation refresh (the paper's
    "Automated Calibration Refresh" roadmap item, implemented here).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


def required_sample_size(alert_rate: float, rel_error: float, z: float = 1.96) -> int:
    """Eq. 5 / Eq. 14: ``n = z^2 (1-a) / (delta^2 a)``.

    Minimum number of unlabeled score samples so the realized alert rate at
    the fitted threshold deviates from the target ``a`` by at most ``delta``
    (relative), with confidence given by z (1.96 -> 95%).
    """
    if not 0.0 < alert_rate < 1.0:
        raise ValueError(f"alert_rate must be in (0,1), got {alert_rate}")
    if rel_error <= 0.0:
        raise ValueError(f"rel_error must be > 0, got {rel_error}")
    return int(np.ceil(z * z * (1.0 - alert_rate) / (rel_error * rel_error * alert_rate)))


def alert_rate_rel_error(alert_rate: float, n: int, z: float = 1.96) -> float:
    """Inverse of Eq. 5: achievable relative error for a given sample budget."""
    return float(z * np.sqrt((1.0 - alert_rate) / (n * alert_rate)))


@dataclasses.dataclass
class StreamingQuantileEstimator:
    """Fixed-size uniform reservoir over a score stream.

    Simple, unbiased, and adequate at MUSE scale: the Appendix-A bound for
    a=0.1% alert rate at delta=20% needs ~96k samples, which a 128k reservoir
    holds exactly until overflow, after which uniform reservoir sampling keeps
    an unbiased subsample.  (P2/t-digest would use less memory; a reservoir is
    exact for the bins we need and trivially correct.)
    """

    capacity: int = 131072
    seed: int = 0
    # ring of the newest samples, independent of reservoir acceptance: the
    # calibration controller validates refit candidates against this window,
    # so a distribution shift AFTER the reservoir filled (which uniform
    # sampling dilutes almost invisibly) still fails support coverage
    recent_capacity: int = 4096

    def __post_init__(self) -> None:
        self._buf = np.empty((self.capacity,), dtype=np.float64)
        self._recent = np.empty((self.recent_capacity,), dtype=np.float64)
        self._recent_pos = 0   # explicit ring pointer (bulk writes reset it)
        self._seen = 0
        self._rng = np.random.default_rng(self.seed)

    @property
    def count(self) -> int:
        return self._seen

    def update(self, scores: np.ndarray) -> None:
        scores = np.asarray(scores, dtype=np.float64).ravel()
        for chunk in np.array_split(scores, max(1, len(scores) // 65536)):
            self._update_chunk(chunk)

    def _update_chunk(self, scores: np.ndarray) -> None:
        k = len(scores)
        if k == 0:
            return
        rc = self.recent_capacity
        if k >= rc:
            self._recent[:] = scores[-rc:]
            self._recent_pos = 0
        else:
            pos = (self._recent_pos + np.arange(k)) % rc
            self._recent[pos] = scores
            self._recent_pos = int((self._recent_pos + k) % rc)
        fill = min(self.capacity - min(self._seen, self.capacity), k)
        if fill > 0:
            start = self._seen
            self._buf[start : start + fill] = scores[:fill]
        rest = scores[fill:]
        if len(rest) > 0:
            # Vectorized reservoir: each element replaces a random slot with
            # probability capacity / (index seen so far).
            idx = self._seen + fill + np.arange(len(rest), dtype=np.int64) + 1
            accept = self._rng.random(len(rest)) < (self.capacity / idx)
            slots = self._rng.integers(0, self.capacity, size=len(rest))
            sel = np.flatnonzero(accept)
            self._buf[slots[sel]] = rest[sel]
        self._seen += k

    def quantiles(self, levels: np.ndarray) -> np.ndarray:
        if self._seen == 0:
            raise ValueError("no samples observed")
        data = self._buf[: min(self._seen, self.capacity)]
        q = np.quantile(data, np.asarray(levels))
        return np.maximum.accumulate(q)

    def values(self) -> np.ndarray:
        """Read-only view of the retained (reservoir) samples."""
        view = self._buf[: min(self._seen, self.capacity)]
        view.flags.writeable = False
        return view

    def recent(self) -> np.ndarray:
        """Read-only view of the newest ≤``recent_capacity`` samples
        (unordered).  Empty until the first update."""
        view = self._recent[: min(self._seen, self.recent_capacity)]
        view.flags.writeable = False
        return view

    def ready(self, alert_rate: float, rel_error: float, z: float = 1.96) -> bool:
        """Has this stream accumulated enough events for a trustworthy T^Q?"""
        return self._seen >= required_sample_size(alert_rate, rel_error, z)

    # ------------------------------------------------------- persistence
    def checkpoint_arrays(self) -> dict[str, np.ndarray]:
        """Array state for a checkpoint leaf dict (reservoir + recent ring).

        Full-capacity buffers are stored (not just the filled prefix) so the
        restore target has a static shape; ``checkpoint_meta`` records how
        much of each is live."""
        return {"buf": self._buf.copy(), "recent": self._recent.copy()}

    def checkpoint_meta(self) -> dict:
        """JSON-safe scalar state.  The RNG bit-generator state is reprd
        (its 128-bit PCG64 ints overflow orjson's 64-bit limit) so a
        restored estimator continues the SAME reservoir-acceptance sequence
        it would have run unsaved."""
        return {
            "capacity": int(self.capacity),
            "seed": int(self.seed),
            "recent_capacity": int(self.recent_capacity),
            "seen": int(self._seen),
            "recent_pos": int(self._recent_pos),
            "rng_state": repr(self._rng.bit_generator.state),
        }

    @staticmethod
    def from_checkpoint(arrays: dict, meta: dict) -> "StreamingQuantileEstimator":
        """Rebuild an estimator from ``checkpoint_arrays``/``checkpoint_meta``.

        The round-trip is exact: reservoir samples, recent ring (+ pointer),
        observed count (so the Eq.-5 gate still passes), and RNG state all
        restore bit-for-bit — a surged replica starts warm."""
        import ast

        est = StreamingQuantileEstimator(
            capacity=int(meta["capacity"]), seed=int(meta["seed"]),
            recent_capacity=int(meta["recent_capacity"]))
        est._buf[:] = np.asarray(arrays["buf"], np.float64)
        est._recent[:] = np.asarray(arrays["recent"], np.float64)
        est._seen = int(meta["seen"])
        est._recent_pos = int(meta["recent_pos"])
        rng_state = meta.get("rng_state")
        if rng_state:
            est._rng.bit_generator.state = ast.literal_eval(rng_state)
        return est


def batch_sample_quantiles(
    samples: Sequence[np.ndarray],
    levels: np.ndarray,
) -> np.ndarray:
    """Quantiles of MANY sample sets in one vectorized pass -> (R, L).

    The fleet-wide calibration refresh refits every ready (tenant, predictor)
    stream at once.  Rows are padded with +inf into one (R, C_max) matrix,
    sorted with a single ``np.sort`` call (C-level, the padding tails sort
    last), and every row's quantile table comes from two vectorized
    ``take_along_axis`` gathers with linear interpolation against the row's
    OWN length — identical semantics to ``np.quantile(row, levels)``
    (method='linear') per row, without numpy's per-row ``nanquantile``
    Python loop.  Monotonicity is enforced per row (fp jitter guard, same
    as the scalar path).
    """
    levels = np.asarray(levels, np.float64)
    if not samples:
        return np.empty((0, len(levels)), np.float64)
    rows = [np.asarray(r, np.float64).ravel() for r in samples]
    lens = np.array([len(r) for r in rows], np.int64)
    if (lens == 0).any():
        raise ValueError("cannot refit a stream with no samples")
    mat = np.full((len(rows), int(lens.max())), np.inf, np.float64)
    for i, r in enumerate(rows):
        mat[i, : len(r)] = r
    mat.sort(axis=1)
    # np.quantile 'linear' method: position = level * (n - 1), per row
    pos = levels[None, :] * (lens[:, None] - 1).astype(np.float64)  # (R, L)
    lo = np.floor(pos).astype(np.int64)
    hi = np.ceil(pos).astype(np.int64)
    frac = pos - lo
    q_lo = np.take_along_axis(mat, lo, axis=1)
    q_hi = np.take_along_axis(mat, hi, axis=1)
    q = q_lo + (q_hi - q_lo) * frac                    # (R, L)
    return np.maximum.accumulate(q, axis=1)


def batch_quantiles(scores: np.ndarray, n_levels: int) -> tuple[np.ndarray, np.ndarray]:
    """Offline fit: (levels, quantiles) with monotonicity enforced."""
    levels = np.linspace(0.0, 1.0, n_levels)
    q = np.quantile(np.asarray(scores, dtype=np.float64), levels)
    return levels, np.maximum.accumulate(q)
