"""Quantile estimation machinery + the Appendix-A sample-size bound.

Two estimation paths:
  * Offline batch fit (``np.quantile``) — used when enough history exists.
  * Streaming reservoir estimator — the serving layer feeds live scores into
    it per (tenant, predictor) pair; once ``required_sample_size`` is met the
    control plane can trigger a transformation refresh (the paper's
    "Automated Calibration Refresh" roadmap item, implemented here).

Mergeable sketches (the fleet-calibration reduction)
----------------------------------------------------

:meth:`StreamingQuantileEstimator.merge` /
:meth:`StreamingQuantileEstimator.merge_checkpoints` reduce per-replica
estimator states into ONE estimator equivalent (up to the bound below) to an
estimator that watched the concatenation of every replica's stream.  The
fleet calibration plane (``serving/calibration.py``) pulls each replica's
exact checkpoint (reservoir + recent ring, PR-5 serialization), merges per
(tenant, predictor), and fits T^Q once on the merged view.

**Merge accuracy bound.**  Each retained sample of part *i* represents
``seen_i / retained_i`` stream elements; when the union of retained samples
exceeds the merged capacity, a weighted subsample without replacement
(Efraimidis–Spirakis keys) keeps the merged reservoir an approximately
uniform sample of the concatenated stream.  Every uniform-subsampling stage
of size *n* contributes at most ``c(δ) / sqrt(n)`` rank (level-space) error
with probability ≥ 1 − δ, where ``c(δ) = sqrt(ln(2/δ) / 2)`` (the DKW
inequality); stages compose additively.  :func:`merge_rank_error_bound`
evaluates the bound and the property tests in ``tests/test_quantiles.py``
assert merged-vs-concatenated fits against it.  Merged ``count`` is exactly
the sum of part counts — associative and commutative — so the Eq.-5 gate
sees the union of what every replica saw.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Sequence

import numpy as np


def merge_rank_error_bound(*stage_sizes: int, delta: float = 1e-3) -> float:
    """Worst-case rank (level-space) error of a multi-stage uniform subsample.

    ``stage_sizes`` lists the size of every subsampling stage between the
    concatenated stream and the final reservoir (per-part reservoirs, the
    merge subsample, a comparison estimator's own reservoir, ...).  Each
    stage of size ``n`` contributes ``sqrt(ln(2/delta) / 2) / sqrt(n)``
    (DKW, confidence 1 − delta per stage); the stages add.
    """
    c = math.sqrt(math.log(2.0 / delta) / 2.0)
    return float(sum(c / math.sqrt(n) for n in stage_sizes if n > 0))


def required_sample_size(alert_rate: float, rel_error: float, z: float = 1.96) -> int:
    """Eq. 5 / Eq. 14: ``n = z^2 (1-a) / (delta^2 a)``.

    Minimum number of unlabeled score samples so the realized alert rate at
    the fitted threshold deviates from the target ``a`` by at most ``delta``
    (relative), with confidence given by z (1.96 -> 95%).
    """
    if not 0.0 < alert_rate < 1.0:
        raise ValueError(f"alert_rate must be in (0,1), got {alert_rate}")
    if rel_error <= 0.0:
        raise ValueError(f"rel_error must be > 0, got {rel_error}")
    return int(np.ceil(z * z * (1.0 - alert_rate) / (rel_error * rel_error * alert_rate)))


def alert_rate_rel_error(alert_rate: float, n: int, z: float = 1.96) -> float:
    """Inverse of Eq. 5: achievable relative error for a given sample budget."""
    return float(z * np.sqrt((1.0 - alert_rate) / (n * alert_rate)))


@dataclasses.dataclass
class StreamingQuantileEstimator:
    """Fixed-size uniform reservoir over a score stream.

    Simple, unbiased, and adequate at MUSE scale: the Appendix-A bound for
    a=0.1% alert rate at delta=20% needs ~96k samples, which a 128k reservoir
    holds exactly until overflow, after which uniform reservoir sampling keeps
    an unbiased subsample.  (P2/t-digest would use less memory; a reservoir is
    exact for the bins we need and trivially correct.)
    """

    capacity: int = 131072
    seed: int = 0
    # ring of the newest samples, independent of reservoir acceptance: the
    # calibration controller validates refit candidates against this window,
    # so a distribution shift AFTER the reservoir filled (which uniform
    # sampling dilutes almost invisibly) still fails support coverage
    recent_capacity: int = 4096

    def __post_init__(self) -> None:
        self._buf = np.empty((self.capacity,), dtype=np.float64)
        self._recent = np.empty((self.recent_capacity,), dtype=np.float64)
        self._recent_pos = 0   # explicit ring pointer (bulk writes reset it)
        self._seen = 0
        # live slot counts: equal to min(seen, capacity) for a purely
        # streamed estimator, but a MERGED estimator may hold fewer retained
        # samples than its count implies (parts already subsampled), so the
        # live prefixes are tracked explicitly
        self._filled = 0
        self._recent_filled = 0
        self._rng = np.random.default_rng(self.seed)

    @property
    def count(self) -> int:
        return self._seen

    def update(self, scores: np.ndarray) -> None:
        scores = np.asarray(scores, dtype=np.float64).ravel()
        # ceil division: floor allowed chunks up to 131071 — double the
        # documented 65536 bound (array_split over k parts caps each at
        # ceil(n / k), so k must be ceil(n / 65536))
        for chunk in np.array_split(scores, max(1, -(-len(scores) // 65536))):
            self._update_chunk(chunk)

    def apply_chunks(self, chunks: list[np.ndarray]) -> None:
        """Device-backed materialization hook: replay staged samples with
        one ``update`` call per ORIGINAL tracking window.

        State after a sequence of updates depends on the sample values AND
        the update-call boundaries (the recent ring bulk-resets on windows
        >= its capacity; the reservoir RNG draws once per overflow batch),
        so a device tracker that staged several windows must replay them as
        the separate calls they were — that is what makes its drained state
        bitwise-identical to eager tracking (see
        ``kernels/quantile_track.py``), not merely statistically equal."""
        for chunk in chunks:
            self.update(chunk)

    def _update_chunk(self, scores: np.ndarray) -> None:
        k = len(scores)
        if k == 0:
            return
        rc = self.recent_capacity
        if k >= rc:
            self._recent[:] = scores[-rc:]
            self._recent_pos = 0
            self._recent_filled = rc
        else:
            pos = (self._recent_pos + np.arange(k)) % rc
            self._recent[pos] = scores
            self._recent_pos = int((self._recent_pos + k) % rc)
            self._recent_filled = min(self._recent_filled + k, rc)
        fill = min(self.capacity - self._filled, k)
        if fill > 0:
            start = self._filled
            self._buf[start : start + fill] = scores[:fill]
            self._filled += fill
        rest = scores[fill:]
        if len(rest) > 0:
            # Vectorized reservoir: each element replaces a random slot with
            # probability capacity / (index seen so far).
            idx = self._seen + fill + np.arange(len(rest), dtype=np.int64) + 1
            accept = self._rng.random(len(rest)) < (self.capacity / idx)
            slots = self._rng.integers(0, self.capacity, size=len(rest))
            sel = np.flatnonzero(accept)
            self._buf[slots[sel]] = rest[sel]
        self._seen += k

    def quantiles(self, levels: np.ndarray) -> np.ndarray:
        if self._filled == 0:
            raise ValueError("no samples observed")
        data = self._buf[: self._filled]
        q = np.quantile(data, np.asarray(levels))
        return np.maximum.accumulate(q)

    def values(self) -> np.ndarray:
        """Read-only view of the retained (reservoir) samples."""
        view = self._buf[: self._filled]
        view.flags.writeable = False
        return view

    def recent(self) -> np.ndarray:
        """Read-only view of the newest ≤``recent_capacity`` samples
        (unordered).  Empty until the first update."""
        view = self._recent[: self._recent_filled]
        view.flags.writeable = False
        return view

    # ------------------------------------------------------------ merging
    def merge(self, *others: "StreamingQuantileEstimator"
              ) -> "StreamingQuantileEstimator":
        """Non-mutating reduction: a NEW estimator over the union of streams.

        See the module docstring for the accuracy bound; ``count`` of the
        result is exactly the sum of the parts' counts (associative and
        commutative), so the Eq.-5 gate evaluates the fleet-wide union.
        """
        return StreamingQuantileEstimator.merged((self, *others))

    @staticmethod
    def merged(parts: "Sequence[StreamingQuantileEstimator]"
               ) -> "StreamingQuantileEstimator":
        """Merge MANY estimators (the fleet reduction over replicas).

        Reservoir: the union of retained samples when it fits the merged
        capacity (exact — zero merge error); otherwise an Efraimidis–
        Spirakis weighted subsample without replacement, each part's samples
        weighted by ``seen_i / retained_i`` (the stream mass one retained
        sample represents).  Recent ring: the union of the parts' recent
        windows, uniformly subsampled to the merged ring capacity.  The
        merge seed derives from the (order-independent) multiset of part
        seeds/counts, so merging is deterministic given the parts.
        """
        parts = [p for p in parts]
        if not parts:
            raise ValueError("nothing to merge")
        cap = max(p.capacity for p in parts)
        rc = max(p.recent_capacity for p in parts)
        seed = zlib.crc32(repr(sorted(
            (p.seed, p.count, p.capacity) for p in parts)).encode())
        out = StreamingQuantileEstimator(capacity=cap, seed=seed,
                                         recent_capacity=rc)
        vals = [np.asarray(p.values(), np.float64) for p in parts]
        seens = [p.count for p in parts]
        retained = np.concatenate([v for v in vals if len(v)]) \
            if any(len(v) for v in vals) else np.empty(0, np.float64)
        if len(retained) <= cap:
            out._buf[: len(retained)] = retained
            out._filled = len(retained)
        else:
            # ES weighted subsample w/o replacement: key = log(u)/w, top-cap
            w = np.concatenate([np.full(len(v), s / len(v), np.float64)
                                for v, s in zip(vals, seens) if len(v)])
            keys = np.log(out._rng.random(len(retained))) / w
            sel = np.argpartition(-keys, cap - 1)[:cap]
            out._buf[:cap] = retained[sel]
            out._filled = cap
        out._seen = int(sum(seens))
        recents = [np.asarray(p.recent(), np.float64) for p in parts]
        pool = np.concatenate([r for r in recents if len(r)]) \
            if any(len(r) for r in recents) else np.empty(0, np.float64)
        if len(pool) > rc:
            pool = pool[out._rng.choice(len(pool), rc, replace=False)]
        out._recent[: len(pool)] = pool
        out._recent_filled = len(pool)
        out._recent_pos = int(len(pool) % rc)
        return out

    @staticmethod
    def merge_checkpoints(snapshots: Sequence[tuple[dict, dict]]
                          ) -> "StreamingQuantileEstimator":
        """Merge per-replica ``(checkpoint_arrays, checkpoint_meta)`` pairs.

        The fleet calibration plane's wire format IS the exact PR-5
        checkpoint serialization: each snapshot rebuilds bit-for-bit, then
        the estimators reduce through :meth:`merged`.
        """
        return StreamingQuantileEstimator.merged(
            [StreamingQuantileEstimator.from_checkpoint(a, m)
             for a, m in snapshots])

    def ready(self, alert_rate: float, rel_error: float, z: float = 1.96) -> bool:
        """Has this stream accumulated enough events for a trustworthy T^Q?"""
        return self._seen >= required_sample_size(alert_rate, rel_error, z)

    # ------------------------------------------------------- persistence
    def checkpoint_arrays(self) -> dict[str, np.ndarray]:
        """Array state for a checkpoint leaf dict (reservoir + recent ring).

        Full-capacity buffers are stored (not just the filled prefix) so the
        restore target has a static shape; ``checkpoint_meta`` records how
        much of each is live."""
        return {"buf": self._buf.copy(), "recent": self._recent.copy()}

    def checkpoint_meta(self) -> dict:
        """JSON-safe scalar state.  The RNG bit-generator state is reprd
        (its 128-bit PCG64 ints overflow orjson's 64-bit limit) so a
        restored estimator continues the SAME reservoir-acceptance sequence
        it would have run unsaved."""
        return {
            "capacity": int(self.capacity),
            "seed": int(self.seed),
            "recent_capacity": int(self.recent_capacity),
            "seen": int(self._seen),
            "recent_pos": int(self._recent_pos),
            # live prefixes: min(seen, capacity) for streamed estimators,
            # but smaller after a merge (parts had already subsampled)
            "filled": int(self._filled),
            "recent_filled": int(self._recent_filled),
            "rng_state": repr(self._rng.bit_generator.state),
        }

    @staticmethod
    def from_checkpoint(arrays: dict, meta: dict) -> "StreamingQuantileEstimator":
        """Rebuild an estimator from ``checkpoint_arrays``/``checkpoint_meta``.

        The round-trip is exact: reservoir samples, recent ring (+ pointer),
        observed count (so the Eq.-5 gate still passes), and RNG state all
        restore bit-for-bit — a surged replica starts warm."""
        import ast

        est = StreamingQuantileEstimator(
            capacity=int(meta["capacity"]), seed=int(meta["seed"]),
            recent_capacity=int(meta["recent_capacity"]))
        est._buf[:] = np.asarray(arrays["buf"], np.float64)
        est._recent[:] = np.asarray(arrays["recent"], np.float64)
        est._seen = int(meta["seen"])
        est._recent_pos = int(meta["recent_pos"])
        # pre-merge checkpoints carry no live-prefix keys: default to the
        # streamed invariant min(seen, capacity)
        est._filled = int(meta.get(
            "filled", min(est._seen, est.capacity)))
        est._recent_filled = int(meta.get(
            "recent_filled", min(est._seen, est.recent_capacity)))
        rng_state = meta.get("rng_state")
        if rng_state:
            est._rng.bit_generator.state = ast.literal_eval(rng_state)
        return est


def batch_sample_quantiles(
    samples: Sequence[np.ndarray],
    levels: np.ndarray,
) -> np.ndarray:
    """Quantiles of MANY sample sets in one vectorized pass -> (R, L).

    The fleet-wide calibration refresh refits every ready (tenant, predictor)
    stream at once.  Rows are padded with +inf into one (R, C_max) matrix,
    sorted with a single ``np.sort`` call (C-level, the padding tails sort
    last), and every row's quantile table comes from two vectorized
    ``take_along_axis`` gathers with linear interpolation against the row's
    OWN length — identical semantics to ``np.quantile(row, levels)``
    (method='linear') per row, without numpy's per-row ``nanquantile``
    Python loop.  Monotonicity is enforced per row (fp jitter guard, same
    as the scalar path).
    """
    levels = np.asarray(levels, np.float64)
    if not samples:
        return np.empty((0, len(levels)), np.float64)
    rows = [np.asarray(r, np.float64).ravel() for r in samples]
    lens = np.array([len(r) for r in rows], np.int64)
    if (lens == 0).any():
        raise ValueError("cannot refit a stream with no samples")
    mat = np.full((len(rows), int(lens.max())), np.inf, np.float64)
    for i, r in enumerate(rows):
        mat[i, : len(r)] = r
    mat.sort(axis=1)
    # np.quantile 'linear' method: position = level * (n - 1), per row
    pos = levels[None, :] * (lens[:, None] - 1).astype(np.float64)  # (R, L)
    lo = np.floor(pos).astype(np.int64)
    hi = np.ceil(pos).astype(np.int64)
    frac = pos - lo
    q_lo = np.take_along_axis(mat, lo, axis=1)
    q_hi = np.take_along_axis(mat, hi, axis=1)
    q = q_lo + (q_hi - q_lo) * frac                    # (R, L)
    return np.maximum.accumulate(q, axis=1)


def batch_quantiles(scores: np.ndarray, n_levels: int) -> tuple[np.ndarray, np.ndarray]:
    """Offline fit: (levels, quantiles) with monotonicity enforced."""
    levels = np.linspace(0.0, 1.0, n_levels)
    q = np.quantile(np.asarray(scores, dtype=np.float64), levels)
    return levels, np.maximum.accumulate(q)
