"""Model pool with cross-predictor deduplication (paper Sec. 2.2.1).

A *model* here is a physical deployment unit (the paper's Triton container;
for us, a compiled JAX scoring executable + weights).  Predictors reference
models by name; the pool refcounts them so that

  * deploying predictor ``p2 = {m1, m2, m3}`` on top of ``p1 = {m1, m2}``
    provisions only ``m3`` (infrastructure dedup), and
  * decommissioning ``p1`` keeps ``m1``/``m2`` alive while ``p2`` needs them.

The pool also records provision/reuse counters so the dedup benefit is
observable (tested + surfaced in benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

ScoreFn = Callable[..., Any]


class ModelNotDeployed(LookupError):
    pass


@dataclasses.dataclass
class ModelHandle:
    name: str
    score_fn: ScoreFn
    metadata: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    refcount: int = 0
    # resource accounting (abstract units, e.g. bytes of params or pod count)
    resource_cost: float = 1.0


class ModelPool:
    """Refcounted registry of deployed model executables."""

    def __init__(self) -> None:
        self._models: dict[str, ModelHandle] = {}
        self.provision_events = 0   # how many times a container was (re)created
        self.reuse_events = 0       # how many acquisitions hit an existing one

    # -- deployment ---------------------------------------------------------
    def deploy(self, name: str, score_fn: ScoreFn, *,
               metadata: Mapping[str, Any] | None = None,
               resource_cost: float = 1.0) -> ModelHandle:
        """Idempotent: re-deploying an existing name reuses the container."""
        if name in self._models:
            self.reuse_events += 1
            return self._models[name]
        handle = ModelHandle(name=name, score_fn=score_fn,
                             metadata=dict(metadata or {}),
                             resource_cost=resource_cost)
        self._models[name] = handle
        self.provision_events += 1
        return handle

    def acquire(self, name: str) -> ModelHandle:
        if name not in self._models:
            raise ModelNotDeployed(name)
        handle = self._models[name]
        handle.refcount += 1
        self.reuse_events += 1
        return handle

    def release(self, name: str) -> None:
        if name not in self._models:
            raise ModelNotDeployed(name)
        handle = self._models[name]
        handle.refcount = max(0, handle.refcount - 1)
        if handle.refcount == 0:
            # Decommission only when no predictor references the model.
            del self._models[name]

    # -- introspection ------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._models

    def get(self, name: str) -> ModelHandle:
        if name not in self._models:
            raise ModelNotDeployed(name)
        return self._models[name]

    def names(self) -> tuple[str, ...]:
        return tuple(self._models)

    def total_resource_cost(self) -> float:
        return sum(h.resource_cost for h in self._models.values())

    def marginal_cost_of(self, model_names: tuple[str, ...],
                         costs: Mapping[str, float]) -> float:
        """Resource cost of deploying a predictor over this pool: only the
        models not already present are provisioned (Sec. 2.2.1 benefit #1)."""
        return sum(costs.get(n, 1.0) for n in model_names if n not in self._models)
