"""Intent-based routing (paper Sec. 2.5, Fig. 2).

Clients express a *scoring intent* — request metadata such as tenant id,
geography, schema, payment channel — never a model name.  The routing table
maps intents to predictors:

  * ``scoring_rules``: evaluated **sequentially**, first match wins, resolves
    to exactly one *live* predictor (its score is returned to the client).
  * ``shadow_rules``: evaluated **in parallel**, every match fires, each
    resolves to one or more *shadow* predictors whose responses are logged to
    the data lake sink but never returned.

The table is an immutable value object: "transparent model switching" is
publishing a new table version and letting the rollout controller swap it —
there is no in-place mutation, mirroring the paper's stateless design.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class Intent:
    """Request metadata carried by every scoring call."""

    tenant: str
    geography: str = ""
    schema: str = ""
    channel: str = ""
    extra: Mapping[str, str] = dataclasses.field(default_factory=dict)

    def get(self, field: str) -> str:
        if field in ("tenant", "geography", "schema", "channel"):
            return getattr(self, field)
        return self.extra.get(field, "")


@dataclasses.dataclass(frozen=True)
class Condition:
    """Conjunctive match over intent fields; empty lists match anything.

    Matches Fig. 2 semantics: ``condition: {}`` is a catch-all; each present
    field is an OR-list; fields combine with AND.
    """

    tenants: tuple[str, ...] = ()
    geographies: tuple[str, ...] = ()
    schemas: tuple[str, ...] = ()
    channels: tuple[str, ...] = ()
    extra: Mapping[str, tuple[str, ...]] = dataclasses.field(default_factory=dict)

    def matches(self, intent: Intent) -> bool:
        checks = [
            (self.tenants, intent.tenant),
            (self.geographies, intent.geography),
            (self.schemas, intent.schema),
            (self.channels, intent.channel),
        ]
        for allowed, value in checks:
            if allowed and value not in allowed:
                return False
        for field, allowed in self.extra.items():
            if allowed and intent.get(field) not in allowed:
                return False
        return True

    @staticmethod
    def from_dict(d: Mapping[str, Any]) -> "Condition":
        known = {"tenants", "geographies", "schemas", "channels"}
        extra = {k: tuple(v) for k, v in d.items() if k not in known}
        return Condition(
            tenants=tuple(d.get("tenants", ())),
            geographies=tuple(d.get("geographies", ())),
            schemas=tuple(d.get("schemas", ())),
            channels=tuple(d.get("channels", ())),
            extra=extra,
        )


@dataclasses.dataclass(frozen=True)
class ScoringRule:
    condition: Condition
    target_predictor: str
    description: str = ""


@dataclasses.dataclass(frozen=True)
class ShadowRule:
    condition: Condition
    target_predictors: tuple[str, ...]
    description: str = ""


@dataclasses.dataclass(frozen=True)
class Resolution:
    live: str
    shadows: tuple[str, ...]
    rule_description: str = ""


class NoMatchingRule(LookupError):
    pass


@dataclasses.dataclass(frozen=True)
class RoutingTable:
    """Immutable, versioned routing configuration."""

    scoring_rules: tuple[ScoringRule, ...]
    shadow_rules: tuple[ShadowRule, ...] = ()
    version: str = "v0"

    def resolve(self, intent: Intent) -> Resolution:
        live: str | None = None
        desc = ""
        for rule in self.scoring_rules:  # sequential, first match wins
            if rule.condition.matches(intent):
                live = rule.target_predictor
                desc = rule.description
                break
        if live is None:
            raise NoMatchingRule(
                f"no scoring rule matches intent {intent} (table {self.version})"
            )
        shadows: list[str] = []
        for rule in self.shadow_rules:  # parallel, all matches fire
            if rule.condition.matches(intent):
                for name in rule.target_predictors:
                    if name != live and name not in shadows:
                        shadows.append(name)
        return Resolution(live=live, shadows=tuple(shadows), rule_description=desc)

    def referenced_predictors(self) -> tuple[str, ...]:
        names: list[str] = []
        for r in self.scoring_rules:
            if r.target_predictor not in names:
                names.append(r.target_predictor)
        for s in self.shadow_rules:
            for n in s.target_predictors:
                if n not in names:
                    names.append(n)
        return tuple(names)

    def with_rule_update(self, old_predictor: str, new_predictor: str,
                         version: str) -> "RoutingTable":
        """Transparent model switching: retarget rules, bump version."""
        new_scoring = tuple(
            dataclasses.replace(r, target_predictor=new_predictor)
            if r.target_predictor == old_predictor
            else r
            for r in self.scoring_rules
        )
        return dataclasses.replace(self, scoring_rules=new_scoring, version=version)

    @staticmethod
    def from_dict(cfg: Mapping[str, Any], version: str = "v0") -> "RoutingTable":
        """Parse the Fig.-2-style declarative config."""
        routing = cfg.get("routing", cfg)
        scoring = tuple(
            ScoringRule(
                condition=Condition.from_dict(r.get("condition", {})),
                target_predictor=r["targetPredictorName"],
                description=r.get("description", ""),
            )
            for r in routing.get("scoringRules", ())
        )
        shadow = tuple(
            ShadowRule(
                condition=Condition.from_dict(r.get("condition", {})),
                target_predictors=tuple(r["targetPredictorNames"]),
                description=r.get("description", ""),
            )
            for r in routing.get("shadowRules", ())
        )
        return RoutingTable(scoring_rules=scoring, shadow_rules=shadow, version=version)
