"""Composable score transformations (paper Sec. 2.3).

Three transformation nodes compose a predictor's post-model DAG:

  * :class:`PosteriorCorrection`  — ``T^C`` (Eq. 3), undoes undersampling bias.
  * :class:`Aggregation`          — ``A``, weighted average of calibrated experts.
  * :class:`QuantileMap`          — ``T^Q`` (Eq. 4), piecewise-linear CDF alignment.

All transforms are pure pytrees of arrays + static metadata so they can live
inside jitted serving steps, be donated, swapped (the paper's "seamless model
update" = replacing these pytrees under a stable routing intent), and sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Posterior Correction (Eq. 3)
# ---------------------------------------------------------------------------

def posterior_correction(scores: Array, beta: Array | float) -> Array:
    """Eq. 3: ``T^C(y) = beta*y / (1 - (1-beta)*y)``.

    ``beta`` is the undersampling ratio of the majority (negative) class used
    when training the expert: ``beta = P(keep negative sample)``.  Scores are
    posterior probabilities in [0, 1].  The map is monotone, fixes 0 and 1,
    and is the exact analytical inverse of the prior shift introduced by
    undersampling (Dal Pozzolo et al., 2015).
    """
    scores = jnp.asarray(scores)
    beta = jnp.asarray(beta, dtype=scores.dtype)
    return (beta * scores) / (1.0 - (1.0 - beta) * scores)


def posterior_correction_inverse(corrected: Array, beta: Array | float) -> Array:
    """Inverse of Eq. 3 — maps a true posterior back to the biased score.

    Used by the synthetic data pipeline to *induce* undersampling bias with a
    known ground truth, and in tests as the round-trip oracle.
    """
    corrected = jnp.asarray(corrected)
    beta = jnp.asarray(beta, dtype=corrected.dtype)
    return corrected / (beta + (1.0 - beta) * corrected)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PosteriorCorrection:
    """Per-expert ``T^C_k`` node: carries the training undersampling ratio."""

    beta: Array  # scalar (or broadcastable) undersampling ratio in (0, 1]

    def __call__(self, scores: Array) -> Array:
        return posterior_correction(scores, self.beta)

    @staticmethod
    def identity() -> "PosteriorCorrection":
        # beta = 1.0 means "no undersampling" -> T^C is the identity map.
        return PosteriorCorrection(beta=jnp.float32(1.0))


# ---------------------------------------------------------------------------
# Ensemble aggregation (Sec. 2.3.2)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Aggregation:
    """Weighted-average aggregation ``A`` over K calibrated expert scores.

    Weights are normalized at call time so that updating them (the paper's
    "lightweight model adaptation") never needs renormalization bookkeeping.
    """

    weights: Array  # (K,)

    def __call__(self, expert_scores: Array) -> Array:
        """``expert_scores``: (..., K) -> (...)."""
        w = self.weights / jnp.sum(self.weights)
        return jnp.einsum("...k,k->...", expert_scores, w)

    @staticmethod
    def uniform(k: int) -> "Aggregation":
        return Aggregation(weights=jnp.ones((k,), dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Quantile Mapping (Eq. 4)
# ---------------------------------------------------------------------------

def _searchsorted_branchless(table: Array, values: Array) -> Array:
    """TPU-friendly bucket search: index i s.t. table[i] <= v < table[i+1].

    The paper computes this with an O(log N) binary search on CPU.  On TPU a
    data-dependent branchy search is hostile to the VPU; an N-wide broadcast
    compare-and-sum is a handful of vector ops and keeps everything dense.
    Clamps to [0, N-2] so interpolation always has a right neighbour.
    """
    n = table.shape[-1]
    # sum over the table axis of (v >= q_i) gives #quantiles <= v; -1 -> index.
    idx = jnp.sum(values[..., None] >= table, axis=-1) - 1
    return jnp.clip(idx, 0, n - 2)


def quantile_map(
    scores: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Eq. 4: piecewise-linear map aligning CDF of S onto CDF of R.

    ``src_quantiles``/``ref_quantiles``: (N,) monotone non-decreasing arrays of
    matched quantiles q^S_i, q^R_i (same quantile levels).  The map is monotone
    (non-decreasing), hence rank/ROC preserving — the paper's key invariant.
    Values outside [q^S_1, q^S_N] are linearly extended from the edge segment
    and clipped to the reference support.
    """
    scores = jnp.asarray(scores)
    dtype = scores.dtype
    qs = src_quantiles.astype(dtype)
    qr = ref_quantiles.astype(dtype)
    i = _searchsorted_branchless(qs, scores)
    q_s_i = jnp.take(qs, i)
    q_s_n = jnp.take(qs, i + 1)
    q_r_i = jnp.take(qr, i)
    q_r_n = jnp.take(qr, i + 1)
    # Guard degenerate (flat) source segments.
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, jnp.asarray(1.0, dtype))
    slope = (q_r_n - q_r_i) / denom
    out = q_r_i + (scores - q_s_i) * slope
    return jnp.clip(out, qr[0], qr[-1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantileMap:
    """``T^Q`` node: tenant-specific source quantiles -> shared reference."""

    src_quantiles: Array  # (N,)
    ref_quantiles: Array  # (N,)

    def __call__(self, scores: Array) -> Array:
        return quantile_map(scores, self.src_quantiles, self.ref_quantiles)

    @property
    def num_quantiles(self) -> int:
        return self.src_quantiles.shape[-1]

    @staticmethod
    def identity(n: int = 64) -> "QuantileMap":
        q = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        return QuantileMap(src_quantiles=q, ref_quantiles=q)

    @staticmethod
    def fit(
        source_scores: np.ndarray | Array,
        ref_quantiles: Array,
        levels: np.ndarray | None = None,
    ) -> "QuantileMap":
        """Fit tenant-specific source quantiles from (unlabeled!) scores.

        This is the offline fitting path (Sec. 2.3.3): needs only raw score
        samples, no labels.  ``ref_quantiles`` must be evaluated at the same
        quantile ``levels`` (default: uniform grid of len(ref_quantiles)).
        """
        ref_q = np.asarray(ref_quantiles, dtype=np.float64)
        n = ref_q.shape[-1]
        if levels is None:
            levels = np.linspace(0.0, 1.0, n)
        src = np.quantile(np.asarray(source_scores, dtype=np.float64), levels)
        src = np.maximum.accumulate(src)  # enforce monotone vs fp jitter
        return QuantileMap(
            src_quantiles=jnp.asarray(src, dtype=jnp.float32),
            ref_quantiles=jnp.asarray(ref_q, dtype=jnp.float32),
        )


# ---------------------------------------------------------------------------
# Reference distributions (Sec. 2.3.3 / Sec. 7 of DESIGN.md)
# ---------------------------------------------------------------------------

def fraud_reference_quantiles(n: int = 256, *, a: float = 0.8, b: float = 8.0,
                              tail_w: float = 0.02, tail_a: float = 6.0,
                              tail_b: float = 1.5) -> Array:
    """A configurable reference distribution R with high density near 0 and a
    long tail toward 1 (the paper's guidance for imbalanced fraud settings:
    more resolution in the 0.1%–1% alert-rate region).

    Mixture: (1-tail_w)·Beta(a, b) + tail_w·Beta(tail_a, tail_b).
    Returns its quantiles on a uniform level grid, via numerical CDF inversion.
    """
    from scipy import stats  # offline path only

    levels = np.linspace(0.0, 1.0, n)
    grid = np.linspace(0.0, 1.0, 65537)
    cdf = (1.0 - tail_w) * stats.beta.cdf(grid, a, b) + tail_w * stats.beta.cdf(
        grid, tail_a, tail_b
    )
    q = np.interp(levels, cdf, grid)
    q = np.maximum.accumulate(q)
    return jnp.asarray(q, dtype=jnp.float32)


def uniform_reference_quantiles(n: int = 256) -> Array:
    return jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Full Eq. 2 pipeline (reference composition; fused kernel in kernels/)
# ---------------------------------------------------------------------------

def score_pipeline(
    expert_scores: Array,
    betas: Array,
    weights: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Eq. 2 end-to-end: ``T^Q(A([T^C_k(m_k(x))]))``.

    ``expert_scores``: (..., K) raw scores from the K experts.
    Pure-jnp composition; ``kernels/score_pipeline.py`` provides the fused
    Pallas version with identical semantics (this function is its oracle).
    """
    corrected = posterior_correction(expert_scores, betas)
    w = weights / jnp.sum(weights)
    agg = jnp.einsum("...k,k->...", corrected, w)
    return quantile_map(agg, src_quantiles, ref_quantiles)


# ---------------------------------------------------------------------------
# Tenant-indexed transform bank (mixed-tenant batched Eq. 2)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TransformBank:
    """Stacked per-(tenant, predictor) transform parameters.

    One row per distinct post-model pipeline; a mixed-tenant micro-batch
    carries a per-row ``tenant_idx`` selecting its bank row, so the whole
    batch runs Eq. 2 in ONE dispatch (``kernels/score_pipeline.py::
    score_pipeline_banked``) instead of a Python loop of per-predictor calls.
    This is MUSE's multi-tenant reuse made literal on the serving hot path.

    Banks are immutable and carry a ``generation`` (static metadata, not a
    traced leaf): the calibration control plane publishes a refreshed bank as
    a NEW object with a bumped generation and swaps the reference atomically.
    In-flight dispatches that already snapshotted the old bank finish on the
    old parameters; the next window sees the new generation — never a torn
    mix of rows from two calibration versions.
    """

    betas: Array          # (T, K)
    weights: Array        # (T, K)
    src_quantiles: Array  # (T, N)
    ref_quantiles: Array  # (T, N)
    generation: int = dataclasses.field(
        default=0, metadata=dict(static=True))

    @property
    def num_rows(self) -> int:
        return int(self.betas.shape[0])

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    @property
    def num_quantiles(self) -> int:
        return int(self.src_quantiles.shape[-1])

    def __call__(self, expert_scores: Array, tenant_idx: Array) -> Array:
        return banked_score_pipeline(
            expert_scores, tenant_idx, self.betas, self.weights,
            self.src_quantiles, self.ref_quantiles,
        )

    def pre_quantile(self, expert_scores: Array, tenant_idx: Array) -> Array:
        """Per-row T^Q input (corrected weighted aggregate) — what a
        refreshed T^Q must be fitted on; see TransformPipeline.pre_quantile.

        One jitted call: this sits on the serving hot path (quantile
        tracking, stage 3 of the banked dispatch), where an unfused chain of
        small dispatches measurably contends with the other engine stages."""
        return _banked_pre_quantile(expert_scores, tenant_idx, self.betas,
                                    self.weights)

    def with_rows(
        self,
        rows: Mapping[int, tuple[Array, Array]] | Mapping[int, "QuantileMap"],
        *,
        generation: int | None = None,
    ) -> "TransformBank":
        """Functional update: replace the T^Q tables of selected rows.

        ``rows`` maps row index -> ``QuantileMap`` (or a raw ``(src, ref)``
        pair).  Returns a NEW bank — the receiver is never mutated, so any
        dispatch holding it keeps scoring with the old parameters.  All
        replacement tables are scattered in one ``.at[idx].set`` per array.
        Tables narrower than the bank's N are edge-padded (flat segments are
        degenerate-guarded, same as ``from_params``); wider tables are a
        shape error.  ``generation`` defaults to the current one + 1.
        """
        if not rows:
            return self if generation is None else dataclasses.replace(
                self, generation=generation)
        n = self.num_quantiles
        idx, srcs, refs = [], [], []
        for row, value in sorted(rows.items()):
            if not 0 <= row < self.num_rows:
                raise IndexError(f"row {row} outside bank of {self.num_rows}")
            src, ref = (value.src_quantiles, value.ref_quantiles) \
                if isinstance(value, QuantileMap) else value
            src = jnp.asarray(src, jnp.float32)
            ref = jnp.asarray(ref, jnp.float32)
            pad = n - src.shape[-1]
            if pad < 0:
                raise ValueError(
                    f"row {row}: {src.shape[-1]} knots > bank's {n}")
            if pad:
                src = jnp.pad(src, (0, pad), mode="edge")
                ref = jnp.pad(ref, (0, pad), mode="edge")
            idx.append(row)
            srcs.append(src)
            refs.append(ref)
        idx = jnp.asarray(idx, jnp.int32)
        return dataclasses.replace(
            self,
            src_quantiles=self.src_quantiles.at[idx].set(jnp.stack(srcs)),
            ref_quantiles=self.ref_quantiles.at[idx].set(jnp.stack(refs)),
            generation=self.generation + 1 if generation is None else generation,
        )

    @staticmethod
    def from_params(params: Sequence[tuple[Array, Array, Array, Array]],
                    *, generation: int = 0) -> "TransformBank":
        """Stack (betas, weights, src_q, ref_q) rows, padding ragged axes.

        Expert axes are padded with ``beta=1, weight=0`` columns (identity
        correction, zero aggregation mass).  Quantile tables are padded by
        repeating the last knot: the extra flat segments are degenerate
        (guarded denominator) and values past the true support already clip
        to the reference edge, so padding is semantics-preserving.
        """
        if not params:
            raise ValueError("cannot build an empty TransformBank")
        rows = [(jnp.atleast_1d(jnp.asarray(b, jnp.float32)),
                 jnp.atleast_1d(jnp.asarray(w, jnp.float32)),
                 jnp.asarray(qs, jnp.float32), jnp.asarray(qr, jnp.float32))
                for b, w, qs, qr in params]
        k_max = max(b.shape[-1] for b, _, _, _ in rows)
        n_max = max(qs.shape[-1] for _, _, qs, _ in rows)

        def _pad_k(x, fill):
            pad = k_max - x.shape[-1]
            return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

        def _pad_n(x):
            pad = n_max - x.shape[-1]
            return jnp.pad(x, (0, pad), mode="edge") if pad else x

        return TransformBank(
            betas=jnp.stack([_pad_k(b, 1.0) for b, _, _, _ in rows]),
            weights=jnp.stack([_pad_k(w, 0.0) for _, w, _, _ in rows]),
            src_quantiles=jnp.stack([_pad_n(qs) for _, _, qs, _ in rows]),
            ref_quantiles=jnp.stack([_pad_n(qr) for _, _, _, qr in rows]),
            generation=generation,
        )


@jax.jit
def _banked_pre_quantile(expert_scores: Array, tenant_idx: Array,
                         betas: Array, weights: Array) -> Array:
    tenant_idx = jnp.asarray(tenant_idx, jnp.int32)
    b = jnp.take(betas, tenant_idx, axis=0)       # (B, K)
    w = jnp.take(weights, tenant_idx, axis=0)     # (B, K)
    corrected = posterior_correction(expert_scores, b)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.sum(corrected * w, axis=-1)


def banked_score_pipeline(
    expert_scores: Array,
    tenant_idx: Array,
    betas: Array,
    weights: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Mixed-tenant Eq. 2: row ``i`` uses parameter row ``tenant_idx[i]``.

    ``expert_scores``: (..., K); ``tenant_idx``: (...) int; bank params are
    (T, K) / (T, N).  Pure-jnp reference — the oracle for the banked Pallas
    kernel.  Weights are normalized per row (so padded expert columns with
    weight 0 contribute nothing).
    """
    expert_scores = jnp.asarray(expert_scores)
    tenant_idx = jnp.asarray(tenant_idx, jnp.int32)
    b = jnp.take(betas, tenant_idx, axis=0)            # (..., K)
    w = jnp.take(weights, tenant_idx, axis=0)          # (..., K)
    qs = jnp.take(src_quantiles, tenant_idx, axis=0)   # (..., N)
    qr = jnp.take(ref_quantiles, tenant_idx, axis=0)   # (..., N)
    corrected = posterior_correction(expert_scores, b)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    agg = jnp.sum(corrected * w, axis=-1)              # (...)

    dtype = agg.dtype
    qs = qs.astype(dtype)
    qr = qr.astype(dtype)
    n = qs.shape[-1]
    i = jnp.clip(jnp.sum(agg[..., None] >= qs, axis=-1) - 1, 0, n - 2)
    q_s_i = jnp.take_along_axis(qs, i[..., None], axis=-1)[..., 0]
    q_s_n = jnp.take_along_axis(qs, i[..., None] + 1, axis=-1)[..., 0]
    q_r_i = jnp.take_along_axis(qr, i[..., None], axis=-1)[..., 0]
    q_r_n = jnp.take_along_axis(qr, i[..., None] + 1, axis=-1)[..., 0]
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, jnp.asarray(1.0, dtype))
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    return jnp.clip(out, qr[..., 0], qr[..., -1])
