"""Composable score transformations (paper Sec. 2.3).

Three transformation nodes compose a predictor's post-model DAG:

  * :class:`PosteriorCorrection`  — ``T^C`` (Eq. 3), undoes undersampling bias.
  * :class:`Aggregation`          — ``A``, weighted average of calibrated experts.
  * :class:`QuantileMap`          — ``T^Q`` (Eq. 4), piecewise-linear CDF alignment.

All transforms are pure pytrees of arrays + static metadata so they can live
inside jitted serving steps, be donated, swapped (the paper's "seamless model
update" = replacing these pytrees under a stable routing intent), and sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Posterior Correction (Eq. 3)
# ---------------------------------------------------------------------------

def posterior_correction(scores: Array, beta: Array | float) -> Array:
    """Eq. 3: ``T^C(y) = beta*y / (1 - (1-beta)*y)``.

    ``beta`` is the undersampling ratio of the majority (negative) class used
    when training the expert: ``beta = P(keep negative sample)``.  Scores are
    posterior probabilities in [0, 1].  The map is monotone, fixes 0 and 1,
    and is the exact analytical inverse of the prior shift introduced by
    undersampling (Dal Pozzolo et al., 2015).
    """
    scores = jnp.asarray(scores)
    beta = jnp.asarray(beta, dtype=scores.dtype)
    return (beta * scores) / (1.0 - (1.0 - beta) * scores)


def posterior_correction_inverse(corrected: Array, beta: Array | float) -> Array:
    """Inverse of Eq. 3 — maps a true posterior back to the biased score.

    Used by the synthetic data pipeline to *induce* undersampling bias with a
    known ground truth, and in tests as the round-trip oracle.
    """
    corrected = jnp.asarray(corrected)
    beta = jnp.asarray(beta, dtype=corrected.dtype)
    return corrected / (beta + (1.0 - beta) * corrected)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PosteriorCorrection:
    """Per-expert ``T^C_k`` node: carries the training undersampling ratio."""

    beta: Array  # scalar (or broadcastable) undersampling ratio in (0, 1]

    def __call__(self, scores: Array) -> Array:
        return posterior_correction(scores, self.beta)

    @staticmethod
    def identity() -> "PosteriorCorrection":
        # beta = 1.0 means "no undersampling" -> T^C is the identity map.
        return PosteriorCorrection(beta=jnp.float32(1.0))


# ---------------------------------------------------------------------------
# Ensemble aggregation (Sec. 2.3.2)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Aggregation:
    """Weighted-average aggregation ``A`` over K calibrated expert scores.

    Weights are normalized at call time so that updating them (the paper's
    "lightweight model adaptation") never needs renormalization bookkeeping.
    """

    weights: Array  # (K,)

    def __call__(self, expert_scores: Array) -> Array:
        """``expert_scores``: (..., K) -> (...)."""
        w = self.weights / jnp.sum(self.weights)
        return jnp.einsum("...k,k->...", expert_scores, w)

    @staticmethod
    def uniform(k: int) -> "Aggregation":
        return Aggregation(weights=jnp.ones((k,), dtype=jnp.float32))


# ---------------------------------------------------------------------------
# Quantile Mapping (Eq. 4)
# ---------------------------------------------------------------------------

def _searchsorted_branchless(table: Array, values: Array) -> Array:
    """TPU-friendly bucket search: index i s.t. table[i] <= v < table[i+1].

    The paper computes this with an O(log N) binary search on CPU.  On TPU a
    data-dependent branchy search is hostile to the VPU; an N-wide broadcast
    compare-and-sum is a handful of vector ops and keeps everything dense.
    Clamps to [0, N-2] so interpolation always has a right neighbour.
    """
    n = table.shape[-1]
    # sum over the table axis of (v >= q_i) gives #quantiles <= v; -1 -> index.
    idx = jnp.sum(values[..., None] >= table, axis=-1) - 1
    return jnp.clip(idx, 0, n - 2)


def quantile_map(
    scores: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Eq. 4: piecewise-linear map aligning CDF of S onto CDF of R.

    ``src_quantiles``/``ref_quantiles``: (N,) monotone non-decreasing arrays of
    matched quantiles q^S_i, q^R_i (same quantile levels).  The map is monotone
    (non-decreasing), hence rank/ROC preserving — the paper's key invariant.
    Values outside [q^S_1, q^S_N] are linearly extended from the edge segment
    and clipped to the reference support.
    """
    scores = jnp.asarray(scores)
    dtype = scores.dtype
    qs = src_quantiles.astype(dtype)
    qr = ref_quantiles.astype(dtype)
    i = _searchsorted_branchless(qs, scores)
    q_s_i = jnp.take(qs, i)
    q_s_n = jnp.take(qs, i + 1)
    q_r_i = jnp.take(qr, i)
    q_r_n = jnp.take(qr, i + 1)
    # Guard degenerate (flat) source segments.
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, jnp.asarray(1.0, dtype))
    slope = (q_r_n - q_r_i) / denom
    out = q_r_i + (scores - q_s_i) * slope
    return jnp.clip(out, qr[0], qr[-1])


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantileMap:
    """``T^Q`` node: tenant-specific source quantiles -> shared reference."""

    src_quantiles: Array  # (N,)
    ref_quantiles: Array  # (N,)

    def __call__(self, scores: Array) -> Array:
        return quantile_map(scores, self.src_quantiles, self.ref_quantiles)

    @property
    def num_quantiles(self) -> int:
        return self.src_quantiles.shape[-1]

    @staticmethod
    def identity(n: int = 64) -> "QuantileMap":
        q = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
        return QuantileMap(src_quantiles=q, ref_quantiles=q)

    @staticmethod
    def fit(
        source_scores: np.ndarray | Array,
        ref_quantiles: Array,
        levels: np.ndarray | None = None,
    ) -> "QuantileMap":
        """Fit tenant-specific source quantiles from (unlabeled!) scores.

        This is the offline fitting path (Sec. 2.3.3): needs only raw score
        samples, no labels.  ``ref_quantiles`` must be evaluated at the same
        quantile ``levels`` (default: uniform grid of len(ref_quantiles)).
        """
        ref_q = np.asarray(ref_quantiles, dtype=np.float64)
        n = ref_q.shape[-1]
        if levels is None:
            levels = np.linspace(0.0, 1.0, n)
        src = np.quantile(np.asarray(source_scores, dtype=np.float64), levels)
        src = np.maximum.accumulate(src)  # enforce monotone vs fp jitter
        return QuantileMap(
            src_quantiles=jnp.asarray(src, dtype=jnp.float32),
            ref_quantiles=jnp.asarray(ref_q, dtype=jnp.float32),
        )


# ---------------------------------------------------------------------------
# Reference distributions (Sec. 2.3.3 / Sec. 7 of DESIGN.md)
# ---------------------------------------------------------------------------

def fraud_reference_quantiles(n: int = 256, *, a: float = 0.8, b: float = 8.0,
                              tail_w: float = 0.02, tail_a: float = 6.0,
                              tail_b: float = 1.5) -> Array:
    """A configurable reference distribution R with high density near 0 and a
    long tail toward 1 (the paper's guidance for imbalanced fraud settings:
    more resolution in the 0.1%–1% alert-rate region).

    Mixture: (1-tail_w)·Beta(a, b) + tail_w·Beta(tail_a, tail_b).
    Returns its quantiles on a uniform level grid, via numerical CDF inversion.
    """
    from scipy import stats  # offline path only

    levels = np.linspace(0.0, 1.0, n)
    grid = np.linspace(0.0, 1.0, 65537)
    cdf = (1.0 - tail_w) * stats.beta.cdf(grid, a, b) + tail_w * stats.beta.cdf(
        grid, tail_a, tail_b
    )
    q = np.interp(levels, cdf, grid)
    q = np.maximum.accumulate(q)
    return jnp.asarray(q, dtype=jnp.float32)


def uniform_reference_quantiles(n: int = 256) -> Array:
    return jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Full Eq. 2 pipeline (reference composition; fused kernel in kernels/)
# ---------------------------------------------------------------------------

def score_pipeline(
    expert_scores: Array,
    betas: Array,
    weights: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Eq. 2 end-to-end: ``T^Q(A([T^C_k(m_k(x))]))``.

    ``expert_scores``: (..., K) raw scores from the K experts.
    Pure-jnp composition; ``kernels/score_pipeline.py`` provides the fused
    Pallas version with identical semantics (this function is its oracle).
    """
    corrected = posterior_correction(expert_scores, betas)
    w = weights / jnp.sum(weights)
    agg = jnp.einsum("...k,k->...", corrected, w)
    return quantile_map(agg, src_quantiles, ref_quantiles)


def pad_quantile_tables(
    value: "QuantileMap | tuple[Array, Array]", n: int, *, row: int | None = None,
) -> tuple[Array, Array]:
    """Normalize one replacement T^Q table pair to exactly ``n`` knots.

    ``value`` is a :class:`QuantileMap` or a raw ``(src, ref)`` pair.  Tables
    narrower than ``n`` are edge-padded: the extra flat segments are
    degenerate (guarded denominator in :func:`quantile_map`) and values past
    the true support already clip to the reference edge, so padding is
    semantics-preserving.  Wider tables are a shape error.  Shared by both
    bank ``with_rows`` scatters and the tiered store's host-row writes
    (``serving/tiering.py``), which must pad identically for the tiered
    path to stay bitwise-equal to a dense bank built from the same rows.
    """
    src, ref = (value.src_quantiles, value.ref_quantiles) \
        if isinstance(value, QuantileMap) else value
    src = jnp.asarray(src, jnp.float32)
    ref = jnp.asarray(ref, jnp.float32)
    pad = n - src.shape[-1]
    if pad < 0:
        where = f"row {row}: " if row is not None else ""
        raise ValueError(f"{where}{src.shape[-1]} knots > bank's {n}")
    if pad:
        src = jnp.pad(src, (0, pad), mode="edge")
        ref = jnp.pad(ref, (0, pad), mode="edge")
    return src, ref


# ---------------------------------------------------------------------------
# Tenant-indexed transform bank (mixed-tenant batched Eq. 2)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TransformBank:
    """Stacked per-(tenant, predictor) transform parameters.

    One row per distinct post-model pipeline; a mixed-tenant micro-batch
    carries a per-row ``tenant_idx`` selecting its bank row, so the whole
    batch runs Eq. 2 in ONE dispatch (``kernels/score_pipeline.py::
    score_pipeline_banked``) instead of a Python loop of per-predictor calls.
    This is MUSE's multi-tenant reuse made literal on the serving hot path.

    Banks are immutable and carry a ``generation`` (static metadata, not a
    traced leaf): the calibration control plane publishes a refreshed bank as
    a NEW object with a bumped generation and swaps the reference atomically.
    In-flight dispatches that already snapshotted the old bank finish on the
    old parameters; the next window sees the new generation — never a torn
    mix of rows from two calibration versions.
    """

    betas: Array          # (T, K)
    weights: Array        # (T, K)
    src_quantiles: Array  # (T, N)
    ref_quantiles: Array  # (T, N)
    generation: int = dataclasses.field(
        default=0, metadata=dict(static=True))

    @property
    def num_rows(self) -> int:
        return int(self.betas.shape[0])

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    @property
    def num_quantiles(self) -> int:
        return int(self.src_quantiles.shape[-1])

    def __call__(self, expert_scores: Array, tenant_idx: Array) -> Array:
        return banked_score_pipeline(
            expert_scores, tenant_idx, self.betas, self.weights,
            self.src_quantiles, self.ref_quantiles,
        )

    def pre_quantile(self, expert_scores: Array, tenant_idx: Array) -> Array:
        """Per-row T^Q input (corrected weighted aggregate) — what a
        refreshed T^Q must be fitted on; see TransformPipeline.pre_quantile.

        One jitted call: this sits on the serving hot path (quantile
        tracking, stage 3 of the banked dispatch), where an unfused chain of
        small dispatches measurably contends with the other engine stages."""
        return _banked_pre_quantile(expert_scores, tenant_idx, self.betas,
                                    self.weights)

    def with_rows(
        self,
        rows: Mapping[int, tuple[Array, Array]] | Mapping[int, "QuantileMap"],
        *,
        generation: int | None = None,
    ) -> "TransformBank":
        """Functional update: replace the T^Q tables of selected rows.

        ``rows`` maps row index -> ``QuantileMap`` (or a raw ``(src, ref)``
        pair).  Returns a NEW bank — the receiver is never mutated, so any
        dispatch holding it keeps scoring with the old parameters.  All
        replacement tables are scattered in one ``.at[idx].set`` per array.
        Tables narrower than the bank's N are edge-padded (flat segments are
        degenerate-guarded, same as ``from_params``); wider tables are a
        shape error.  ``generation`` defaults to the current one + 1.
        """
        if not rows:
            return self if generation is None else dataclasses.replace(
                self, generation=generation)
        n = self.num_quantiles
        idx, srcs, refs = [], [], []
        for row, value in sorted(rows.items()):
            if not 0 <= row < self.num_rows:
                raise IndexError(f"row {row} outside bank of {self.num_rows}")
            src, ref = pad_quantile_tables(value, n, row=row)
            idx.append(row)
            srcs.append(src)
            refs.append(ref)
        idx = jnp.asarray(idx, jnp.int32)
        return dataclasses.replace(
            self,
            src_quantiles=self.src_quantiles.at[idx].set(jnp.stack(srcs)),
            ref_quantiles=self.ref_quantiles.at[idx].set(jnp.stack(refs)),
            generation=self.generation + 1 if generation is None else generation,
        )

    @staticmethod
    def from_params(params: Sequence[tuple[Array, Array, Array, Array]],
                    *, generation: int = 0) -> "TransformBank":
        """Stack (betas, weights, src_q, ref_q) rows, padding ragged axes.

        Expert axes are padded with ``beta=1, weight=0`` columns (identity
        correction, zero aggregation mass).  Quantile tables are padded by
        repeating the last knot: the extra flat segments are degenerate
        (guarded denominator) and values past the true support already clip
        to the reference edge, so padding is semantics-preserving.
        """
        if not params:
            raise ValueError("cannot build an empty TransformBank")
        rows = [(jnp.atleast_1d(jnp.asarray(b, jnp.float32)),
                 jnp.atleast_1d(jnp.asarray(w, jnp.float32)),
                 jnp.asarray(qs, jnp.float32), jnp.asarray(qr, jnp.float32))
                for b, w, qs, qr in params]
        k_max = max(b.shape[-1] for b, _, _, _ in rows)
        n_max = max(qs.shape[-1] for _, _, qs, _ in rows)

        def _pad_k(x, fill):
            pad = k_max - x.shape[-1]
            return jnp.pad(x, (0, pad), constant_values=fill) if pad else x

        def _pad_n(x):
            pad = n_max - x.shape[-1]
            return jnp.pad(x, (0, pad), mode="edge") if pad else x

        return TransformBank(
            betas=jnp.stack([_pad_k(b, 1.0) for b, _, _, _ in rows]),
            weights=jnp.stack([_pad_k(w, 0.0) for _, w, _, _ in rows]),
            src_quantiles=jnp.stack([_pad_n(qs) for _, _, qs, _ in rows]),
            ref_quantiles=jnp.stack([_pad_n(qr) for _, _, _, qr in rows]),
            generation=generation,
        )


@jax.jit
def _banked_pre_quantile(expert_scores: Array, tenant_idx: Array,
                         betas: Array, weights: Array) -> Array:
    tenant_idx = jnp.asarray(tenant_idx, jnp.int32)
    b = jnp.take(betas, tenant_idx, axis=0)       # (B, K)
    w = jnp.take(weights, tenant_idx, axis=0)     # (B, K)
    corrected = posterior_correction(expert_scores, b)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return jnp.sum(corrected * w, axis=-1)


# ---------------------------------------------------------------------------
# Tenant-sharded transform bank (mesh row partition, ROADMAP "Sharded
# transform banks")
# ---------------------------------------------------------------------------

TENANT_AXIS = "tenants"  # mesh axis name the bank rows are partitioned over


def shard_rows(num_rows: int, num_shards: int,
               shard_of: np.ndarray | None = None
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Row-partition rule shared by every sharded container.

    Assigns each of ``num_rows`` global rows an owning shard (default:
    round-robin ``t % S``, occupancy within one row of even) and a local
    id in global-row order within the shard.  Both
    :meth:`ShardedTransformBank.from_dense` and the tiered-over-sharded
    store (``serving/tiering.ShardedTieredBankStore``) derive their
    global↔local remaps from THIS function, so a hotness snapshot or a
    publish addressed by global row id lands on the same (shard, local)
    coordinates whichever container serves it.

    Returns ``(shard_of, local_of, row_counts)``; local ids are assigned
    vectorized (publishes run under the control-plane lock, so an O(T)
    Python loop would serialize the fleet at large T).
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    assign = (np.arange(num_rows) % num_shards if shard_of is None
              else np.asarray(shard_of, np.int64).reshape(-1))
    if assign.shape[0] != num_rows:
        raise ValueError(
            f"shard_of has {assign.shape[0]} entries for {num_rows} rows")
    if assign.size and (assign.min() < 0 or assign.max() >= num_shards):
        raise ValueError("shard_of entries outside [0, num_shards)")
    counts = np.bincount(assign, minlength=num_shards).astype(np.int64)
    order = np.argsort(assign, kind="stable")
    starts = np.cumsum(counts) - counts
    local = np.empty(num_rows, np.int64)
    local[order] = np.arange(num_rows) - np.repeat(starts, counts)
    return assign, local, counts


@dataclasses.dataclass(frozen=True, eq=False)
class ShardedTransformBank:
    """A :class:`TransformBank` row-partitioned over a mesh "tenants" axis.

    The dense bank stacks EVERY (tenant, predictor) row on every replica —
    the wall past ~10k tenants.  This container splits the rows over S
    shards: parameter arrays carry a leading shard axis ((S, Tl, K) /
    (S, Tl, N), ``Tl = max shard occupancy``) so ``shard_map`` placement
    over the "tenants" axis leaves each device holding ONLY its local rows
    (``per_shard_bytes`` ≈ dense/S).  ``shard_of``/``local_of`` are the
    host-side global↔local tenant-id remap the serving layer buckets
    requests with; occupancy may be uneven and shards may be empty (rows
    beyond ``row_counts[s]`` are inert identity padding — no request ever
    selects them).

    Like the dense bank, a sharded bank is immutable and generation-stamped:
    ``with_rows`` scatters refreshed T^Q tables ONLY into each row's owning
    shard and returns a NEW object under one bumped generation, so a
    calibration publish swaps every shard's sub-bank in the same single
    control-plane assignment — per-shard generations can never diverge.
    """

    betas: Array          # (S, Tl, K)
    weights: Array        # (S, Tl, K)
    src_quantiles: Array  # (S, Tl, N)
    ref_quantiles: Array  # (S, Tl, N)
    shard_of: np.ndarray  # (T,) owning shard per global bank row
    local_of: np.ndarray  # (T,) local row within the owning shard
    row_counts: np.ndarray  # (S,) occupied rows per shard
    generation: int = 0

    # ------------------------------------------------------------ geometry
    @property
    def num_shards(self) -> int:
        return int(self.betas.shape[0])

    @property
    def num_rows(self) -> int:
        return int(self.shard_of.shape[0])

    @property
    def rows_per_shard(self) -> int:
        return int(self.betas.shape[1])

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    @property
    def num_quantiles(self) -> int:
        return int(self.src_quantiles.shape[-1])

    @property
    def per_shard_bytes(self) -> int:
        """Bank bytes RESIDENT on one shard (the 1/S residency headline)."""
        tl, k, n = self.rows_per_shard, self.num_experts, self.num_quantiles
        return tl * (2 * k + 2 * n) * 4

    def locate(self, tenant_idx) -> tuple[np.ndarray, np.ndarray]:
        """Global row ids -> (owning shard, local row) — the dispatch remap."""
        tid = np.asarray(tenant_idx, np.int64).reshape(-1)
        return self.shard_of[tid], self.local_of[tid]

    # --------------------------------------------------------- conversions
    @staticmethod
    def from_dense(bank: TransformBank, num_shards: int,
                   shard_of: np.ndarray | None = None
                   ) -> "ShardedTransformBank":
        """Partition a dense bank's rows over ``num_shards`` shards.

        ``shard_of`` (optional, (T,)) assigns each global row an owning
        shard — any assignment is legal, including empty shards.  Default is
        round-robin (``t % S``), which keeps occupancy within one row of
        even.  Local ids are assigned in global-row order within each shard;
        shards are padded to the max occupancy with identity rows
        (beta=1, weight=1, identity quantile table) that no request selects.
        """
        t = bank.num_rows
        assign, local, counts = shard_rows(t, num_shards, shard_of)
        tl = max(int(counts.max()) if counts.size else 0, 1)
        k, n = bank.num_experts, bank.num_quantiles

        betas = np.ones((num_shards, tl, k), np.float32)
        weights = np.ones((num_shards, tl, k), np.float32)
        ident = np.linspace(0.0, 1.0, n, dtype=np.float32)
        src = np.broadcast_to(ident, (num_shards, tl, n)).copy()
        ref = src.copy()
        b_np = np.asarray(bank.betas)
        w_np = np.asarray(bank.weights)
        qs_np = np.asarray(bank.src_quantiles)
        qr_np = np.asarray(bank.ref_quantiles)
        betas[assign, local] = b_np
        weights[assign, local] = w_np
        src[assign, local] = qs_np
        ref[assign, local] = qr_np
        return ShardedTransformBank(
            betas=jnp.asarray(betas), weights=jnp.asarray(weights),
            src_quantiles=jnp.asarray(src), ref_quantiles=jnp.asarray(ref),
            shard_of=assign, local_of=local, row_counts=counts,
            generation=bank.generation)

    def shard_bank(self, shard: int) -> TransformBank:
        """The dense sub-bank one shard serves (its occupied local rows)."""
        c = int(self.row_counts[shard])
        c = max(c, 1)  # empty shard: expose one (inert) identity row
        return TransformBank(
            betas=self.betas[shard, :c], weights=self.weights[shard, :c],
            src_quantiles=self.src_quantiles[shard, :c],
            ref_quantiles=self.ref_quantiles[shard, :c],
            generation=self.generation)

    def to_dense(self) -> TransformBank:
        """Reassemble the global dense bank (parity/inspection path)."""
        sh = jnp.asarray(self.shard_of)
        lo = jnp.asarray(self.local_of)
        return TransformBank(
            betas=self.betas[sh, lo], weights=self.weights[sh, lo],
            src_quantiles=self.src_quantiles[sh, lo],
            ref_quantiles=self.ref_quantiles[sh, lo],
            generation=self.generation)

    # ------------------------------------------------------------- updates
    def with_rows(
        self,
        rows: Mapping[int, tuple[Array, Array]] | Mapping[int, "QuantileMap"],
        *,
        generation: int | None = None,
    ) -> "ShardedTransformBank":
        """Functional T^Q update addressed by GLOBAL row id.

        Each replacement table is scattered only into its row's owning
        shard ((shard, local) indices, one ``.at[].set`` per array); every
        other shard's rows are carried over untouched.  Semantics otherwise
        match :meth:`TransformBank.with_rows` (edge-padding of narrow
        tables, generation defaulting to current + 1).
        """
        if not rows:
            return self if generation is None else dataclasses.replace(
                self, generation=generation)
        n = self.num_quantiles
        s_idx, l_idx, srcs, refs = [], [], [], []
        for row, value in sorted(rows.items()):
            if not 0 <= row < self.num_rows:
                raise IndexError(f"row {row} outside bank of {self.num_rows}")
            src, ref = pad_quantile_tables(value, n, row=row)
            s_idx.append(int(self.shard_of[row]))
            l_idx.append(int(self.local_of[row]))
            srcs.append(src)
            refs.append(ref)
        s_idx = jnp.asarray(s_idx, jnp.int32)
        l_idx = jnp.asarray(l_idx, jnp.int32)
        return dataclasses.replace(
            self,
            src_quantiles=self.src_quantiles.at[s_idx, l_idx].set(
                jnp.stack(srcs)),
            ref_quantiles=self.ref_quantiles.at[s_idx, l_idx].set(
                jnp.stack(refs)),
            generation=self.generation + 1 if generation is None else generation,
        )


def banked_score_pipeline(
    expert_scores: Array,
    tenant_idx: Array,
    betas: Array,
    weights: Array,
    src_quantiles: Array,
    ref_quantiles: Array,
) -> Array:
    """Mixed-tenant Eq. 2: row ``i`` uses parameter row ``tenant_idx[i]``.

    ``expert_scores``: (..., K); ``tenant_idx``: (...) int; bank params are
    (T, K) / (T, N).  Pure-jnp reference — the oracle for the banked Pallas
    kernel.  Weights are normalized per row (so padded expert columns with
    weight 0 contribute nothing).
    """
    expert_scores = jnp.asarray(expert_scores)
    tenant_idx = jnp.asarray(tenant_idx, jnp.int32)
    b = jnp.take(betas, tenant_idx, axis=0)            # (..., K)
    w = jnp.take(weights, tenant_idx, axis=0)          # (..., K)
    qs = jnp.take(src_quantiles, tenant_idx, axis=0)   # (..., N)
    qr = jnp.take(ref_quantiles, tenant_idx, axis=0)   # (..., N)
    corrected = posterior_correction(expert_scores, b)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    agg = jnp.sum(corrected * w, axis=-1)              # (...)

    dtype = agg.dtype
    qs = qs.astype(dtype)
    qr = qr.astype(dtype)
    n = qs.shape[-1]
    i = jnp.clip(jnp.sum(agg[..., None] >= qs, axis=-1) - 1, 0, n - 2)
    q_s_i = jnp.take_along_axis(qs, i[..., None], axis=-1)[..., 0]
    q_s_n = jnp.take_along_axis(qs, i[..., None] + 1, axis=-1)[..., 0]
    q_r_i = jnp.take_along_axis(qr, i[..., None], axis=-1)[..., 0]
    q_r_n = jnp.take_along_axis(qr, i[..., None] + 1, axis=-1)[..., 0]
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, jnp.asarray(1.0, dtype))
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    return jnp.clip(out, qr[..., 0], qr[..., -1])
