"""Shared experimental substrate for the paper's evaluation scenarios.

Builds a miniature Feedzai-world with known ground truth:

  * tenants with distinct data distributions (feature shift, fraud rate);
  * expert models = logistic scorers trained on *undersampled* tenant data
    (undersampling ratio beta per expert — the bias T^C must undo);
  * ensembles + transformation pipelines wired through the MUSE core.

Every benchmark (Figs. 4-6, Table 1) and example driver instantiates this
world so numbers are directly comparable across experiments.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.coldstart import fit_beta_mixture, default_quantile_map
from repro.core.predictor import PredictorSpec
from repro.core.transforms import QuantileMap, fraud_reference_quantiles
from repro.training.data import (
    FraudEventStream,
    TenantProfile,
    fit_logistic_expert,
    logistic_expert_scores,
)

DIM = 16


@dataclasses.dataclass
class Expert:
    name: str
    beta: float                  # undersampling ratio used in training
    w: np.ndarray
    b: float
    feature_mask: np.ndarray     # which features this expert sees

    def score(self, x: np.ndarray) -> np.ndarray:
        return logistic_expert_scores(x * self.feature_mask, self.w, self.b)

    def score_fn(self):
        mask, w, b = self.feature_mask, self.w, self.b

        def fn(x):
            x = np.asarray(x, np.float32)
            return jnp.asarray(
                1.0 / (1.0 + np.exp(-((x * mask) @ w + b))), jnp.float32
            )

        return fn


def train_expert(stream: FraudEventStream, name: str, beta: float,
                 *, n_train: int = 60_000, mask_seed: int = 0,
                 mask_keep: float = 1.0) -> Expert:
    """Train a logistic expert on beta-undersampled data from ``stream``."""
    rng = np.random.default_rng(mask_seed)
    mask = (rng.random(DIM) < mask_keep).astype(np.float64)
    if mask.sum() == 0:
        mask[:] = 1.0
    x, y = stream.sample_undersampled(n_train, beta=beta)
    w, b = fit_logistic_expert(x * mask, y, seed=mask_seed)
    return Expert(name=name, beta=beta, w=w, b=b, feature_mask=mask)


@dataclasses.dataclass
class FraudWorld:
    """The cross-experiment fixture."""

    train_tenant: FraudEventStream
    client: FraudEventStream          # live client with shifted distribution
    experts: dict[str, Expert]
    ref_quantiles: np.ndarray         # shared reference distribution R

    @staticmethod
    def build(*, n_experts: int = 3, betas: tuple[float, ...] = (0.18, 0.18, 0.02),
              client_shift: float = 0.35, client_fraud_rate: float = 0.008,
              seed: int = 0, n_ref: int = 256) -> "FraudWorld":
        train_tenant = FraudEventStream(
            TenantProfile("train-pool", fraud_rate=0.01, seed=seed)
        )
        client = FraudEventStream(
            TenantProfile("bank1", fraud_rate=client_fraud_rate,
                          feature_shift=client_shift, seed=seed + 100)
        )
        experts = {}
        for i in range(n_experts):
            beta = betas[i % len(betas)]
            experts[f"m{i + 1}"] = train_expert(
                train_tenant, f"m{i + 1}", beta,
                mask_seed=seed + i, mask_keep=1.0 if i == 0 else 0.8,
            )
        ref = np.asarray(fraud_reference_quantiles(n_ref))
        return FraudWorld(train_tenant, client, experts, ref)

    # ------------------------------------------------------------------
    def ensemble_raw_scores(self, names: tuple[str, ...], x: np.ndarray
                            ) -> np.ndarray:
        """(n, K) raw expert scores."""
        return np.stack([self.experts[n].score(x) for n in names], axis=-1)

    def ensemble_aggregated(self, names: tuple[str, ...], x: np.ndarray,
                            *, corrected: bool = True) -> np.ndarray:
        """Posterior-corrected (optional) equal-weight aggregation."""
        from repro.core.transforms import posterior_correction
        raw = self.ensemble_raw_scores(names, x)
        if corrected:
            betas = np.array([self.experts[n].beta for n in names])
            raw = np.asarray(posterior_correction(jnp.asarray(raw),
                                                  jnp.asarray(betas)))
        return raw.mean(axis=-1)

    def coldstart_quantile_map(self, names: tuple[str, ...],
                               *, n_scores: int = 60_000, seed: int = 7,
                               n_trials: int = 3) -> QuantileMap:
        """T^Q_v0: Beta-mixture prior fit on TRAINING-pool ensemble scores."""
        x, y = self.train_tenant.sample(n_scores)
        agg = self.ensemble_aggregated(names, x)
        fit = fit_beta_mixture(agg, fraud_prior=float(np.mean(y)),
                               n_trials=n_trials, seed=seed)
        return default_quantile_map(fit, self.ref_quantiles)

    def custom_quantile_map(self, names: tuple[str, ...], x_client: np.ndarray
                            ) -> QuantileMap:
        """T^Q_v1: fitted on (unlabeled) client traffic through the ensemble."""
        agg = self.ensemble_aggregated(names, x_client)
        return QuantileMap.fit(agg, jnp.asarray(self.ref_quantiles, jnp.float32))

    def predictor_spec(self, name: str, names: tuple[str, ...],
                       qm: QuantileMap) -> PredictorSpec:
        betas = tuple(self.experts[n].beta for n in names)
        weights = (1.0,) * len(names)
        return PredictorSpec(name, names, betas, weights, qm)

    def model_factories(self):
        return {n: (lambda e=e: e.score_fn()) for n, e in self.experts.items()}
