"""Shared experimental substrate for the paper's evaluation scenarios.

Builds a miniature Feedzai-world with known ground truth:

  * tenants with distinct data distributions (feature shift, fraud rate);
  * expert models = logistic scorers trained on *undersampled* tenant data
    (undersampling ratio beta per expert — the bias T^C must undo);
  * ensembles + transformation pipelines wired through the MUSE core.

Every benchmark (Figs. 4-6, Table 1) and example driver instantiates this
world so numbers are directly comparable across experiments.

Adversarial attack campaigns
----------------------------

MUSE's pitch is resilience against *shifting attacks*; the related work
(Full-range Calibration, arXiv 2607.05481) stresses the regime where the
malicious score distribution drifts FAST while benign stays stable.
:class:`AttackCampaign` models exactly that on top of the fraud world:

  * **benign stays stationary** — every day's legitimate events are drawn
    from the tenant's fixed :class:`~repro.training.data.TenantProfile`
    distribution (same mean, same covariance, same fraud direction);
  * **malicious drifts per wave** — an :class:`AttackWave` targets specific
    tenants for a span of days, multiplying their fraud rate (burstiness)
    and moving the malicious class-conditional mean TOWARD the decision
    boundary: the fraud separation is scaled down per wave and decays
    further each day inside the wave (``drift_per_day``), and a
    ``boundary_mass`` fraction of fraud events is drawn even closer to the
    boundary (mass migration into the region where thresholds live);
  * **scripted multi-day schedules** — ``schedule()`` materializes the
    per-day picture (active waves, effective drift parameters, model
    promotion days) so a replay harness can interleave
    ``RollingUpdate`` promotions with attack waves deterministically.

Sampling is DETERMINISTIC and order-independent: ``sample(tenant, day, n)``
derives a fresh PRNG from ``(seed, tenant, day)``, so identical seeds give
bitwise-identical streams no matter in which order days or tenants are
drawn (the seed-determinism regression in ``tests/test_attack_campaign.py``
locks this down).
"""
from __future__ import annotations

import dataclasses
import zlib

import jax.numpy as jnp
import numpy as np

from repro.core.coldstart import fit_beta_mixture, default_quantile_map
from repro.core.predictor import PredictorSpec
from repro.core.transforms import QuantileMap, fraud_reference_quantiles
from repro.training.data import (
    FraudEventStream,
    TenantProfile,
    fit_logistic_expert,
    logistic_expert_scores,
)

DIM = 16


@dataclasses.dataclass
class Expert:
    name: str
    beta: float                  # undersampling ratio used in training
    w: np.ndarray
    b: float
    feature_mask: np.ndarray     # which features this expert sees

    def score(self, x: np.ndarray) -> np.ndarray:
        return logistic_expert_scores(x * self.feature_mask, self.w, self.b)

    def score_fn(self):
        mask, w, b = self.feature_mask, self.w, self.b

        def fn(x):
            x = np.asarray(x, np.float32)
            return jnp.asarray(
                1.0 / (1.0 + np.exp(-((x * mask) @ w + b))), jnp.float32
            )

        return fn


def train_expert(stream: FraudEventStream, name: str, beta: float,
                 *, n_train: int = 60_000, mask_seed: int = 0,
                 mask_keep: float = 1.0) -> Expert:
    """Train a logistic expert on beta-undersampled data from ``stream``."""
    rng = np.random.default_rng(mask_seed)
    mask = (rng.random(DIM) < mask_keep).astype(np.float64)
    if mask.sum() == 0:
        mask[:] = 1.0
    x, y = stream.sample_undersampled(n_train, beta=beta)
    w, b = fit_logistic_expert(x * mask, y, seed=mask_seed)
    return Expert(name=name, beta=beta, w=w, b=b, feature_mask=mask)


@dataclasses.dataclass
class FraudWorld:
    """The cross-experiment fixture."""

    train_tenant: FraudEventStream
    client: FraudEventStream          # live client with shifted distribution
    experts: dict[str, Expert]
    ref_quantiles: np.ndarray         # shared reference distribution R

    @staticmethod
    def build(*, n_experts: int = 3, betas: tuple[float, ...] = (0.18, 0.18, 0.02),
              client_shift: float = 0.35, client_fraud_rate: float = 0.008,
              seed: int = 0, n_ref: int = 256) -> "FraudWorld":
        train_tenant = FraudEventStream(
            TenantProfile("train-pool", fraud_rate=0.01, seed=seed)
        )
        client = FraudEventStream(
            TenantProfile("bank1", fraud_rate=client_fraud_rate,
                          feature_shift=client_shift, seed=seed + 100)
        )
        experts = {}
        for i in range(n_experts):
            beta = betas[i % len(betas)]
            experts[f"m{i + 1}"] = train_expert(
                train_tenant, f"m{i + 1}", beta,
                mask_seed=seed + i, mask_keep=1.0 if i == 0 else 0.8,
            )
        ref = np.asarray(fraud_reference_quantiles(n_ref))
        return FraudWorld(train_tenant, client, experts, ref)

    # ------------------------------------------------------------------
    def ensemble_raw_scores(self, names: tuple[str, ...], x: np.ndarray
                            ) -> np.ndarray:
        """(n, K) raw expert scores."""
        return np.stack([self.experts[n].score(x) for n in names], axis=-1)

    def ensemble_aggregated(self, names: tuple[str, ...], x: np.ndarray,
                            *, corrected: bool = True) -> np.ndarray:
        """Posterior-corrected (optional) equal-weight aggregation."""
        from repro.core.transforms import posterior_correction
        raw = self.ensemble_raw_scores(names, x)
        if corrected:
            betas = np.array([self.experts[n].beta for n in names])
            raw = np.asarray(posterior_correction(jnp.asarray(raw),
                                                  jnp.asarray(betas)))
        return raw.mean(axis=-1)

    def coldstart_quantile_map(self, names: tuple[str, ...],
                               *, n_scores: int = 60_000, seed: int = 7,
                               n_trials: int = 3) -> QuantileMap:
        """T^Q_v0: Beta-mixture prior fit on TRAINING-pool ensemble scores."""
        x, y = self.train_tenant.sample(n_scores)
        agg = self.ensemble_aggregated(names, x)
        fit = fit_beta_mixture(agg, fraud_prior=float(np.mean(y)),
                               n_trials=n_trials, seed=seed)
        return default_quantile_map(fit, self.ref_quantiles)

    def custom_quantile_map(self, names: tuple[str, ...], x_client: np.ndarray
                            ) -> QuantileMap:
        """T^Q_v1: fitted on (unlabeled) client traffic through the ensemble."""
        agg = self.ensemble_aggregated(names, x_client)
        return QuantileMap.fit(agg, jnp.asarray(self.ref_quantiles, jnp.float32))

    def predictor_spec(self, name: str, names: tuple[str, ...],
                       qm: QuantileMap) -> PredictorSpec:
        betas = tuple(self.experts[n].beta for n in names)
        weights = (1.0,) * len(names)
        return PredictorSpec(name, names, betas, weights, qm)

    def model_factories(self):
        return {n: (lambda e=e: e.score_fn()) for n, e in self.experts.items()}


# ---------------------------------------------------------------------------
# Adversarial attack campaigns
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttackWave:
    """One bursty, tenant-targeted wave of fast-drifting malicious traffic.

    During the wave the targeted tenants' fraud rate is multiplied by
    ``fraud_multiplier`` (the burst) and the malicious class-conditional
    mean moves toward the decision boundary: fraud events are generated at
    ``separation_scale`` of the world's base class separation, decaying by
    ``drift_per_day`` every day the wave ages (fast intra-wave drift), and
    a ``boundary_mass`` fraction of them is drawn at an additional
    ``boundary_scale`` contraction — the mass migration into the threshold
    region.  Benign events are untouched.
    """

    name: str
    targets: tuple[str, ...]
    start_day: int
    duration: int
    fraud_multiplier: float = 6.0
    separation_scale: float = 0.55
    drift_per_day: float = 0.06
    boundary_mass: float = 0.5
    boundary_scale: float = 0.55
    min_scale: float = 0.08

    def active_on(self, day: int) -> bool:
        return self.start_day <= day < self.start_day + self.duration

    def effective_scale(self, day: int) -> float:
        """Separation scale on ``day`` — drifts down as the wave ages."""
        age = max(day - self.start_day, 0)
        return max(self.separation_scale - self.drift_per_day * age,
                   self.min_scale)


@dataclasses.dataclass(frozen=True)
class CampaignDay:
    """One materialized day of the scripted schedule."""

    day: int
    waves: tuple[str, ...]            # active wave names
    promote: bool                     # a model promotion runs this day
    # per-tenant effective malicious parameters for the day:
    # tenant -> (fraud_multiplier, separation_scale, boundary_mass)
    tenant_params: dict[str, tuple[float, float, float]]


@dataclasses.dataclass
class AttackCampaign:
    """Multi-day adversarial schedule over a set of tenant streams.

    ``tenants`` maps tenant name -> the BENIGN generative profile (held
    stationary for the whole campaign); ``waves`` and ``promotion_days``
    script the adversarial timeline.  ``sample`` is pure in
    ``(seed, tenant, day)`` — see the module docstring.
    """

    tenants: dict[str, TenantProfile]
    waves: tuple[AttackWave, ...]
    promotion_days: tuple[int, ...]
    n_days: int
    dim: int = DIM
    seed: int = 0
    separation: float = 2.2           # FraudEventStream's class separation

    # ------------------------------------------------------------- schedule
    def waves_on(self, day: int, tenant: str) -> list[AttackWave]:
        return [w for w in self.waves
                if w.active_on(day) and tenant in w.targets]

    def day_params(self, day: int, tenant: str
                   ) -> tuple[float, float, float]:
        """Effective (fraud_multiplier, separation_scale, boundary_mass)
        for one tenant-day; quiet days are (1, 1, 0)."""
        active = self.waves_on(day, tenant)
        if not active:
            return 1.0, 1.0, 0.0
        mult = 1.0
        scale = 1.0
        bmass = 0.0
        for w in active:               # overlapping waves compound
            mult *= w.fraud_multiplier
            scale = min(scale, w.effective_scale(day))
            bmass = max(bmass, w.boundary_mass)
        return mult, scale, bmass

    def schedule(self) -> list[CampaignDay]:
        """The scripted multi-day timeline, fully materialized."""
        out = []
        for day in range(self.n_days):
            names = tuple(w.name for w in self.waves if w.active_on(day))
            out.append(CampaignDay(
                day=day, waves=names, promote=day in self.promotion_days,
                tenant_params={t: self.day_params(day, t)
                               for t in self.tenants}))
        return out

    # -------------------------------------------------------------- sampling
    def _direction(self, tenant: str) -> np.ndarray:
        # identical construction to FraudEventStream: crc32-keyed so the
        # campaign's fraud direction matches the tenant's benign stream
        rng = np.random.default_rng(zlib.crc32(tenant.encode()))
        d = rng.normal(0, 1, self.dim)
        return d / np.linalg.norm(d)

    def sample(self, tenant: str, day: int, n: int
               ) -> tuple[np.ndarray, np.ndarray]:
        """(features (n, dim), labels (n,)) for one tenant-day.

        Deterministic in (seed, tenant, day): the PRNG is derived fresh per
        call, so replays are bitwise-identical regardless of draw order.
        """
        profile = self.tenants[tenant]
        rng = np.random.default_rng(
            [self.seed, zlib.crc32(tenant.encode()), day])
        mult, scale, bmass = self.day_params(day, tenant)
        rate = min(profile.fraud_rate * mult, 0.5)
        y = (rng.random(n) < rate).astype(np.int64)
        # benign: STATIONARY — same distribution every day of the campaign
        x = rng.normal(0, 1, (n, self.dim)) + profile.feature_shift
        direction = self._direction(tenant)
        # malicious: per-wave drifted separation; a boundary_mass fraction
        # migrates further toward the decision boundary
        sep = np.full(n, self.separation * scale)
        if bmass > 0.0:
            near = rng.random(n) < bmass
            sep = np.where(near, sep * min(
                w.boundary_scale for w in self.waves_on(day, tenant)), sep)
        x += (y * sep)[:, None] * direction[None, :]
        return x.astype(np.float32), y

    # --------------------------------------------------------------- builder
    @staticmethod
    def build(tenant_names: tuple[str, ...],
              *, n_days: int = 10, n_waves: int = 2,
              promotion_days: tuple[int, ...] = (2, 6),
              fraud_rate: float = 0.01, feature_shift: float = 0.25,
              seed: int = 0, dim: int = DIM) -> "AttackCampaign":
        """Script a deterministic campaign: ``n_waves`` bursty waves with
        staggered starts, each targeting one tenant round-robin, interleaved
        with the given model-promotion days."""
        rng = np.random.default_rng([seed, 0xA77AC4])
        tenants = {
            t: TenantProfile(t, fraud_rate=fraud_rate * (1 + 0.2 * i),
                             feature_shift=feature_shift + 0.05 * i,
                             seed=seed + 900 + i)
            for i, t in enumerate(tenant_names)
        }
        waves = []
        quiet = max((n_days - 2) // max(n_waves, 1), 2)
        for k in range(n_waves):
            start = 2 + k * quiet + int(rng.integers(0, 2))
            waves.append(AttackWave(
                name=f"wave{k}",
                targets=(tenant_names[k % len(tenant_names)],),
                start_day=min(start, n_days - 2),
                duration=int(rng.integers(2, max(quiet, 3))),
                fraud_multiplier=float(rng.uniform(4.0, 8.0)),
                separation_scale=float(rng.uniform(0.45, 0.65)),
                drift_per_day=float(rng.uniform(0.04, 0.10)),
                boundary_mass=float(rng.uniform(0.3, 0.6)),
            ))
        return AttackCampaign(tenants=tenants, waves=tuple(waves),
                              promotion_days=promotion_days, n_days=n_days,
                              dim=dim, seed=seed)
