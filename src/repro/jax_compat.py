"""Compatibility shims for jax APIs that moved between releases.

The repo targets the newest jax surface (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.sharding.get_abstract_mesh``) but must also
run on jax 0.4.x, where shard_map lives in ``jax.experimental`` (with
``check_rep`` instead of ``check_vma``), meshes are activated purely via the
``with mesh:`` context, and there are no axis types.  Every call site goes
through this module instead of feature-testing jax inline.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax
from jax.sharding import Mesh

_HAS_SET_MESH = hasattr(jax, "set_mesh")


def make_mesh(axis_shapes: tuple[int, ...], axis_names: tuple[str, ...],
              *, auto_axes: bool = True) -> Mesh:
    """``jax.make_mesh`` with AxisType.Auto where supported, plain otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if auto_axes and axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def set_mesh(mesh: Mesh):
    """Context manager activating ``mesh`` for sharding-aware tracing.

    On new jax this is ``jax.set_mesh``; on 0.4.x the ``with mesh:`` physical
    context (which call sites already enter) is the only mechanism, so this
    degrades to a no-op.
    """
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return contextlib.nullcontext(mesh)


def get_active_mesh() -> Any:
    """The mesh in scope for the current trace (abstract or physical)."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        mesh = getter()
        if mesh is not None and getattr(mesh, "axis_names", ()):
            return mesh
    from jax._src import mesh as _mesh_src  # 0.4.x fallback
    return _mesh_src.thread_resources.env.physical_mesh


def shard_map(f: Callable | None = None, *, mesh: Any, in_specs: Any,
              out_specs: Any, check_vma: bool = True) -> Callable:
    """``jax.shard_map`` when available, else the experimental one.

    The replication-checking kwarg was renamed ``check_rep`` -> ``check_vma``;
    we accept the new name and translate.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
    else:
        from jax.experimental.shard_map import shard_map as impl  # noqa: N813
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
    if f is None:
        return lambda fn: impl(fn, **kwargs)
    return impl(f, **kwargs)
