"""Pallas decode attention: one query position against a long KV cache.

The decode hot loop is memory-bound (stream the cache once); the kernel
blocks over the sequence axis of the cache with online-softmax accumulation
in VMEM scratch (flash-decoding shape), GQA-aware: the (qpk, D) query-head
group for one KV head rides along each cache tile so the MXU sees a
(qpk, D) x (D, BS) matmul per tile instead of qpk separate dot products.

``valid_len`` masks unwritten cache slots (per batch row) — ring-buffer
sliding-window caches pass their window capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _decode_kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_s: int):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (qpk, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (BS, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (BS, D)
    valid = vlen_ref[0]                               # scalar int32

    s = (q @ k.T) * scale                             # (qpk, BS)
    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < valid, s, -jnp.inf)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1)
    acc_scr[...] = alpha[:, None] * acc_scr[...] + p @ v
    m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     valid_len: Array, *, block_s: int = 512,
                     interpret: bool = True) -> Array:
    """q: (B, Hq, D); caches: (B, S, Hkv, D); valid_len: (B,) -> (B, Hq, D)."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    qpk = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_s = min(block_s, s)
    pad_s = (-s) % block_s
    qg = q.reshape(b, hkv, qpk, d)
    kt = jnp.moveaxis(k_cache, 1, 2)                  # (B, Hkv, S, D)
    vt = jnp.moveaxis(v_cache, 1, 2)
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    s_p = s + pad_s
    vlen = jnp.minimum(jnp.asarray(valid_len, jnp.int32).reshape(b), s)

    kernel = functools.partial(_decode_kernel, scale=scale, block_s=block_s)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, s_p // block_s),
        in_specs=[
            pl.BlockSpec((1,), lambda b_, h, si: (b_,)),
            pl.BlockSpec((1, 1, qpk, d), lambda b_, h, si: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b_, h, si: (b_, h, si, 0)),
            pl.BlockSpec((1, 1, block_s, d), lambda b_, h, si: (b_, h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, d), lambda b_, h, si: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, qpk, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk, d), jnp.float32),
        ],
        interpret=interpret,
    )(vlen, qg, kt, vt)
    return out.reshape(b, hq, d)
