"""Pallas flash attention (GQA + causal + sliding window) for TPU.

Blocked online-softmax attention: grid (B, Hq, Tq/BQ, Tk/BK) with the K axis
innermost (sequential on TPU), carrying running max / denominator / output in
VMEM scratch.  Tiles are MXU-aligned (block sizes multiples of 128 at real
sizes); GQA maps query head h to KV head h // (Hq/Hkv) in the K/V BlockSpec
index maps, so KV tiles are fetched once per query-head group.

Numerics: masked logits are -inf; the running max is guarded so fully-masked
tiles (above the causal diagonal / outside the sliding window) contribute
exactly zero without NaNs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, sliding_window: int,
                  block_q: int, block_k: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (BK, D)

    s = (q @ k.T) * scale                             # (BQ, BK)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= qpos >= kpos
    if sliding_window > 0:
        mask &= kpos > qpos - sliding_window
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])                  # masked -> exp(-inf)=0
    alpha = jnp.where(jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_scr[...] = alpha[:, None] * acc_scr[...] + p @ v
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    sliding_window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> Array:
    """q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, D) -> (B, Tq, Hq, D)."""
    b, tq, hq, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k

    qt = jnp.moveaxis(q, 1, 2)                        # (B, Hq, Tq, D)
    kt = jnp.moveaxis(k, 1, 2)                        # (B, Hkv, Tk, D)
    vt = jnp.moveaxis(v, 1, 2)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    tq_p, tk_p = tq + pad_q, tk + pad_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        sliding_window=sliding_window, block_q=block_q, block_k=block_k,
        seq_k=tk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, tq_p // block_q, tk_p // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki: (b_, h // qpk, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, qi, ki: (b_, h // qpk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :tq], 2, 1)         # (B, Tq, Hq, D)
