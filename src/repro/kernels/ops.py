"""Jit'd public entry points for the Pallas kernels.

On this CPU container every kernel runs in ``interpret=True`` (the Pallas
interpreter executes the kernel body exactly); on TPU set
``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to compile to Mosaic.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import quantile_map as _qm
from repro.kernels import score_pipeline as _sp

Array = jax.Array

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def quantile_map(scores: Array, src_quantiles: Array, ref_quantiles: Array,
                 *, block: int = _qm.DEFAULT_BLOCK,
                 interpret: bool | None = None) -> Array:
    return _qm.quantile_map(
        scores, src_quantiles, ref_quantiles, block=block,
        interpret=_INTERPRET if interpret is None else interpret,
    )


def score_pipeline(expert_scores: Array, betas: Array, weights: Array,
                   src_quantiles: Array, ref_quantiles: Array,
                   *, block: int = _sp.DEFAULT_BLOCK,
                   interpret: bool | None = None) -> Array:
    return _sp.score_pipeline(
        expert_scores, betas, weights, src_quantiles, ref_quantiles,
        block=block, interpret=_INTERPRET if interpret is None else interpret,
    )


def banked_skip_stats(tenant_idx, *, block: int = _sp.DEFAULT_BLOCK) -> dict:
    """Host-side uniform-block fast-path report for a tenant layout (see
    :func:`repro.kernels.score_pipeline.banked_skip_stats`)."""
    return _sp.banked_skip_stats(tenant_idx, block=block)


def score_pipeline_banked(expert_scores: Array, tenant_idx: Array,
                          betas: Array, weights: Array,
                          src_quantiles: Array, ref_quantiles: Array,
                          *, block: int = _sp.DEFAULT_BLOCK,
                          interpret: bool | None = None) -> Array:
    return _sp.score_pipeline_banked(
        expert_scores, tenant_idx, betas, weights, src_quantiles,
        ref_quantiles, block=block,
        interpret=_INTERPRET if interpret is None else interpret,
    )


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    sliding_window: int = 0, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None) -> Array:
    return _fa.flash_attention(
        q, k, v, causal=causal, sliding_window=sliding_window,
        block_q=block_q, block_k=block_k,
        interpret=_INTERPRET if interpret is None else interpret,
    )


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     valid_len: Array, *, block_s: int = 512,
                     interpret: bool | None = None) -> Array:
    return _da.decode_attention(
        q, k_cache, v_cache, valid_len, block_s=block_s,
        interpret=_INTERPRET if interpret is None else interpret,
    )
