"""Pallas TPU kernel for the paper's Quantile Mapping T^Q (Eq. 4).

TPU adaptation (DESIGN.md §2/§6): the paper's O(log N) binary search is a
branchy scalar loop — poison for the VPU.  Here the quantile tables (N <= 2048
f32 values) sit in VMEM; the bucket index is a **branchless compare-and-sum**
(one (BLOCK, N) vector compare + row reduction), and the four table lookups
(q^S_i, q^S_{i+1}, q^R_i, q^R_{i+1}) become a single one-hot (BLOCK, N) x
(N, 2) matmul on the MXU — no data-dependent control flow anywhere.

Grid: 1-D over score blocks; tables are broadcast to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK = 1024


def _quantile_map_kernel(scores_ref, src_ref, ref_ref, out_ref):
    s = scores_ref[...].astype(jnp.float32)          # (BLOCK,)
    qs = src_ref[...].astype(jnp.float32)            # (N,)
    qr = ref_ref[...].astype(jnp.float32)            # (N,)
    n = qs.shape[-1]

    # branchless bucket search: idx = #(q_i <= s) - 1, clipped to [0, N-2]
    ge = (s[:, None] >= qs[None, :]).astype(jnp.float32)   # (BLOCK, N)
    idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)

    # one-hot gather of the 4 table values as 2 MXU matvecs
    iota = jax.lax.broadcasted_iota(jnp.float32, (s.shape[0], n), 1)
    onehot_i = (iota == idx[:, None]).astype(jnp.float32)        # (BLOCK, N)
    onehot_ip1 = (iota == (idx + 1.0)[:, None]).astype(jnp.float32)
    tables = jnp.stack([qs, qr], axis=-1)                        # (N, 2)
    lo = onehot_i @ tables                                       # (BLOCK, 2)
    hi = onehot_ip1 @ tables
    q_s_i, q_r_i = lo[:, 0], lo[:, 1]
    q_s_n, q_r_n = hi[:, 0], hi[:, 1]

    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
    out = q_r_i + (s - q_s_i) * (q_r_n - q_r_i) / denom
    out = jnp.clip(out, qr[0], qr[-1])
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantile_map(scores: Array, src_quantiles: Array, ref_quantiles: Array,
                 *, block: int = DEFAULT_BLOCK, interpret: bool = True) -> Array:
    """Flat or batched scores -> mapped scores (same shape/dtype)."""
    shape = scores.shape
    flat = scores.reshape(-1)
    n = flat.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    total = flat.shape[0]
    nq = src_quantiles.shape[-1]

    out = pl.pallas_call(
        _quantile_map_kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), scores.dtype),
        interpret=interpret,
    )(flat, src_quantiles, ref_quantiles)
    return out[:n].reshape(shape)
