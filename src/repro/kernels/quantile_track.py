"""Fused on-device quantile tracking: score -> transform -> track, one dispatch.

The track stage (``MuseServer.track``) was the last serial host loop on the
data plane: every window synced its posterior-corrected aggregate back to
host (``np.asarray``) and then ran one numpy reservoir update per (tenant,
predictor) stream under the estimator lock.  This module moves the hot path
onto the device:

* :func:`fused_track_append` is ONE jitted program that computes the banked
  ``pre_quantile`` aggregate (the exact op sequence of
  :func:`repro.core.transforms._banked_pre_quantile` — it is inlined, so the
  two can never drift) and scatters each row into a per-stream device
  staging buffer.  No host transfer, no per-stream Python on the hot path.
* :class:`DeviceQuantileTracker` owns the staging buffers (control-plane
  state) and the bookkeeping that makes the deferred host materialization
  BITWISE identical to eager tracking, including RNG state.

Why vectorized segment ops instead of a Pallas grid: the scatter targets are
data-dependent (stream slot x pending offset), which maps naturally onto one
XLA scatter with host-planned unique indices, while the aggregate reuses the
already-fused banked math.  A Pallas kernel would re-implement the same
scatter without the bitwise-parity guarantee that inlining
``_banked_pre_quantile`` gives for free.

Exactness contract (why replay is bitwise, not approximate):

* Per-stream estimators are independent, and a
  :class:`~repro.core.quantiles.StreamingQuantileEstimator`'s state after a
  sequence of ``update`` calls depends only on the sample values and the
  UPDATE-CALL BOUNDARIES (the recent ring resets on >=capacity bulk writes
  and the PCG64 draws are consumed per overflow batch).  The eager path
  issues exactly one ``update`` per stream per window.
* The tracker therefore records, per stream, the cumulative sample count at
  every window boundary.  Draining replays ``update`` once per ORIGINAL
  window chunk, in arrival order, against the same host estimator class —
  reservoir, recent ring, pointers, seen counts and RNG state come out
  bit-for-bit equal to eager tracking (asserted by
  ``tests/test_device_tracking.py``).
* Scatter indices are ``slot * capacity + pending + within-window rank`` —
  unique by construction, so the scatter is deterministic and needs no
  device RNG (``unique_indices=True`` + ``mode="promise_in_bounds"`` are
  safe and let XLA skip the dedup/clamp paths).

Host pulls happen ONLY at the calibration boundary: a stream spills when its
staging would overflow, and the calibration plane (Eq.-5 gating, snapshots,
fleet merge) calls :meth:`DeviceQuantileTracker.sync` before reading
estimators.  Thread-safety: the owner (``MuseServer``) serializes every
tracker call under its estimator lock; the tracker itself is not locked.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import _banked_pre_quantile

# chunks replayed at drain time must respect the estimator's documented
# per-update-call bound; windows are far smaller in practice (engine cap)
DEFAULT_STAGING = 4096


@functools.partial(jax.jit, donate_argnums=(0,))
def _fused_append(staging: jax.Array, flat_idx: jax.Array,
                  expert_scores: jax.Array, tenant_idx: jax.Array,
                  betas: jax.Array, weights: jax.Array) -> jax.Array:
    """score -> transform -> track in one XLA program.

    ``staging`` is the flat ``(slots * capacity,)`` f32 staging plane
    (donated: updated in place, the caller rebinds the result).  The
    aggregate is the inlined ``_banked_pre_quantile`` jaxpr — bitwise the
    value the eager host path would have pulled."""
    agg = _banked_pre_quantile(expert_scores, tenant_idx, betas, weights)
    return staging.at[flat_idx].set(
        agg, mode="promise_in_bounds", unique_indices=True)


@functools.partial(jax.jit, donate_argnums=(0,))
def _staged_append(staging: jax.Array, flat_idx: jax.Array,
                   agg: jax.Array) -> jax.Array:
    """Scatter an already-computed aggregate (tiered stores compute
    ``pre_quantile`` against host-paged rows, so only the append fuses)."""
    return staging.at[flat_idx].set(
        jnp.asarray(agg, jnp.float32),
        mode="promise_in_bounds", unique_indices=True)


def _segment_plan(slots: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
    """Vectorized per-window segment bookkeeping over the row->slot vector.

    Returns ``(ranks, uniq_slots, incoming)``: each row's 0-based arrival
    rank within its stream, the unique slots present, and the per-unique-
    slot row counts.  Stable sort keeps arrival order inside a stream —
    the property the bitwise replay contract rests on."""
    b = len(slots)
    order = np.argsort(slots, kind="stable")
    sorted_slots = slots[order]
    new_seg = np.r_[True, sorted_slots[1:] != sorted_slots[:-1]]
    seg_start = np.flatnonzero(new_seg)
    ranks_sorted = np.arange(b, dtype=np.int64) - \
        np.repeat(seg_start, np.diff(np.r_[seg_start, b]))
    ranks = np.empty(b, dtype=np.int64)
    ranks[order] = ranks_sorted
    return ranks, sorted_slots[seg_start], np.diff(np.r_[seg_start, b])


class DeviceQuantileTracker:
    """Device staging plane for per-(tenant, predictor) quantile streams.

    ``apply(key, chunks)`` is the host-materialization callback: it must
    route each chunk list into the stream's estimator via one
    ``update`` call per chunk (see
    :meth:`~repro.core.quantiles.StreamingQuantileEstimator.apply_chunks`).
    The owner calls every method under one lock.
    """

    def __init__(self, apply: Callable[[tuple, list[np.ndarray]], None], *,
                 staging_capacity: int = DEFAULT_STAGING,
                 initial_slots: int = 64) -> None:
        if staging_capacity < 1:
            raise ValueError("staging_capacity must be >= 1")
        self.capacity = int(staging_capacity)
        self._apply = apply
        self._slots: dict[tuple, int] = {}        # stream key -> slot
        self._slot_key: dict[int, tuple] = {}
        self._free: list[int] = []
        self._num_slots = int(initial_slots)
        self._counts = np.zeros(self._num_slots, dtype=np.int64)
        # per-slot cumulative sample counts at each appended window's end —
        # the replay boundaries that make drain bitwise-equal to eager
        self._bounds: list[list[int]] = [[] for _ in range(self._num_slots)]
        self._staging = jnp.zeros((self._num_slots * self.capacity,),
                                  jnp.float32)
        # observability: spills (staging-full drains) and windows that fell
        # back to the eager host path because one stream outsized the plane
        self.spills = 0
        self.host_fallbacks = 0
        self.appends = 0

    # ------------------------------------------------------------- capacity
    def _alloc(self, key: tuple) -> int:
        if self._free:
            slot = self._free.pop()
        else:
            slot = len(self._slots)
            if slot >= self._num_slots:
                self._grow(slot + 1)
        self._slots[key] = slot
        self._slot_key[slot] = key
        return slot

    def _grow(self, needed: int) -> None:
        new_n = self._num_slots
        while new_n < needed:
            new_n *= 2   # doubling bounds recompiles to O(log streams)
        pad = (new_n - self._num_slots) * self.capacity
        self._staging = jnp.concatenate(
            [self._staging, jnp.zeros((pad,), jnp.float32)])
        self._counts = np.concatenate(
            [self._counts, np.zeros(new_n - self._num_slots, np.int64)])
        self._bounds.extend([] for _ in range(new_n - self._num_slots))
        self._num_slots = new_n

    # --------------------------------------------------------------- append
    def _plan(self, keys: list[tuple]) -> np.ndarray | None:
        """Spill-aware scatter plan for one window; None => host fallback.

        Updates counts/bounds as if the append already happened, so the
        caller MUST follow a non-None plan with the device scatter."""
        slots = np.empty(len(keys), dtype=np.int64)
        for j, key in enumerate(keys):
            s = self._slots.get(key)
            slots[j] = self._alloc(key) if s is None else s
        ranks, uniq, incoming = _segment_plan(slots)
        if int(incoming.max()) > self.capacity:
            # one stream's share of this window outsizes the whole staging
            # plane — drain its history first (order!), then let the caller
            # take the eager path for the entire window
            self.host_fallbacks += 1
            self._drain_slots(uniq)
            return None
        over = uniq[self._counts[uniq] + incoming > self.capacity]
        if len(over):
            self.spills += 1
            self._drain_slots(over)
        flat_idx = slots * self.capacity + self._counts[slots] + ranks
        self._counts[uniq] += incoming
        for s, inc in zip(uniq, incoming):
            self._bounds[s].append(int(self._counts[s]))
        self.appends += 1
        return flat_idx.astype(np.int32)

    def append_fused(self, keys: list[tuple], raws: np.ndarray,
                     tenant_idx: np.ndarray, bank: Any) -> bool:
        """Stage one window through the fused program (dense banks).

        Returns False when the window must take the eager host path (a
        single stream larger than the staging plane)."""
        if not keys:
            return True
        flat_idx = self._plan(keys)
        if flat_idx is None:
            return False
        self._staging = _fused_append(
            self._staging, jnp.asarray(flat_idx),
            jnp.asarray(raws, jnp.float32), jnp.asarray(tenant_idx),
            bank.betas, bank.weights)
        return True

    def append_agg(self, keys: list[tuple], agg: Any) -> bool:
        """Stage one window whose aggregate is already computed (tiered
        stores page ``pre_quantile`` through host rows)."""
        if not keys:
            return True
        flat_idx = self._plan(keys)
        if flat_idx is None:
            return False
        self._staging = _staged_append(
            self._staging, jnp.asarray(flat_idx), jnp.asarray(agg))
        return True

    # ---------------------------------------------------------------- drain
    def _drain_slots(self, slots: Any) -> int:
        todo = [int(s) for s in slots if self._counts[s] > 0]
        if not todo:
            return 0
        host = np.asarray(self._staging)   # ONE device->host pull
        drained = 0
        for s in todo:
            n = int(self._counts[s])
            scores = host[s * self.capacity : s * self.capacity + n].copy()
            chunks = np.split(scores, self._bounds[s][:-1])
            self._apply(self._slot_key[s], chunks)
            self._counts[s] = 0
            self._bounds[s] = []
            drained += n
        return drained

    def sync(self) -> int:
        """Materialize every staged sample into its host estimator (the
        calibration plane's host-pull boundary).  Returns samples drained."""
        return self._drain_slots(np.flatnonzero(self._counts > 0))

    # ------------------------------------------------------------ ownership
    def pending(self, key: tuple) -> int:
        """Samples staged on device but not yet in the host estimator."""
        s = self._slots.get(key)
        return 0 if s is None else int(self._counts[s])

    def pending_total(self) -> int:
        return int(self._counts.sum())

    def drop_where(self, predicate: Callable[[tuple], bool]) -> int:
        """Discard streams (staged data included) whose key matches —
        decommission support: a dead predictor's staged samples must never
        materialize into a revived stream."""
        dead = [k for k in self._slots if predicate(k)]
        for key in dead:
            slot = self._slots.pop(key)
            del self._slot_key[slot]
            self._counts[slot] = 0
            self._bounds[slot] = []
            self._free.append(slot)
        return len(dead)
