"""Pure-jnp oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# quantile map (paper Eq. 4) — oracle = core.transforms.quantile_map
# ---------------------------------------------------------------------------

def quantile_map(scores: Array, src_q: Array, ref_q: Array) -> Array:
    from repro.core.transforms import quantile_map as _qm
    return _qm(scores, src_q, ref_q)


# ---------------------------------------------------------------------------
# fused score pipeline (paper Eq. 2) — oracle = core.transforms.score_pipeline
# ---------------------------------------------------------------------------

def score_pipeline(expert_scores: Array, betas: Array, weights: Array,
                   src_q: Array, ref_q: Array) -> Array:
    from repro.core.transforms import score_pipeline as _sp
    return _sp(expert_scores, betas, weights, src_q, ref_q)


# ---------------------------------------------------------------------------
# banked (tenant-indexed) score pipeline — oracle =
# core.transforms.banked_score_pipeline
# ---------------------------------------------------------------------------

def score_pipeline_banked(expert_scores: Array, tenant_idx: Array,
                          betas: Array, weights: Array,
                          src_q: Array, ref_q: Array) -> Array:
    from repro.core.transforms import banked_score_pipeline as _bsp
    return _bsp(expert_scores, tenant_idx, betas, weights, src_q, ref_q)


# ---------------------------------------------------------------------------
# flash attention (GQA, causal / sliding window)
# ---------------------------------------------------------------------------

def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    sliding_window: int = 0) -> Array:
    """Naive exact attention. q: (B,Tq,Hq,D); k,v: (B,Tk,Hkv,D)."""
    b, tq, hq, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    qh = q.reshape(b, tq, hkv, qpk, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos >= kpos
    if sliding_window > 0:
        mask &= kpos > qpos - sliding_window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, tq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention (single query position over a KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     valid_len: Array | int) -> Array:
    """q: (B,Hq,D); caches: (B,S,Hkv,D); attends to positions < valid_len."""
    b, hq, d = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    qpk = hq // hkv
    qh = q.reshape(b, hkv, qpk, d).astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < jnp.asarray(valid_len)[..., None]
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)
