"""Fused Pallas kernel for the full Eq. 2 post-model pipeline:

    T^Q( A( [T^C_k(y_k)]_k ) )   —  posterior correction -> weighted
                                     aggregation -> quantile map

One VMEM pass over a (BLOCK, K) score tile instead of K+2 HBM round trips:
the correction is elementwise, the aggregation a (BLOCK,K)x(K,) matvec, and
the quantile map reuses the branchless compare-and-sum + one-hot-matmul
lookup of kernels/quantile_map.py.  This kernel IS the paper's transformation
DAG as a single fused op — the serving hot path for every scored event.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK = 1024


def _score_pipeline_kernel(scores_ref, betas_ref, weights_ref, src_ref,
                           ref_ref, out_ref):
    y = scores_ref[...].astype(jnp.float32)          # (BLOCK, K)
    beta = betas_ref[...].astype(jnp.float32)        # (K,)
    w = weights_ref[...].astype(jnp.float32)         # (K,)
    qs = src_ref[...].astype(jnp.float32)            # (N,)
    qr = ref_ref[...].astype(jnp.float32)

    # --- T^C: posterior correction (Eq. 3), elementwise on the VPU
    corrected = beta[None, :] * y / (1.0 - (1.0 - beta[None, :]) * y)

    # --- A: weighted average (self-normalizing), one matvec
    w_norm = w / jnp.sum(w)
    agg = corrected @ w_norm                          # (BLOCK,)

    # --- T^Q: branchless piecewise-linear quantile map (Eq. 4)
    n = qs.shape[-1]
    ge = (agg[:, None] >= qs[None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)
    iota = jax.lax.broadcasted_iota(jnp.float32, (agg.shape[0], n), 1)
    onehot_i = (iota == idx[:, None]).astype(jnp.float32)
    onehot_ip1 = (iota == (idx + 1.0)[:, None]).astype(jnp.float32)
    tables = jnp.stack([qs, qr], axis=-1)
    lo = onehot_i @ tables
    hi = onehot_ip1 @ tables
    q_s_i, q_r_i = lo[:, 0], lo[:, 1]
    q_s_n, q_r_n = hi[:, 0], hi[:, 1]
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    out_ref[...] = jnp.clip(out, qr[0], qr[-1]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def score_pipeline(expert_scores: Array, betas: Array, weights: Array,
                   src_quantiles: Array, ref_quantiles: Array,
                   *, block: int = DEFAULT_BLOCK, interpret: bool = True
                   ) -> Array:
    """expert_scores: (..., K) -> (...) business-ready scores."""
    *batch_shape, k = expert_scores.shape
    flat = expert_scores.reshape(-1, k)
    n = flat.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    total = flat.shape[0]
    nq = src_quantiles.shape[-1]

    out = pl.pallas_call(
        _score_pipeline_kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), expert_scores.dtype),
        interpret=interpret,
    )(flat, betas, weights, src_quantiles, ref_quantiles)
    return out[:n].reshape(batch_shape)
