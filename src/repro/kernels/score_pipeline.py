"""Fused Pallas kernels for the full Eq. 2 post-model pipeline:

    T^Q( A( [T^C_k(y_k)]_k ) )   —  posterior correction -> weighted
                                     aggregation -> quantile map

One VMEM pass over a (BLOCK, K) score tile instead of K+2 HBM round trips:
the correction is elementwise, the aggregation a (BLOCK,K)x(K,) matvec, and
the quantile map reuses the branchless compare-and-sum + one-hot-matmul
lookup of kernels/quantile_map.py.  This kernel IS the paper's transformation
DAG as a single fused op — the serving hot path for every scored event.

Two entry points:

  * :func:`score_pipeline`        — one shared (betas, weights, q-tables)
                                    parameter set for the whole batch.
  * :func:`score_pipeline_banked` — tenant-indexed: parameters are (T, ·)
                                    banks and each row carries a
                                    ``tenant_idx`` gathered INSIDE the kernel,
                                    so a single ``pallas_call`` scores a
                                    mixed-tenant micro-batch.

The banked kernel distils ``tenant_idx`` into per-block scalars carried via
``pltpu.PrefetchScalarGridSpec``: for every grid block the wrapper computes
(block_tenant, block_uniform) — available in SMEM before the block body runs
(and to the block index maps).  An all-one-tenant block skips the dense
(BLOCK, T) one-hot gather matmuls entirely and loads its single parameter
row with one scalar-indexed slice; only genuinely mixed blocks pay the
one-hot path.  Real traffic is bursty per tenant (and the sharded serving
path buckets rows by owning shard, which sorts them by tenant), so most
serving blocks take the fast path — :func:`banked_skip_stats` reports the
realized skip rate for a given layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK = 1024


def _round_block(n: int, block: int) -> int:
    """Next power of two >= n, capped at ``block`` — bounds the number of
    distinct (block,) jit specializations the serving layer can trigger."""
    b = 1
    while b < min(n, block):
        b *= 2
    return min(b, block)


def _score_pipeline_kernel(scores_ref, betas_ref, weights_ref, src_ref,
                           ref_ref, out_ref):
    y = scores_ref[...].astype(jnp.float32)          # (BLOCK, K)
    beta = betas_ref[...].astype(jnp.float32)        # (K,)
    w = weights_ref[...].astype(jnp.float32)         # (K,)
    qs = src_ref[...].astype(jnp.float32)            # (N,)
    qr = ref_ref[...].astype(jnp.float32)

    # --- T^C: posterior correction (Eq. 3), elementwise on the VPU
    corrected = beta[None, :] * y / (1.0 - (1.0 - beta[None, :]) * y)

    # --- A: weighted average (self-normalizing), one matvec
    w_norm = w / jnp.sum(w)
    agg = corrected @ w_norm                          # (BLOCK,)

    # --- T^Q: branchless piecewise-linear quantile map (Eq. 4)
    n = qs.shape[-1]
    ge = (agg[:, None] >= qs[None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)
    iota = jax.lax.broadcasted_iota(jnp.float32, (agg.shape[0], n), 1)
    onehot_i = (iota == idx[:, None]).astype(jnp.float32)
    onehot_ip1 = (iota == (idx + 1.0)[:, None]).astype(jnp.float32)
    tables = jnp.stack([qs, qr], axis=-1)
    lo = onehot_i @ tables
    hi = onehot_ip1 @ tables
    q_s_i, q_r_i = lo[:, 0], lo[:, 1]
    q_s_n, q_r_n = hi[:, 0], hi[:, 1]
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    out_ref[...] = jnp.clip(out, qr[0], qr[-1]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def score_pipeline(expert_scores: Array, betas: Array, weights: Array,
                   src_quantiles: Array, ref_quantiles: Array,
                   *, block: int = DEFAULT_BLOCK, interpret: bool = True
                   ) -> Array:
    """expert_scores: (..., K) -> (...) business-ready scores."""
    *batch_shape, k = expert_scores.shape
    flat = expert_scores.reshape(-1, k)
    n = flat.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    total = flat.shape[0]
    nq = src_quantiles.shape[-1]

    out = pl.pallas_call(
        _score_pipeline_kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), expert_scores.dtype),
        interpret=interpret,
    )(flat, betas, weights, src_quantiles, ref_quantiles)
    return out[:n].reshape(batch_shape)


def _score_pipeline_banked_kernel(btenant_ref, uniform_ref, scores_ref,
                                  idx_ref, betas_ref, weights_ref,
                                  src_ref, ref_ref, out_ref):
    b = pl.program_id(0)
    y = scores_ref[...].astype(jnp.float32)          # (BLOCK, K)

    def finish(beta, w, qs, qr):
        """Eq. 2 tail on gathered parameters; row axes broadcast, so the
        uniform path passes (1, ·) rows and the mixed path (BLOCK, ·) —
        the per-row fp op sequence is IDENTICAL either way (the sharded
        serving path relies on this for bitwise dense/sharded parity)."""
        # --- T^C: per-row posterior correction (Eq. 3)
        corrected = beta * y / (1.0 - (1.0 - beta) * y)
        # --- A: per-row self-normalizing weighted average
        w_norm = w / jnp.sum(w, axis=-1, keepdims=True)
        agg = jnp.sum(corrected * w_norm, axis=-1)              # (BLOCK,)
        # --- T^Q: branchless quantile map against per-row tables (Eq. 4)
        n = qs.shape[-1]
        ge = (agg[:, None] >= qs).astype(jnp.float32)
        idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)
        iota_n = jax.lax.broadcasted_iota(jnp.float32, (agg.shape[0], n), 1)
        onehot_i = (iota_n == idx[:, None]).astype(jnp.float32)
        onehot_ip1 = (iota_n == (idx + 1.0)[:, None]).astype(jnp.float32)
        q_s_i = jnp.sum(onehot_i * qs, axis=-1)
        q_s_n = jnp.sum(onehot_ip1 * qs, axis=-1)
        q_r_i = jnp.sum(onehot_i * qr, axis=-1)
        q_r_n = jnp.sum(onehot_ip1 * qr, axis=-1)
        denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
        out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
        out_ref[...] = jnp.clip(out, qr[:, 0], qr[:, -1]).astype(out_ref.dtype)

    @pl.when(uniform_ref[b] == 1)
    def _uniform_block():
        # fast path: every row of this block selects the same bank row —
        # ONE scalar-indexed (1, ·) slice per table replaces four dense
        # (BLOCK, T) one-hot gather matmuls.  The row index comes from the
        # prefetched SMEM scalars, available before the block body runs.
        t0 = btenant_ref[b]
        row = (pl.ds(t0, 1), slice(None))
        finish(pl.load(betas_ref, row).astype(jnp.float32),
               pl.load(weights_ref, row).astype(jnp.float32),
               pl.load(src_ref, row).astype(jnp.float32),
               pl.load(ref_ref, row).astype(jnp.float32))

    @pl.when(uniform_ref[b] == 0)
    def _mixed_block():
        # general path: gather each row's (tenant, predictor) parameters
        # with a one-hot (BLOCK, T) matmul per (T, ·) bank — dense and
        # MXU-friendly, no data-dependent addressing.
        tid = idx_ref[...].astype(jnp.int32)         # (BLOCK,)
        t = betas_ref.shape[0]
        iota_t = jax.lax.broadcasted_iota(jnp.int32, (y.shape[0], t), 1)
        sel = (iota_t == tid[:, None]).astype(jnp.float32)      # (BLOCK, T)
        finish(sel @ betas_ref[...].astype(jnp.float32),        # (BLOCK, K)
               sel @ weights_ref[...].astype(jnp.float32),
               sel @ src_ref[...].astype(jnp.float32),          # (BLOCK, N)
               sel @ ref_ref[...].astype(jnp.float32))


def _block_summary(idx_flat: Array, block: int) -> tuple[Array, Array]:
    """Distil a padded (G·block,) tenant vector into per-block scalars:
    (block_tenant, block_uniform) — the scalar-prefetch operands."""
    blocks = idx_flat.reshape(-1, block)
    btenant = blocks[:, 0].astype(jnp.int32)
    uniform = jnp.all(blocks == btenant[:, None], axis=1).astype(jnp.int32)
    return btenant, uniform


def banked_skip_stats(tenant_idx, *, block: int = DEFAULT_BLOCK) -> dict:
    """Host-side skip-rate report for a given tenant layout.

    Mirrors the wrapper's blocking exactly (power-of-two block, edge-padded
    tail) and returns how many grid blocks take the uniform fast path —
    the fraction of blocks that skip the one-hot gather matmuls.
    """
    idx = np.asarray(tenant_idx).reshape(-1)
    n = idx.shape[0]
    blk = _round_block(max(n, 1), block)
    pad = (-n) % blk
    if pad and n:
        idx = np.concatenate([idx, np.full(pad, idx[-1], idx.dtype)])
    blocks = idx.reshape(-1, blk)
    uniform = int((blocks == blocks[:, :1]).all(axis=1).sum())
    total = blocks.shape[0]
    return {"block": blk, "blocks": total, "uniform_blocks": uniform,
            "skip_rate": uniform / total if total else 0.0}


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def score_pipeline_banked(expert_scores: Array, tenant_idx: Array,
                          betas: Array, weights: Array,
                          src_quantiles: Array, ref_quantiles: Array,
                          *, block: int = DEFAULT_BLOCK,
                          interpret: bool = True) -> Array:
    """Mixed-tenant Eq. 2 in ONE ``pallas_call``.

    ``expert_scores``: (..., K) raw scores; ``tenant_idx``: (...) int32 row
    index into the (T, K) / (T, N) parameter banks.  Every grid step keeps
    the full banks resident in VMEM (T·(2K+2N)·4 bytes — ~130 KB for a
    64-tenant bank with N=256; constant index maps mean they are fetched
    once, not per block) and gathers per-row parameters in-kernel, so a
    mixed-tenant micro-batch costs one dispatch instead of T.

    ``tenant_idx`` is distilled into per-block (block_tenant, block_uniform)
    scalars carried through ``PrefetchScalarGridSpec``: blocks whose rows
    all share one tenant skip the one-hot gather matmuls (see module
    docstring).  The padding tail repeats the last real tenant id so a
    uniform final block stays on the fast path (padded rows are sliced off).
    """
    *batch_shape, k = expert_scores.shape
    flat = expert_scores.reshape(-1, k)
    idx_flat = jnp.asarray(tenant_idx, jnp.int32).reshape(-1)
    if idx_flat.shape[0] != flat.shape[0]:
        raise ValueError(
            f"tenant_idx has {idx_flat.shape[0]} rows for "
            f"{flat.shape[0]} score rows")
    n = flat.shape[0]
    block = _round_block(max(n, 1), block)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
        # edge mode: padded rows reuse the last real row's params (sliced
        # off below), keeping an otherwise-uniform tail block uniform
        idx_flat = jnp.pad(idx_flat, (0, pad), mode="edge")
    total = flat.shape[0]
    t, nq = src_quantiles.shape
    btenant, uniform = _block_summary(idx_flat, block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i, bt, uf: (i, 0)),
            pl.BlockSpec((block,), lambda i, bt, uf: (i,)),
            pl.BlockSpec((t, k), lambda i, bt, uf: (0, 0)),
            pl.BlockSpec((t, k), lambda i, bt, uf: (0, 0)),
            pl.BlockSpec((t, nq), lambda i, bt, uf: (0, 0)),
            pl.BlockSpec((t, nq), lambda i, bt, uf: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i, bt, uf: (i,)),
    )
    out = pl.pallas_call(
        _score_pipeline_banked_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((total,), expert_scores.dtype),
        interpret=interpret,
    )(btenant, uniform, flat, idx_flat, betas, weights,
      src_quantiles, ref_quantiles)
    return out[:n].reshape(batch_shape)
