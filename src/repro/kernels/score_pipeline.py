"""Fused Pallas kernels for the full Eq. 2 post-model pipeline:

    T^Q( A( [T^C_k(y_k)]_k ) )   —  posterior correction -> weighted
                                     aggregation -> quantile map

One VMEM pass over a (BLOCK, K) score tile instead of K+2 HBM round trips:
the correction is elementwise, the aggregation a (BLOCK,K)x(K,) matvec, and
the quantile map reuses the branchless compare-and-sum + one-hot-matmul
lookup of kernels/quantile_map.py.  This kernel IS the paper's transformation
DAG as a single fused op — the serving hot path for every scored event.

Two entry points:

  * :func:`score_pipeline`        — one shared (betas, weights, q-tables)
                                    parameter set for the whole batch.
  * :func:`score_pipeline_banked` — tenant-indexed: parameters are (T, ·)
                                    banks and each row carries a
                                    ``tenant_idx`` gathered INSIDE the kernel
                                    (one-hot matmuls on the MXU), so a single
                                    ``pallas_call`` scores a mixed-tenant
                                    micro-batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK = 1024


def _round_block(n: int, block: int) -> int:
    """Next power of two >= n, capped at ``block`` — bounds the number of
    distinct (block,) jit specializations the serving layer can trigger."""
    b = 1
    while b < min(n, block):
        b *= 2
    return min(b, block)


def _score_pipeline_kernel(scores_ref, betas_ref, weights_ref, src_ref,
                           ref_ref, out_ref):
    y = scores_ref[...].astype(jnp.float32)          # (BLOCK, K)
    beta = betas_ref[...].astype(jnp.float32)        # (K,)
    w = weights_ref[...].astype(jnp.float32)         # (K,)
    qs = src_ref[...].astype(jnp.float32)            # (N,)
    qr = ref_ref[...].astype(jnp.float32)

    # --- T^C: posterior correction (Eq. 3), elementwise on the VPU
    corrected = beta[None, :] * y / (1.0 - (1.0 - beta[None, :]) * y)

    # --- A: weighted average (self-normalizing), one matvec
    w_norm = w / jnp.sum(w)
    agg = corrected @ w_norm                          # (BLOCK,)

    # --- T^Q: branchless piecewise-linear quantile map (Eq. 4)
    n = qs.shape[-1]
    ge = (agg[:, None] >= qs[None, :]).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)
    iota = jax.lax.broadcasted_iota(jnp.float32, (agg.shape[0], n), 1)
    onehot_i = (iota == idx[:, None]).astype(jnp.float32)
    onehot_ip1 = (iota == (idx + 1.0)[:, None]).astype(jnp.float32)
    tables = jnp.stack([qs, qr], axis=-1)
    lo = onehot_i @ tables
    hi = onehot_ip1 @ tables
    q_s_i, q_r_i = lo[:, 0], lo[:, 1]
    q_s_n, q_r_n = hi[:, 0], hi[:, 1]
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    out_ref[...] = jnp.clip(out, qr[0], qr[-1]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def score_pipeline(expert_scores: Array, betas: Array, weights: Array,
                   src_quantiles: Array, ref_quantiles: Array,
                   *, block: int = DEFAULT_BLOCK, interpret: bool = True
                   ) -> Array:
    """expert_scores: (..., K) -> (...) business-ready scores."""
    *batch_shape, k = expert_scores.shape
    flat = expert_scores.reshape(-1, k)
    n = flat.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    total = flat.shape[0]
    nq = src_quantiles.shape[-1]

    out = pl.pallas_call(
        _score_pipeline_kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
            pl.BlockSpec((nq,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), expert_scores.dtype),
        interpret=interpret,
    )(flat, betas, weights, src_quantiles, ref_quantiles)
    return out[:n].reshape(batch_shape)


def _score_pipeline_banked_kernel(scores_ref, idx_ref, betas_ref, weights_ref,
                                  src_ref, ref_ref, out_ref):
    y = scores_ref[...].astype(jnp.float32)          # (BLOCK, K)
    tid = idx_ref[...].astype(jnp.int32)             # (BLOCK,)
    t = betas_ref.shape[0]

    # --- gather this row's (tenant, predictor) parameters from the bank.
    # A one-hot (BLOCK, T) matmul against each (T, ·) bank keeps the gather
    # dense (MXU-friendly) — no data-dependent addressing inside the kernel.
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (y.shape[0], t), 1)
    sel = (iota_t == tid[:, None]).astype(jnp.float32)          # (BLOCK, T)
    beta = sel @ betas_ref[...].astype(jnp.float32)             # (BLOCK, K)
    w = sel @ weights_ref[...].astype(jnp.float32)              # (BLOCK, K)
    qs = sel @ src_ref[...].astype(jnp.float32)                 # (BLOCK, N)
    qr = sel @ ref_ref[...].astype(jnp.float32)                 # (BLOCK, N)

    # --- T^C: per-row posterior correction (Eq. 3)
    corrected = beta * y / (1.0 - (1.0 - beta) * y)

    # --- A: per-row self-normalizing weighted average
    w_norm = w / jnp.sum(w, axis=-1, keepdims=True)
    agg = jnp.sum(corrected * w_norm, axis=-1)                  # (BLOCK,)

    # --- T^Q: branchless quantile map against per-row tables (Eq. 4)
    n = qs.shape[-1]
    ge = (agg[:, None] >= qs).astype(jnp.float32)
    idx = jnp.clip(jnp.sum(ge, axis=-1) - 1.0, 0.0, n - 2.0)
    iota_n = jax.lax.broadcasted_iota(jnp.float32, (agg.shape[0], n), 1)
    onehot_i = (iota_n == idx[:, None]).astype(jnp.float32)
    onehot_ip1 = (iota_n == (idx + 1.0)[:, None]).astype(jnp.float32)
    q_s_i = jnp.sum(onehot_i * qs, axis=-1)
    q_s_n = jnp.sum(onehot_ip1 * qs, axis=-1)
    q_r_i = jnp.sum(onehot_i * qr, axis=-1)
    q_r_n = jnp.sum(onehot_ip1 * qr, axis=-1)
    denom = jnp.where(q_s_n - q_s_i > 0, q_s_n - q_s_i, 1.0)
    out = q_r_i + (agg - q_s_i) * (q_r_n - q_r_i) / denom
    out_ref[...] = jnp.clip(out, qr[:, 0], qr[:, -1]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def score_pipeline_banked(expert_scores: Array, tenant_idx: Array,
                          betas: Array, weights: Array,
                          src_quantiles: Array, ref_quantiles: Array,
                          *, block: int = DEFAULT_BLOCK,
                          interpret: bool = True) -> Array:
    """Mixed-tenant Eq. 2 in ONE ``pallas_call``.

    ``expert_scores``: (..., K) raw scores; ``tenant_idx``: (...) int32 row
    index into the (T, K) / (T, N) parameter banks.  Every grid step keeps
    the full banks resident in VMEM (T·(2K+2N)·4 bytes — ~130 KB for a
    64-tenant bank with N=256) and gathers per-row parameters in-kernel, so
    a mixed-tenant micro-batch costs one dispatch instead of T.
    """
    *batch_shape, k = expert_scores.shape
    flat = expert_scores.reshape(-1, k)
    idx_flat = jnp.asarray(tenant_idx, jnp.int32).reshape(-1)
    if idx_flat.shape[0] != flat.shape[0]:
        raise ValueError(
            f"tenant_idx has {idx_flat.shape[0]} rows for "
            f"{flat.shape[0]} score rows")
    n = flat.shape[0]
    block = _round_block(max(n, 1), block)
    pad = (-n) % block
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
        idx_flat = jnp.pad(idx_flat, (0, pad))  # row 0 params; sliced off
    total = flat.shape[0]
    t, nq = src_quantiles.shape

    out = pl.pallas_call(
        _score_pipeline_banked_kernel,
        grid=(total // block,),
        in_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((t, k), lambda i: (0, 0)),
            pl.BlockSpec((t, k), lambda i: (0, 0)),
            pl.BlockSpec((t, nq), lambda i: (0, 0)),
            pl.BlockSpec((t, nq), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((total,), expert_scores.dtype),
        interpret=interpret,
    )(flat, idx_flat, betas, weights, src_quantiles, ref_quantiles)
    return out[:n].reshape(batch_shape)
