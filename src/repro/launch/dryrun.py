import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (including any
# ``from repro...``) — jax locks the device count at first backend init.

__doc__ = """Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell: build the production mesh,
apply the sharding rules, ``jit(step).lower(*abstract_args).compile()``, and
record memory_analysis + cost_analysis + the collective schedule.  Succeeds
for BOTH the single-pod (16x16) and multi-pod (2x16x16) meshes.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count at first backend initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--both]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import jax_compat
from repro.configs import ARCH_IDS, applicable_shapes
from repro.launch import mesh as mesh_lib
from repro.launch import roofline, shardings, specs
from repro.training.optimizer import AdamWState
from repro.training.train import TrainState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _logits_sharding(mesh, batch, vocab, ndim):
    model = mesh_lib.model_axis_size(mesh)
    daxes = mesh_lib.data_axes(mesh)
    dsize = mesh_lib.data_axis_size(mesh)
    spec = [None] * ndim
    if batch % dsize == 0 and dsize > 1:
        spec[0] = daxes
    if vocab % model == 0:
        spec[-1] = "model"
    return NamedSharding(mesh, P(*spec))


# per-device param-bytes budget above which we switch to FSDP (ZeRO-3)
FSDP_THRESHOLD_BYTES = 4 * 2**30


def _decide_fsdp(params, mesh) -> bool:
    per_dev = shardings.total_param_bytes(params) / mesh_lib.model_axis_size(mesh)
    return per_dev > FSDP_THRESHOLD_BYTES


def build_shardings(bundle: specs.StepBundle, mesh, *,
                    fsdp: bool | None = None):
    """(in_shardings, out_shardings, fsdp_used) pytrees for this step."""
    rep = shardings.replicated(mesh)
    b = bundle.shape.global_batch
    cfg = bundle.cfg
    batch_sh = NamedSharding(mesh, shardings.batch_pspec(b, mesh, 0))

    if bundle.kind == "train":
        state, tokens, labels = bundle.abstract_args
        if fsdp is None:
            # training state is ~3x f32 params: 12 bytes/param
            fsdp = _decide_fsdp(state.params, mesh) or _decide_fsdp(
                state.opt.mu, mesh)
        psh = shardings.params_shardings(state.params, mesh, fsdp=fsdp)
        state_sh = TrainState(
            params=psh,
            opt=AdamWState(step=rep, mu=psh, nu=psh),
        )
        tok_sh = shardings.tokens_sharding(b, mesh)
        metrics_sh = jax.tree.map(lambda _: rep, bundle.step_fn and
                                  _abstract_metrics())
        return (state_sh, tok_sh, tok_sh), (state_sh, metrics_sh), fsdp

    params = bundle.abstract_args[0]
    if fsdp is None:
        fsdp = _decide_fsdp(params, mesh)
    psh = shardings.params_shardings(params, mesh, fsdp=fsdp)

    if bundle.kind == "prefill":
        inputs = bundle.abstract_args[1]
        in_sh = {
            k: NamedSharding(mesh, shardings.batch_pspec(
                b, mesh, v.ndim - 1))
            for k, v in inputs.items()
        }
        # step may carry bare-PartitionSpec constraints / shard_map
        with mesh, jax_compat.set_mesh(mesh):
            out = jax.eval_shape(bundle.step_fn, *bundle.abstract_args)
        out_sh = {}
        if "logits" in out:
            out_sh["logits"] = _logits_sharding(
                mesh, b, cfg.vocab_size, out["logits"].ndim)
        out_sh["risk_score"] = batch_sh
        if "cache" in out:
            out_sh["cache"] = shardings.cache_shardings(out["cache"], b, mesh)
        return (psh, in_sh), out_sh, fsdp

    # decode
    _, cache, inputs, _pos = bundle.abstract_args
    cache_sh = shardings.cache_shardings(cache, b, mesh)
    in_sh = {
        k: NamedSharding(mesh, shardings.batch_pspec(b, mesh, v.ndim - 1))
        for k, v in inputs.items()
    }
    out_sh = {
        "logits": _logits_sharding(mesh, b, cfg.vocab_size, 2),
        "risk_score": batch_sh,
        "cache": cache_sh,
    }
    return (psh, cache_sh, in_sh, shardings.replicated(mesh)), out_sh, fsdp


def _abstract_metrics():
    from repro.training.train import StepMetrics
    z = jax.ShapeDtypeStruct((), jnp.float32)
    return StepMetrics(z, z, z, z)


def variant_build_kwargs(variant: str, kind_hint: str, mesh) -> dict:
    """§Perf optimization bundles, keyed by --variant.

    ``opt``:
      train/prefill -> sequence-parallel residual stream (T on "model") +
                        bf16 master-weight cast before collectives (train);
      decode        -> weight-stationary layout: residual d on "data" so
                        FSDP'd weights are contracted in place instead of
                        all-gathered per step.
    """
    if variant == "baseline":
        return {}
    daxes = mesh_lib.data_axes(mesh)
    out: dict = {}
    if kind_hint == "decode":
        out["act_pspec"] = P(None, None, "data")
    elif kind_hint == "train":
        out["act_pspec"] = P(daxes, "model", None)
        out["cast_params_bf16"] = True
    else:
        out["act_pspec"] = P(daxes, "model", None)
    if variant in ("opt2", "opt3"):
        out["moe_ep_constraint"] = True
    if variant == "opt3" and kind_hint == "train":
        out["remat"] = False  # drop the remat re-forward weight-gather pass
    if variant == "opt4" and kind_hint != "decode":
        out["moe_impl"] = "a2a"  # shard_map all-to-all expert parallelism
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             moment_dtype=jnp.float32, verbose: bool = True,
             extra_tag: str = "", fsdp: bool | None = None,
             variant: str = "baseline",
             **build_kwargs) -> dict:
    t0 = time.perf_counter()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    kind_hint = specs.SHAPES[shape_name].kind
    build_kwargs = {**variant_build_kwargs(variant, kind_hint, mesh),
                    **build_kwargs}
    bundle = specs.build_step(arch, shape_name, moment_dtype=moment_dtype,
                              **build_kwargs)
    in_sh, out_sh, fsdp_used = build_shardings(bundle, mesh, fsdp=fsdp)

    with mesh, jax_compat.set_mesh(mesh):
        jitted = jax.jit(
            bundle.step_fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=bundle.donate_argnums,
        )
        lowered = jitted.lower(*bundle.abstract_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    if bundle.kind == "train":
        # params + grads + both moments in training dtype
        pb = shardings.total_param_bytes(bundle.abstract_args[0].params)
        mb = shardings.total_param_bytes(bundle.abstract_args[0].opt.mu)
        param_bytes = pb * 2 + mb * 2
        cache_bytes = 0.0
    else:
        param_bytes = shardings.total_param_bytes(bundle.abstract_args[0])
        cache_bytes = (
            shardings.total_param_bytes(bundle.abstract_args[1])
            if bundle.kind == "decode" else 0.0
        )
    report = roofline.analyze(compiled, bundle.cfg, bundle.shape,
                              bundle.kind, mesh, arch,
                              param_bytes_global=param_bytes,
                              cache_bytes_global=cache_bytes)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": report.mesh_desc,
        "multi_pod": multi_pod,
        "kind": bundle.kind,
        "fsdp": fsdp_used,
        "variant": variant,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        } if mem is not None else None,
        "roofline": report.as_dict(),
    }
    if verbose:
        ma = result["memory_analysis"] or {}
        arg_gb = (ma.get("argument_bytes") or 0) / 2**30
        tmp_gb = (ma.get("temp_bytes") or 0) / 2**30
        print(
            f"[dryrun] {arch:>26s} x {shape_name:<12s} mesh={report.mesh_desc:<16s}"
            f" compile={t_compile:7.1f}s args/dev={arg_gb:7.2f}GiB"
            f" temp/dev={tmp_gb:6.2f}GiB flops/dev={report.flops_per_chip:.3e}"
            f" coll/dev={report.collective_bytes_per_chip:.3e}B"
            f" bottleneck={report.bottleneck}"
        )
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = "pod2" if multi_pod else "pod1"
    if variant != "baseline":
        tag += f"_{variant}"
    if extra_tag:
        tag += f"_{extra_tag}"
    fname = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{tag}.json")
    with open(fname, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(specs.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--bf16-moments", action="store_true",
                    help="AdamW moments in bf16 (memory optimization)")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt", "opt2", "opt3", "opt4"],
                    help="§Perf optimization bundle (see variant_build_kwargs)")
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    moment_dtype = jnp.bfloat16 if args.bf16_moments else jnp.float32
    meshes = [False, True] if args.both else [args.multi_pod]

    cells: list[tuple[str, str]] = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in applicable_shapes(arch):
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, multi_pod=mp, moment_dtype=moment_dtype,
                         variant=args.variant,
                         extra_tag="bf16m" if args.bf16_moments else "")
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] FAILED {arch} x {shape} multi_pod={mp}: {e}")
                if not args.continue_on_error:
                    traceback.print_exc()
                    raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells compiled OK")


if __name__ == "__main__":
    main()
