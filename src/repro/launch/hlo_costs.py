"""Structural HLO cost extraction that is correct under `lax.scan`.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*,
ignoring trip counts (verified empirically — see EXPERIMENTS.md §Dry-run
caveats).  Since every layer stack here is a scan, collectives and flops
inside the loop would be undercounted by n_groups (and inner chunk scans).

This module parses the post-optimization HLO text:

  1. split the module into named computations;
  2. locate every ``while`` op, resolve its body/condition computations, and
     read the trip count from the condition's ROOT compare against a constant;
  3. build per-computation multipliers = product of trip counts along the
     call chain from ENTRY;
  4. sum collective-op result bytes weighted by those multipliers.

Shapes in post-SPMD HLO are per-device, so the result is per-device bytes.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]"
)

# permissive: tuple-typed params contain nested parens, so just require
# "%name (... -> ... {" on one line
_COMP_HEADER_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$"
)

_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)

_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|condition=|body=|branch_computations=\{)%?([\w.\-]+)"
)

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^\s]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)

_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s+constant\((\d+)\)")

_COMPARE_RE = re.compile(
    r"ROOT\s+%?[\w.\-]+\s*=\s*pred\[\]\s+compare\(([^)]*)\),\s*direction=(\w+)"
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def split_computations(hlo: str) -> dict[str, str]:
    """{computation_name: body_text}; crude but robust brace matching."""
    comps: dict[str, str] = {}
    lines = hlo.splitlines()
    i = 0
    while i < len(lines):
        m = _COMP_HEADER_RE.match(lines[i].strip())
        if m and lines[i].rstrip().endswith("{"):
            name = m.group(1)
            body = []
            depth = 1
            i += 1
            while i < len(lines) and depth > 0:
                depth += lines[i].count("{") - lines[i].count("}")
                body.append(lines[i])
                i += 1
            comps[name] = "\n".join(body)
        else:
            i += 1
    return comps


def _entry_name(hlo: str, comps: dict[str, str]) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
    if m and m.group(1) in comps:
        return m.group(1)
    return None


def _trip_count(cond_text: str) -> int:
    """Read the loop bound from the condition's ROOT compare vs a constant."""
    consts = {name: int(val) for name, val in _CONST_RE.findall(cond_text)}
    m = _COMPARE_RE.search(cond_text)
    if m:
        operands = m.group(1)
        for name, val in consts.items():
            if name in operands:
                return max(val, 1)
    if consts:
        return max(consts.values())
    return 1


@dataclasses.dataclass
class HloCollectives:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, float]   # trip-weighted dynamic counts
    static_count: int

    def weighted_bytes(self, factors: dict[str, float]) -> float:
        return sum(factors.get(k, 1.0) * v for k, v in self.bytes_by_kind.items())

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collect_collectives(hlo: str) -> HloCollectives:
    comps = split_computations(hlo)
    entry = _entry_name(hlo, comps)

    # per-computation while calls: parent -> [(body, trips)]
    while_calls: dict[str, list[tuple[str, int]]] = {}
    # generic calls (fusions/conditionals) carry multiplier 1
    plain_calls: dict[str, set[str]] = {}
    for parent, text in comps.items():
        for cond, body in _WHILE_RE.findall(text):
            trips = _trip_count(comps.get(cond, ""))
            while_calls.setdefault(parent, []).append((body, trips))
        calls = set(_CALL_RE.findall(text))
        plain_calls[parent] = {c for c in calls if c in comps}

    # multiplier per computation via BFS from entry
    mult: dict[str, float] = {}
    if entry is not None:
        mult[entry] = 1.0
        frontier = [entry]
        seen = {entry}
        while frontier:
            cur = frontier.pop()
            m = mult[cur]
            for body, trips in while_calls.get(cur, ()):
                mult[body] = max(mult.get(body, 0.0), m * trips)
                if body not in seen:
                    seen.add(body)
                    frontier.append(body)
            for callee in plain_calls.get(cur, ()):
                factor = m
                # avoid double-applying trip counts for bodies already handled
                if callee not in mult or mult[callee] < factor:
                    mult[callee] = factor
                if callee not in seen:
                    seen.add(callee)
                    frontier.append(callee)

    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, float] = {}
    static = 0
    for name, text in comps.items():
        m = mult.get(name, 1.0)
        for line in text.splitlines():
            cm = _COLLECTIVE_RE.match(line)
            if not cm:
                continue
            shape_str, kind, startdone = cm.group(1), cm.group(2), cm.group(3)
            if startdone == "-done":
                continue  # paired with -start; don't double count
            static += 1
            b = _shape_bytes(shape_str) * m
            bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + b
            count_by_kind[kind] = count_by_kind.get(kind, 0.0) + m
    return HloCollectives(bytes_by_kind, count_by_kind, static)
