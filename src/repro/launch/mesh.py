"""Production mesh definitions.

Single pod: 256 chips as ("data", "model") = (16, 16).
Multi-pod:  512 chips as ("pod", "data", "model") = (2, 16, 16) — "pod" is an
outer data-parallel axis (batch sharded over pod x data; gradient all-reduce
crosses the inter-pod links once per step).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS before calling.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, bytes/s
ICI_BW = 50e9                   # per link, bytes/s


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the same axis names (CPU tests / examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_tenant_mesh(num_shards: int) -> jax.sharding.Mesh:
    """1-D serving mesh over the "tenants" axis (sharded transform banks).

    Each of the ``num_shards`` devices holds one row-shard of every
    :class:`~repro.core.transforms.ShardedTransformBank` — or, under the
    tiered-over-sharded topology, one bounded hot-tier/victim-cache view of
    its shard's host rows (``serving/tiering.ShardedTieredBankStore``); the
    serving layer buckets requests by owning shard and launches the banked
    kernel per shard via ``shard_map`` over this axis.  Goes through the
    jax_compat shim so the same call works on jax 0.4.x and the newest
    surface.
    """
    if num_shards < 1:
        raise ValueError(f"num_shards must be >= 1, got {num_shards}")
    avail = jax.device_count()
    if num_shards > avail:
        raise ValueError(
            f"tenant mesh needs {num_shards} devices, have {avail} "
            "(CI: XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro import jax_compat
    return jax_compat.make_mesh((num_shards,), ("tenants",))


def tenant_axis_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("tenants", 1)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The batch-sharding axes for this mesh (('pod','data') or ('data',))."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("model", 1)


def data_axis_size(mesh: jax.sharding.Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
