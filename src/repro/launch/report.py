"""Render §Dry-run / §Roofline tables from benchmarks/results/dryrun/*.json.

  PYTHONPATH=src python -m repro.launch.report [--pod pod1|pod2] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

HBM_PER_CHIP = 16 * 2**30  # v5e

ARCH_ORDER = [
    "internlm2-1.8b", "llama3-405b", "olmoe-1b-7b", "qwen2-vl-7b",
    "hubert-xlarge", "deepseek-coder-33b", "jamba-1.5-large-398b",
    "qwen3-8b", "xlstm-1.3b", "llama4-maverick-400b-a17b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(pod: str = "pod1", tag: str = "") -> list[dict]:
    suffix = f"__{pod}{('_' + tag) if tag else ''}.json"
    rows = []
    for path in glob.glob(os.path.join(RESULTS_DIR, f"*{suffix}")):
        base = os.path.basename(path)
        if not base.endswith(suffix):
            continue
        # exclude tagged variants when untagged requested
        if not tag and base.count("__") != 2:
            continue
        with open(path) as f:
            rows.append(json.load(f))
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    return rows


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(rows: list[dict], markdown: bool = True) -> str:
    hdr = ["arch", "shape", "kind", "fsdp", "compute", "memory", "collect",
           "bottleneck", "MF/HLO", "hbm/chip", "fits16G"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append("  ".join(f"{h:>12}" for h in hdr))
    for r in rows:
        rf = r["roofline"]
        ma = r.get("memory_analysis") or {}
        steady = (ma.get("argument_bytes") or 0) + (ma.get("output_bytes") or 0) \
            - (ma.get("alias_bytes") or 0)
        resident = steady + (ma.get("temp_bytes") or 0)
        fits = "Y" if resident <= HBM_PER_CHIP else f"N({resident/2**30:.0f}G)"
        cells = [
            r["arch"], r["shape"], r["kind"], "Y" if r.get("fsdp") else "n",
            _fmt_s(rf["compute_s"]), _fmt_s(rf["memory_s"]),
            _fmt_s(rf["collective_s"]), rf["bottleneck"],
            f"{rf['useful_flops_ratio']:.2f}",
            f"{(rf['bytes_per_chip']) / 2**30:.1f}G",
            fits,
        ]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in cells) + " |")
        else:
            lines.append("  ".join(f"{str(c):>12}" for c in cells))
    return "\n".join(lines)


def dryrun_table(rows: list[dict], markdown: bool = True) -> str:
    hdr = ["arch", "shape", "mesh", "compile_s", "args/chip", "temp/chip",
           "coll bytes/chip", "coll ops (dyn)", "dominant collective"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for r in rows:
        rf = r["roofline"]
        ma = r.get("memory_analysis") or {}
        by_kind = rf.get("collective_bytes_by_kind", {})
        dom = max(by_kind, key=by_kind.get) if by_kind else "-"
        counts = rf.get("collective_counts", {})
        cells = [
            r["arch"], r["shape"], r["mesh"], f"{r['compile_s']:.1f}",
            f"{(ma.get('argument_bytes') or 0)/2**30:.2f}G",
            f"{(ma.get('temp_bytes') or 0)/2**30:.2f}G",
            f"{rf['collective_bytes_per_chip']:.2e}",
            f"{sum(counts.values()):.0f}",
            dom,
        ]
        if markdown:
            lines.append("| " + " | ".join(str(c) for c in cells) + " |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pod", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    args = ap.parse_args()
    rows = load(args.pod, args.tag)
    if args.table == "roofline":
        print(roofline_table(rows))
    else:
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
