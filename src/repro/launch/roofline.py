"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (deliverable g):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw               (819 GB/s)
  collective = collective_bytes_per_chip / link_bw       (~50 GB/s/link)

``cost_analysis`` runs on the SPMD-partitioned module, so its flops/bytes are
per-device.  Collective bytes are not in cost_analysis: we parse the
post-optimization HLO and sum result-shape bytes of every collective op
(result shapes are per-device post-partitioning).  All-reduce is counted
twice (reduce-scatter + all-gather phases of a ring); all-to-all / permute /
gather / scatter once.
"""
from __future__ import annotations

import dataclasses

from typing import Any

from repro.launch import mesh as mesh_lib

# ring all-reduce moves ~2x the payload (reduce-scatter + all-gather phases)
_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def model_flops(cfg, shape, kind: str) -> float:
    """Global 'useful' FLOPs: 6·N_active·D (train) or 2·N_active·D (fwd)."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# analytic cost model (scan-aware; see EXPERIMENTS.md §Dry-run caveats:
# XLA cost_analysis counts while bodies once, so scanned stacks need an
# explicit model for honest compute/memory terms)
# ---------------------------------------------------------------------------

def analytic_flops(cfg, shape, kind: str) -> float:
    """Global forward+backward matmul FLOPs, structure-aware.

    Counts: projections (2·params per token), attention quadratic terms with
    causal/window truncation, MoE dispatch einsums, SSM scan elementwise work.
    Train multiplies by 4 (fwd + 2·bwd + remat re-fwd).
    """
    from repro.models.config import MambaConfig, XLSTMConfig

    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    t = shape.seq_len
    bsz = shape.global_batch

    if kind == "decode":
        tokens = float(bsz)
        t_ctx = float(min(t, cfg.sliding_window) if cfg.sliding_window else t)
    else:
        tokens = float(bsz * t)
        # average causal context per token
        t_ctx = float(min(t / 2.0, cfg.sliding_window or t))
        if not cfg.causal:
            t_ctx = float(t)  # bidirectional encoder attends to all

    per_token = 0.0
    for spec in cfg.layer_pattern:
        if spec.mixer == "attn":
            proj = 2.0 * (d * hq * hd + 2 * d * hkv * hd + hq * hd * d)
            attn = 2.0 * 2.0 * hq * hd * t_ctx   # QK^T + PV
            per_token += proj + attn
        elif spec.mixer == "mamba":
            mc = cfg.mamba or MambaConfig()
            d_in = mc.expand * d
            rank = mc.dt_rank or -(-d // 16)
            proj = 2.0 * (d * 2 * d_in + d_in * (rank + 2 * mc.d_state)
                          + rank * d_in + d_in * d)
            scan = 10.0 * d_in * mc.d_state      # discretize + assoc-scan
            per_token += proj + scan
        elif spec.mixer == "mlstm":
            xc = cfg.xlstm or XLSTMConfig()
            d_in = int(xc.mlstm_proj_factor * d)
            hd_in = d_in // cfg.n_heads
            q_chunk = min(xc.chunk_size, t) if kind != "decode" else 1
            proj = 2.0 * (d * 2 * d_in + 3 * d_in * hd_in + d_in * d)
            if kind == "decode":
                mix = 2.0 * 3.0 * d_in * hd_in   # state update + readout
            else:
                # intra-chunk causal quadratic (avg ctx Q/2 over scores + PV),
                # + inter-chunk state readout, + per-chunk state update share
                mix = (4.0 * d_in * (q_chunk / 2.0)
                       + 2.0 * d_in * hd_in
                       + 4.0 * d_in * hd_in / q_chunk)
            per_token += proj + mix
        elif spec.mixer == "slstm":
            xc = cfg.xlstm or XLSTMConfig()
            d_up = int(xc.slstm_proj_factor * d)
            per_token += 2.0 * (8.0 * d * d + 2.0 * d * d_up)
        if spec.ffn == "mlp":
            per_token += 2.0 * 3.0 * d * cfg.d_ff
        elif spec.ffn == "moe":
            mo = cfg.moe
            expert = 2.0 * mo.top_k * 3.0 * d * mo.d_ff_expert
            if mo.shared_expert:
                expert += 2.0 * 3.0 * d * (mo.d_ff_shared or mo.d_ff_expert)
            router = 2.0 * d * mo.num_experts
            dispatch = 2.0 * 2.0 * mo.num_experts * (mo.top_k * mo.capacity_factor) * d
            per_token += expert + router + dispatch
    per_token *= cfg.n_groups

    # heads: logits for every token in train/encode, one position otherwise
    if kind == "train" or cfg.is_encoder_only:
        head_tokens = tokens
    elif kind == "prefill":
        head_tokens = float(bsz)
    else:
        head_tokens = tokens
    head = 2.0 * d * cfg.vocab_size * head_tokens + 2.0 * d * tokens  # + score

    fwd = per_token * tokens + head
    if kind == "train":
        return 4.0 * fwd          # fwd + 2x bwd + remat re-forward
    return fwd


def analytic_hbm_bytes(cfg, shape, kind: str, *, param_bytes: float,
                       cache_bytes: float = 0.0) -> float:
    """Global HBM traffic model (documented napkin math, not measured):

    decode  : stream params once + stream cache once + small activations
    prefill : params once + ~6 activation passes/layer + cache write
    train   : ~6x params (grad/moment read-write) + ~10 activation passes
              (fwd write, bwd read, remat rewrite, attention chunks)
    """
    d = cfg.d_model
    t = shape.seq_len
    bsz = shape.global_batch
    act_dtype = 2.0  # bf16
    if kind == "decode":
        act = cfg.n_layers * bsz * d * act_dtype * 6.0
        return param_bytes + cache_bytes + act
    act_pass = cfg.n_layers * bsz * t * d * act_dtype
    logits = bsz * t * cfg.vocab_size * 4.0
    if kind == "prefill":
        return param_bytes + 6.0 * act_pass + cache_bytes + logits / max(t, 1)
    # train
    return 6.0 * param_bytes + 10.0 * act_pass + 3.0 * logits


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh_desc: str
    chips: int
    # scan-aware analytic terms (primary)
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_global: float
    useful_flops_ratio: float
    peak_memory_per_chip: float | None
    collective_counts: dict[str, float]
    collective_bytes_by_kind: dict[str, float]
    # raw XLA cost_analysis (while bodies counted once — cross-check only)
    xla_flops_per_chip: float
    xla_bytes_per_chip: float

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def analyze(compiled, cfg, shape, kind: str, mesh, arch: str,
            *, param_bytes_global: float = 0.0,
            cache_bytes_global: float = 0.0) -> RooflineReport:
    from repro.launch import hlo_costs

    chips = 1
    for n in mesh.shape.values():
        chips *= n

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = hlo_costs.collect_collectives(hlo)
    coll_bytes = coll.weighted_bytes(_COLLECTIVE_FACTOR)

    flops = analytic_flops(cfg, shape, kind) / chips
    hbm = analytic_hbm_bytes(
        cfg, shape, kind,
        param_bytes=param_bytes_global, cache_bytes=cache_bytes_global,
    ) / chips

    compute_s = flops / mesh_lib.PEAK_FLOPS_BF16
    memory_s = hbm / mesh_lib.HBM_BW
    collective_s = coll_bytes / mesh_lib.ICI_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape, kind)
    ratio = mf / (flops * chips) if flops > 0 else float("nan")

    peak_mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_mem = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0)
            )
    except Exception:
        pass

    return RooflineReport(
        arch=arch, shape=shape.name,
        mesh_desc="x".join(f"{k}{v}" for k, v in mesh.shape.items()),
        chips=chips,
        flops_per_chip=flops,
        bytes_per_chip=hbm,
        collective_bytes_per_chip=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops_global=mf,
        useful_flops_ratio=ratio,
        peak_memory_per_chip=peak_mem,
        collective_counts=coll.count_by_kind,
        collective_bytes_by_kind=coll.bytes_by_kind,
        xla_flops_per_chip=xla_flops,
        xla_bytes_per_chip=xla_bytes,
    )
