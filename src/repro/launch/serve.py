"""Serving launcher: prefill + decode loop for one architecture on real
devices, using the same serve_step the dry-run lowers, wrapped in the MUSE
transformation pipeline (the paper's Eq. 2 applied to the risk-score head).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.transforms import (
    QuantileMap,
    fraud_reference_quantiles,
    score_pipeline,
)
from repro.models.model import Model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; use forward serving")
    model = Model(cfg)
    params = model.init(jax.random.key(0), dtype=jnp.float32)
    capacity = args.prompt_len + args.decode_steps

    # MUSE transformation for the risk score (single-model predictor: T^Q)
    ref_q = fraud_reference_quantiles(128)
    qm = QuantileMap(jnp.linspace(0, 1, 128), ref_q)

    prefill = jax.jit(
        lambda p, t: model.prefill(p, tokens=t, cache_capacity=capacity,
                                   logits_mode="last")
    )
    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, tokens=t, pos=pos)
    )
    transform = jax.jit(
        lambda s: score_pipeline(s[:, None], jnp.ones((1,)), jnp.ones((1,)),
                                 qm.src_quantiles, qm.ref_quantiles)
    )

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)

    t0 = time.perf_counter()
    out, cache = prefill(params, prompt)
    jax.block_until_ready(cache)
    t_prefill = time.perf_counter() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.1f}ms "
          f"(incl. compile)")

    tok = jnp.argmax(out.logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    times = []
    for i in range(args.decode_steps):
        t0 = time.perf_counter()
        step = decode(params, cache, tok, args.prompt_len + i)
        cache = step.cache
        tok = jnp.argmax(step.logits, axis=-1).astype(jnp.int32)[:, None]
        biz_score = transform(step.risk_score)
        jax.block_until_ready(biz_score)
        times.append(time.perf_counter() - t0)
    print(f"decode: first {times[0]*1e3:.1f}ms (compile), steady "
          f"{np.mean(times[1:])*1e3:.2f}ms/token, "
          f"{args.batch/np.mean(times[1:]):.0f} tok/s")
    print(f"final business scores (post T^Q): "
          f"{np.round(np.asarray(biz_score), 4)}")


if __name__ == "__main__":
    main()
