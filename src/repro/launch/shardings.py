"""Parameter / activation / cache sharding rules for the production mesh.

Philosophy (DESIGN.md §5): name-based rules over pytree paths, with
divisibility checks and replicate fallback.  GSPMD keeps any sharding
*correct*; these rules control the collective schedule and per-device
footprint that the roofline analysis measures.

Baseline layout:
  * batch axes -> ("pod","data") when divisible, else replicated;
  * matmul weights: column-parallel (shard output dim on "model") for
    QKV/gate/up-style projections, row-parallel (shard input dim) for
    O/down-style projections — the Megatron pairing that turns each block
    into [col-parallel matmul -> row-parallel matmul -> one all-reduce];
  * MoE expert weights: expert-parallel (leading E axis on "model");
  * embeddings vocab-sharded; tiny leaves (norms, biases, score head)
    replicated;
  * KV caches: batch on data axes, sequence on "model" (flash-decoding
    layout: per-chip partial attention + small combine all-reduce);
  * SSM / xLSTM states: batch on data axes, inner features on "model".
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib

PyTree = Any

# path substrings -> which dim (negative index) is column/row parallel
_COL_PARALLEL = (  # shard LAST dim on "model"
    "wq/", "wk/", "wv/", "gate/", "up/", "in_proj/", "w_in/", "w_rec/",
    "dt_proj/", "lm_head/",
)
_ROW_PARALLEL = (  # shard dim -2 on "model"
    "wo/", "down/", "out_proj/", "x_proj/",
)
_REPLICATED = (
    "norm", "score_head", "router/", "bias", "/b",
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts) + "/"


def _div(dim: int, n: int) -> bool:
    return n > 0 and dim % n == 0


def param_pspec(path_str: str, shape: tuple[int, ...], model_size: int,
                *, fsdp_axes: tuple[str, ...] = (), fsdp_size: int = 1) -> P:
    """PartitionSpec for one parameter leaf (stacked leading group dim ok).

    With ``fsdp_axes`` set, a second dim is additionally sharded over the
    data axes (ZeRO-3 / FSDP): required for the 400B-class models whose
    parameters cannot be held at 1/model_size per chip.
    """
    nd = len(shape)
    spec = [None] * nd

    def _fsdp_fill() -> None:
        if not fsdp_axes or nd < 2:
            return
        # shard the largest still-unsharded dim that divides
        for i in sorted(range(nd), key=lambda j: -shape[j]):
            if spec[i] is None and _div(shape[i], fsdp_size):
                spec[i] = fsdp_axes
                return

    def col(dim_idx: int) -> P:
        if _div(shape[dim_idx], model_size):
            spec[dim_idx] = "model"
        _fsdp_fill()
        return P(*spec)

    # MoE expert tensors: .../ffn/{gate,up,down} with ndim >= 3 and a leading
    # (groups, experts, ...) — shard the expert axis (expert parallelism).
    if "/ffn/" in path_str and any(
        k in path_str for k in ("gate/", "up/", "down/")
    ) and nd >= 3 and "shared" not in path_str:
        # stacked: (G, E, d, ff) or unstacked (E, d, ff)
        e_axis = nd - 3
        if _div(shape[e_axis], model_size):
            spec[e_axis] = "model"
            _fsdp_fill()
            return P(*spec)
        # fall through to col/row rules if experts don't divide

    if any(k in path_str for k in _REPLICATED):
        return P(*spec)
    if "embed/table" in path_str:
        v_axis = nd - 2
        if _div(shape[v_axis], model_size):
            spec[v_axis] = "model"
        _fsdp_fill()
        return P(*spec)
    for key in _COL_PARALLEL:
        if key in path_str:
            return col(nd - 1)
    for key in _ROW_PARALLEL:
        if key in path_str:
            return col(nd - 2) if nd >= 2 else P(*spec)
    # mamba per-channel tensors: A_log (G, d_in, N), D / dt_bias (G, d_in),
    # conv_w (G, K, d_in), conv_b (G, d_in)
    if "A_log" in path_str:
        return col(nd - 2)
    if any(k in path_str for k in ("conv_w", "conv_b", "dt_bias", "/D/")) or \
            path_str.endswith("/D/"):
        return col(nd - 1)
    _fsdp_fill()
    return P(*spec)


def params_shardings(params: PyTree, mesh: Mesh, *, fsdp: bool = False) -> PyTree:
    """NamedSharding pytree matching ``params``.

    ``fsdp=True`` additionally shards a second weight dim over the data axes
    (ZeRO-3) — mandatory for 400B-class models on a 256-chip pod.
    """
    model = mesh_lib.model_axis_size(mesh)
    faxes = mesh_lib.data_axes(mesh) if fsdp else ()
    fsize = mesh_lib.data_axis_size(mesh) if fsdp else 1

    def one(path, leaf):
        ps = param_pspec(_path_str(path), leaf.shape, model,
                         fsdp_axes=faxes, fsdp_size=fsize)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(one, params)


def total_param_bytes(params: PyTree) -> int:
    return sum(int(np.prod(l.shape)) * l.dtype.itemsize
               for l in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def batch_pspec(batch: int, mesh: Mesh, rest_dims: int) -> P:
    axes = mesh_lib.data_axes(mesh)
    n = mesh_lib.data_axis_size(mesh)
    if _div(batch, n) and n > 1:
        return P(axes, *([None] * rest_dims))
    return P(*([None] * (rest_dims + 1)))


def tokens_sharding(batch: int, mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(batch, mesh, 1))


def cache_pspec(path_str: str, shape: tuple[int, ...], batch: int,
                mesh: Mesh) -> P:
    """Decode-cache leaf sharding.

    KV caches (G, B, S, Hkv, D): batch on data axes; sequence on "model"
    (flash-decoding).  States (mamba/mlstm/slstm): batch on data; the largest
    inner dim on "model" when divisible.
    """
    model = mesh_lib.model_axis_size(mesh)
    daxes = mesh_lib.data_axes(mesh)
    dsize = mesh_lib.data_axis_size(mesh)
    nd = len(shape)
    spec: list = [None] * nd
    if nd >= 2 and _div(shape[1], dsize) and dsize > 1:
        spec[1] = daxes
    if ("/k/" in path_str or "/v/" in path_str or path_str.endswith("/k/")
            or path_str.endswith("/v/")) and nd == 5:
        if _div(shape[2], model):
            spec[2] = "model"          # sequence axis
        return P(*spec)
    # states: shard the largest remaining dim divisible by model
    if nd >= 3:
        inner = max(range(2, nd), key=lambda i: shape[i])
        if _div(shape[inner], model):
            spec[inner] = "model"
    return P(*spec)


def cache_shardings(cache: PyTree, batch: int, mesh: Mesh) -> PyTree:
    def one(path, leaf):
        ps = cache_pspec(_path_str(path), leaf.shape, batch, mesh)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
