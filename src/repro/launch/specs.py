"""Abstract input specs + step functions for the multi-pod dry-run.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation); ``build_step``
returns the jittable step the dry-run lowers:

  train_4k     -> train_step(state, tokens, labels)
  prefill_32k  -> prefill_step(params, tokens|embeds)   [encoder: encode_step]
  decode_*     -> serve_step(params, cache, token|embed, pos): ONE new token
                  against a seq_len KV cache / recurrent state.

long_500k on dense/MoE/VLM decoders switches the config to the
sliding-window variant (window 8192) — the sub-quadratic requirement; SSM /
hybrid archs run their native constant-state decode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.shapes import SHAPES, InputShape
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.training.optimizer import AdamW
from repro.training.train import TrainState, make_train_step

PyTree = Any

SLIDING_WINDOW_LONG = 8192


def serving_config(arch: str, shape_name: str) -> ModelConfig:
    """The (possibly shape-adapted) config used for this dry-run cell."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.causal and cfg.arch_type not in (
        "ssm", "hybrid"
    ):
        # sub-quadratic requirement: bounded sliding-window KV cache
        cfg = dataclasses.replace(cfg, sliding_window=SLIDING_WINDOW_LONG)
    return cfg


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """Everything the dry-run needs to lower one (arch x shape) cell."""

    arch: str
    shape: InputShape
    cfg: ModelConfig
    model: Model
    step_fn: Callable
    abstract_args: tuple            # ShapeDtypeStructs, step_fn(*args)
    donate_argnums: tuple[int, ...]
    kind: str                        # "train" | "prefill" | "decode"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _abstract_params(model: Model, dtype) -> PyTree:
    return jax.eval_shape(lambda: model.init(jax.random.key(0), dtype=dtype))


def _abstract_cache(model: Model, batch: int, capacity: int) -> PyTree:
    return jax.eval_shape(
        lambda: model.init_cache(batch, capacity, dtype=jnp.bfloat16)
    )


def make_optimizer(moment_dtype=jnp.float32) -> AdamW:
    return AdamW(learning_rate=3e-4, moment_dtype=moment_dtype)


def build_step(arch: str, shape_name: str, *,
               moment_dtype=jnp.float32,
               remat: bool = True,
               logits_mode: str = "last",
               act_pspec=None,
               cast_params_bf16: bool = False,
               moe_ep_constraint: bool = False,
               moe_impl: str = "einsum") -> StepBundle:
    shape = SHAPES[shape_name]
    cfg = serving_config(arch, shape_name)
    if cfg.moe is not None and (moe_ep_constraint or moe_impl != "einsum"):
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, ep_sharding_constraint=moe_ep_constraint,
                impl=moe_impl)
        )
    model = Model(cfg)
    b, t = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = make_optimizer(moment_dtype)
        params = _abstract_params(model, jnp.float32)
        state = jax.eval_shape(
            lambda p: TrainState(p, opt.init(p)), params
        )
        tokens = _sds((b, t), jnp.int32)
        labels = _sds((b, t), jnp.int32)
        step = make_train_step(model, opt, remat=remat, act_pspec=act_pspec,
                               cast_params_bf16=cast_params_bf16)
        return StepBundle(arch, shape, cfg, model, step,
                          (state, tokens, labels), (0,), "train")

    params = _abstract_params(model, jnp.bfloat16)

    if shape.kind == "prefill":
        if cfg.is_encoder_only:
            def encode_step(p, inputs):
                out = model.forward(p, **inputs, logits_mode="all",
                                    act_pspec=act_pspec)
                return {"logits": out.logits, "risk_score": out.risk_score}

            inputs = {"embeds": _sds((b, t, cfg.d_model), jnp.bfloat16)}
            return StepBundle(arch, shape, cfg, model, encode_step,
                              (params, inputs), (), "prefill")

        def prefill_step(p, inputs):
            out, cache = model.prefill(
                p, **inputs, cache_capacity=t, logits_mode=logits_mode,
                act_pspec=act_pspec,
            )
            return {"logits": out.logits, "risk_score": out.risk_score,
                    "cache": cache}

        if cfg.embeds_input:
            inputs = {"embeds": _sds((b, t, cfg.d_model), jnp.bfloat16)}
        else:
            inputs = {"tokens": _sds((b, t), jnp.int32)}
        return StepBundle(arch, shape, cfg, model, prefill_step,
                          (params, inputs), (), "prefill")

    # decode: one token, cache of capacity seq_len (window for sliding)
    if not cfg.has_decode:
        raise ValueError(f"{arch} is encoder-only: no decode shapes")
    cache = _abstract_cache(model, b, t)

    def serve_step(p, cache_in, inputs, pos):
        out = model.decode_step(p, cache_in, **inputs, pos=pos,
                                act_pspec=act_pspec)
        return {"logits": out.logits, "risk_score": out.risk_score,
                "cache": out.cache}

    if cfg.embeds_input:
        inputs = {"embeds": _sds((b, 1, cfg.d_model), jnp.bfloat16)}
    else:
        inputs = {"tokens": _sds((b, 1), jnp.int32)}
    pos = _sds((), jnp.int32)
    return StepBundle(arch, shape, cfg, model, serve_step,
                      (params, cache, inputs, pos), (1,), "decode")
