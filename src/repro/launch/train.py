"""Training launcher: run the SAME train_step the dry-run lowers, on real
devices (all available — CPU host devices or a TPU slice), with the
production sharding rules applied to whatever mesh fits.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 50 --batch 16 --seq 64

On a real slice, drop --smoke to train the full config (the mesh is derived
from the device count as (data = n/model, model = min(16, n))).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch import mesh as mesh_lib
from repro.launch import shardings
from repro.models.model import Model
from repro.training.data import TokenStream
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.train import TrainState, make_train_step


def make_mesh_for_devices() -> jax.sharding.Mesh:
    n = len(jax.devices())
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--bf16-moments", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    mesh = make_mesh_for_devices()
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"params ~{cfg.param_count()/1e6:.1f}M")

    opt = AdamW(
        learning_rate=cosine_schedule(args.lr, 10, args.steps),
        moment_dtype=jnp.bfloat16 if args.bf16_moments else jnp.float32,
    )
    step_fn = make_train_step(model, opt, remat=True,
                              compute_dtype=jnp.float32)

    with mesh:
        params = model.init(jax.random.key(0))
        psh = shardings.params_shardings(params, mesh)
        params = jax.device_put(params, psh)
        state = TrainState(params, opt.init(params))
        tok_sh = shardings.tokens_sharding(args.batch, mesh)
        jitted = jax.jit(step_fn, donate_argnums=(0,))

        stream = iter(TokenStream(cfg.vocab_size, args.seq, args.batch))
        for step in range(1, args.steps + 1):
            tokens, labels = next(stream)
            state, metrics = jitted(
                state,
                jax.device_put(jnp.asarray(tokens), tok_sh),
                jax.device_put(jnp.asarray(labels), tok_sh),
            )
            if step % max(args.steps // 10, 1) == 0:
                print(f"step {step:5d}  loss {float(metrics.loss):.4f}  "
                      f"gnorm {float(metrics.grad_norm):.3f}")
    print("done")


if __name__ == "__main__":
    main()
