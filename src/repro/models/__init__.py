"""Model zoo: composable JAX definitions for the assigned architecture pool."""
from repro.models.config import (
    BlockSpec,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    XLSTMConfig,
)
from repro.models.model import DecodeOutput, Model, ModelOutput

__all__ = [
    "BlockSpec", "MambaConfig", "ModelConfig", "MoEConfig", "XLSTMConfig",
    "DecodeOutput", "Model", "ModelOutput",
]
