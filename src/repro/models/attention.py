"""Attention: GQA with RoPE / M-RoPE / qk-norm, chunked (flash-style) prefill,
sliding-window variants, and single-token decode over KV caches.

The prefill path is *chunked over queries* (``lax.scan``) so the materialized
score block is (B, C, H, T) instead of (B, T, H, T) — the pure-JAX analogue of
flash attention's memory behaviour (exact softmax per query row, no O(T^2)
resident tensor).  ``kernels/flash_attention.py`` provides the Pallas TPU
version; this module is also its oracle at small sizes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-attention-layer cache.

    ``k``/``v``: (B, S, n_kv, head_dim) where S is the capacity — the full
    sequence for dense decode, or the window size for sliding-window decode
    (ring buffer, RoPE pre-applied at absolute positions before writing).
    """

    k: Array
    v: Array


def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> PyTree:
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.head_dim
    params = {
        "wq": layers.init_linear(kq, d, cfg.n_heads * hd, dtype=dtype),
        "wk": layers.init_linear(kk, d, cfg.n_kv_heads * hd, dtype=dtype),
        "wv": layers.init_linear(kv, d, cfg.n_kv_heads * hd, dtype=dtype),
        "wo": layers.init_linear(ko, cfg.n_heads * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), dtype)
        params["k_norm"] = jnp.ones((hd,), dtype)
    return params


def _gqa_scores_chunked(
    q: Array,            # (B, Tq, Hq, D)
    k: Array,            # (B, Tk, Hkv, D)
    v: Array,            # (B, Tk, Hkv, D)
    *,
    causal: bool,
    q_offset: Array | int,
    sliding_window: int,
    kv_valid_len: Array | None = None,
    chunk: int = 256,
) -> Array:
    """Exact attention, scanned over query chunks. Returns (B, Tq, Hq, D)."""
    b, tq, hq, d = q.shape
    tk, hkv = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    chunk = min(chunk, tq)
    n_chunks = -(-tq // chunk)
    pad = n_chunks * chunk - tq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qc = q.reshape(b, n_chunks, chunk, hkv, qpk, d)
    kpos = jnp.arange(tk)

    def one_chunk(carry, inputs):
        ci, q_blk = inputs  # q_blk: (B, C, Hkv, qpk, D)
        logits = jnp.einsum(
            "bchgd,bthd->bchgt", q_blk.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        qpos = q_offset + ci * chunk + jnp.arange(chunk)  # (C,)
        mask = jnp.ones((chunk, tk), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if sliding_window > 0:
            mask &= kpos[None, :] > qpos[:, None] - sliding_window
        if kv_valid_len is not None:
            mask &= kpos[None, :] < kv_valid_len
        logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bchgt,bthd->bchgd", probs, v.astype(jnp.float32))
        return carry, out.astype(q_blk.dtype)

    _, outs = jax.lax.scan(
        one_chunk, None, (jnp.arange(n_chunks), jnp.moveaxis(qc, 1, 0))
    )
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, hq, d)
    return out[:, :tq]


def attention_forward(
    params: PyTree,
    x: Array,                     # (B, T, d_model)
    cfg: ModelConfig,
    *,
    angles: Array | None,         # (B, T, head_dim/2) rope angles (None = no rope)
    cache: KVCache | None = None,
    cache_pos: Array | int = 0,   # absolute position of x[:, 0]
    chunk: int = 256,
    attn_impl: str = "reference",
) -> tuple[Array, KVCache | None]:
    """Unified attention entry point.

    * train / prefill: ``cache is None`` -> self-attention over x, optionally
      returning a fresh cache would be handled by the caller via k/v outputs
      (we return None; prefill cache construction happens in model.py).
    * decode: ``cache`` given, T == 1 -> write k/v at ``cache_pos`` (modulo
      window for sliding-window layers) and attend over the cache.
    """
    b, t, _ = x.shape
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    q = layers.linear(params["wq"], x).reshape(b, t, hq, hd)
    k = layers.linear(params["wk"], x).reshape(b, t, hkv, hd)
    v = layers.linear(params["wv"], x).reshape(b, t, hkv, hd)

    if cfg.qk_norm:
        q = layers.rmsnorm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm_headwise(params["k_norm"], k, cfg.norm_eps)

    if angles is not None:
        q = layers.apply_rope(q, angles)
        k = layers.apply_rope(k, angles)

    if cache is None:
        if attn_impl == "pallas" and t >= 128:
            from repro.kernels import ops as kops
            out = kops.flash_attention(
                q, k, v, causal=cfg.causal, sliding_window=cfg.sliding_window
            )
        else:
            out = _gqa_scores_chunked(
                q, k, v,
                causal=cfg.causal, q_offset=0,
                sliding_window=cfg.sliding_window, chunk=chunk,
            )
        new_cache = None
    else:
        # decode: t is 1 (or small); write into cache then attend.
        capacity = cache.k.shape[1]
        if cfg.sliding_window > 0 and capacity == cfg.sliding_window:
            write_idx = jnp.asarray(cache_pos) % capacity
        else:
            write_idx = jnp.asarray(cache_pos)
        k_new = jax.lax.dynamic_update_slice(
            cache.k, k.astype(cache.k.dtype), (0, write_idx, 0, 0)
        )
        v_new = jax.lax.dynamic_update_slice(
            cache.v, v.astype(cache.v.dtype), (0, write_idx, 0, 0)
        )
        new_cache = KVCache(k=k_new, v=v_new)
        if cfg.sliding_window > 0 and capacity == cfg.sliding_window:
            # ring buffer: every slot valid once pos >= capacity; positions
            # are implicit (RoPE pre-applied), no causal mask needed beyond
            # validity. For pos < capacity only slots <= pos are valid.
            valid = jnp.minimum(jnp.asarray(cache_pos) + 1, capacity)
            out = _gqa_scores_chunked(
                q, k_new, v_new, causal=False, q_offset=cache_pos,
                sliding_window=0, kv_valid_len=valid, chunk=chunk,
            )
        else:
            valid = jnp.asarray(cache_pos) + 1
            out = _gqa_scores_chunked(
                q, k_new, v_new, causal=False, q_offset=cache_pos,
                sliding_window=0, kv_valid_len=valid, chunk=chunk,
            )

    out = out.reshape(b, t, hq * hd)
    return layers.linear(params["wo"], out), new_cache


def prefill_kv(
    params: PyTree,
    x: Array,
    cfg: ModelConfig,
    *,
    angles: Array | None,
    capacity: int,
) -> KVCache:
    """Build a decode cache from a prompt (used by serve prefill)."""
    b, t, _ = x.shape
    hd, hkv = cfg.head_dim, cfg.n_kv_heads
    k = layers.linear(params["wk"], x).reshape(b, t, hkv, hd)
    v = layers.linear(params["wv"], x).reshape(b, t, hkv, hd)
    if cfg.qk_norm:
        k = layers.rmsnorm_headwise(params["k_norm"], k, cfg.norm_eps)
    if angles is not None:
        k = layers.apply_rope(k, angles)
    if cfg.sliding_window > 0:
        w = min(cfg.sliding_window, capacity)
        orig_t = t
        k, v = k[:, -w:], v[:, -w:]
        t = k.shape[1]
        capacity = w
        if orig_t >= w:
            # Align the ring buffer so absolute position p sits at slot p % w:
            # token t-w+i must land at slot (t-w+i) % w = (i + t % w) % w.
            k = jnp.roll(k, shift=orig_t % w, axis=1)
            v = jnp.roll(v, shift=orig_t % w, axis=1)
    pad = capacity - t
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return KVCache(k=k, v=v)
