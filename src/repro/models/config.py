"""Architecture configuration schema.

One :class:`ModelConfig` describes any architecture in the assigned pool:
dense GQA decoders, MoE, VLM backbones, audio encoders, SSMs (xLSTM), and
hybrids (Jamba).  ``layer_pattern`` encodes the repeating block structure so
hybrid stacks can be scanned over their period (keeping HLO size bounded for
126-layer models).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

MixerKind = Literal["attn", "mamba", "mlstm", "slstm"]
FFNKind = Literal["mlp", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer's composition: a sequence mixer + a feed-forward block."""

    mixer: MixerKind = "attn"
    ffn: FFNKind = "mlp"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 1024
    # capacity factor for GShard-style dispatch: capacity per expert =
    # ceil(tokens * top_k / num_experts * capacity_factor)
    capacity_factor: float = 1.25
    shared_expert: bool = False          # llama4-style always-on expert
    d_ff_shared: int = 0
    router_aux_loss_weight: float = 0.01  # load-balance auxiliary loss
    router_jitter: float = 0.0
    # §Perf knob: constrain expert buffers to the "model" mesh axis so the
    # dispatch einsum reduce-scatters each rank's own experts instead of
    # all-reducing the full (E, cap, d) buffer (16x fewer bytes at model=16).
    ep_sharding_constraint: bool = False
    # "einsum": GShard-style one-hot dispatch (portable, all-reduce-heavy);
    # "a2a": shard_map expert parallelism with explicit all_to_all dispatch
    # (the TPU-native schedule — see models/moe_a2a.py and §Perf).
    impl: str = "einsum"


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2          # d_inner = expand * d_model
    dt_rank: int = 0         # 0 -> ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0   # up-projection factor for mLSTM blocks
    slstm_proj_factor: float = 4.0 / 3.0
    chunk_size: int = 128            # chunkwise-parallel mLSTM chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: Literal["dense", "moe", "vlm", "audio", "ssm", "hybrid"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # layer pattern: the stack is n_layers/len(pattern) repetitions of this
    # block tuple. Dense models: a single ("attn","mlp") entry.
    layer_pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    # attention
    rope_theta: float = 10000.0
    qk_norm: bool = False             # qwen3-style per-head q/k RMSNorm
    mrope: bool = False               # qwen2-vl multimodal RoPE (t/h/w sections)
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # in rotary half-dims
    causal: bool = True               # False for encoder-only (hubert)
    sliding_window: int = 0           # 0 = full attention; >0 = window size
    # sub-configs
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    xlstm: XLSTMConfig | None = None
    # frontends (VLM/audio): embeddings come precomputed from a stub frontend
    embeds_input: bool = False
    # serving / scoring head
    score_head: bool = True
    # numerics
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # citation / provenance
    source: str = ""

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}"
            )
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError(f"{self.name}: n_heads must be a multiple of n_kv_heads")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def has_decode(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + heads)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        for spec in self.layer_pattern * self.n_groups:
            if spec.mixer == "attn":
                total += d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
                total += 2 * d  # norms
                if self.qk_norm:
                    total += 2 * hd
            elif spec.mixer == "mamba":
                mc = self.mamba or MambaConfig()
                d_in = mc.expand * d
                dt_rank = mc.dt_rank or -(-d // 16)
                total += d * 2 * d_in            # in_proj (x, z)
                total += d_in * mc.d_conv        # conv
                total += d_in * (dt_rank + 2 * mc.d_state)  # x -> dt, B, C
                total += dt_rank * d_in          # dt_proj
                total += d_in * mc.d_state       # A_log
                total += d_in                    # D
                total += d_in * d                # out_proj
                total += d                       # norm
            elif spec.mixer == "mlstm":
                xc = self.xlstm or XLSTMConfig()
                d_in = int(xc.mlstm_proj_factor * d)
                hd_in = d_in // self.n_heads
                total += d * 2 * d_in            # up proj (x, z)
                total += 3 * d_in * hd_in        # q, k, v (head-wise blocks)
                total += d_in * 2 * self.n_heads # i, f gate projections
                total += d_in * d                # down proj
                total += d                       # norm
            elif spec.mixer == "slstm":
                xc = self.xlstm or XLSTMConfig()
                total += 4 * d * d + 4 * d * d   # input + recurrent (i,f,z,o)
                total += 4 * d                   # biases
                f = xc.slstm_proj_factor
                total += int(d * d * f * 2)      # ffn-ish up/down
                total += d
            if spec.ffn == "mlp":
                total += 3 * d * self.d_ff + d   # swiglu + norm
            elif spec.ffn == "moe":
                mo = self.moe
                assert mo is not None
                total += d * mo.num_experts      # router
                total += mo.num_experts * 3 * d * mo.d_ff_expert
                if mo.shared_expert:
                    total += 3 * d * (mo.d_ff_shared or mo.d_ff_expert)
                total += d
        total += d  # final norm
        if self.score_head:
            total += d + 1
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for s in self.layer_pattern if s.ffn == "moe") * self.n_groups
        per_layer_expert = 3 * self.d_model * mo.d_ff_expert
        inactive = n_moe_layers * (mo.num_experts - mo.top_k) * per_layer_expert
        return full - inactive
