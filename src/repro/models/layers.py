"""Shared neural-net building blocks (pure JAX, pytree params)."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def _dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def init_linear(key, in_dim: int, out_dim: int, *, bias: bool = False,
                dtype=jnp.float32) -> PyTree:
    params = {"w": _dense_init(key, in_dim, out_dim, dtype)}
    if bias:
        params["b"] = jnp.zeros((out_dim,), dtype)
    return params


def linear(params: PyTree, x: Array) -> Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def init_rmsnorm(d: int, dtype=jnp.float32) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: PyTree, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_headwise(scale: Array, x: Array, eps: float = 1e-5) -> Array:
    """qk-norm: RMSNorm over the head_dim axis of (..., heads, head_dim)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> PyTree:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params: PyTree, tokens: Array, dtype=jnp.bfloat16) -> Array:
    return params["table"].astype(dtype)[tokens]


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: Array, head_dim: int, theta: float) -> Array:
    """positions (..., T) -> angles (..., T, head_dim/2)."""
    inv = rope_frequencies(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x: Array, angles: Array) -> Array:
    """x: (B, T, H, D); angles: (B, T, D/2) or (T, D/2)."""
    dtype = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    if angles.ndim == 2:  # (T, D/2) -> broadcast batch
        angles = angles[None]
    cos = jnp.cos(angles)[..., None, :]  # (B, T, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(dtype)


def mrope_angles(position_ids: Array, head_dim: int, theta: float,
                 sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE (arXiv:2409.12191).

    ``position_ids``: (3, B, T) — temporal / height / width position ids.
    The rotary half-dim is partitioned into three contiguous sections that
    take their angle from the t/h/w id respectively.  For pure-text tokens
    all three ids coincide and M-RoPE reduces exactly to standard RoPE.
    Returns angles (B, T, head_dim/2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(head_dim, theta)          # (half,)
    ang = position_ids[..., None].astype(jnp.float32) * inv  # (3, B, T, half)
    sec_idx = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )  # (half,) -> which of t/h/w drives each channel
    return jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1),                    # (B, T, half, 3)
        sec_idx[None, None, :, None],
        axis=-1,
    )[..., 0]                                        # (B, T, half)


def text_position_ids(batch: int, seq: int, offset: Array | int = 0) -> Array:
    """(3, B, T) position ids for text-only input (t = h = w)."""
    pos = jnp.arange(seq)[None, :] + jnp.asarray(offset)
    pos = jnp.broadcast_to(pos, (batch, seq))
    return jnp.broadcast_to(pos[None], (3, batch, seq))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, dtype=dtype),
        "up": init_linear(k2, d_model, d_ff, dtype=dtype),
        "down": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(params: PyTree, x: Array) -> Array:
    g = jax.nn.silu(linear(params["gate"], x))
    u = linear(params["up"], x)
    return linear(params["down"], g * u)
