"""Mamba (S6) selective state-space mixer — TPU-adapted chunked scan.

Hardware adaptation (DESIGN.md §2): the CUDA reference fuses the selective
scan so the (d_inner × d_state) per-timestep states never hit HBM.  The TPU-
native equivalent here is a **chunked two-level scan**: a sequential
`lax.scan` over chunks carries the (B, d_inner, N) state, and within each
chunk a `lax.associative_scan` (log-depth) materializes only
(B, Q, d_inner, N) — bounded VMEM-scale working set per chunk instead of the
O(T · d_inner · N) tensor a naive associative scan over the full sequence
would allocate.  Semantics are exactly Mamba-1 (diagonal A, per-channel dt).

Decode is the O(1) recurrent step with a (B, d_conv-1, d_inner) conv tail and
a (B, d_inner, N) SSM state — constant memory at 500k+ context.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import MambaConfig

Array = jax.Array
PyTree = Any


class MambaState(NamedTuple):
    conv: Array  # (B, d_conv-1, d_inner) — trailing inputs for the causal conv
    ssm: Array   # (B, d_inner, N) — recurrent SSM state (f32)


def dt_rank_of(d_model: int, mc: MambaConfig) -> int:
    return mc.dt_rank or -(-d_model // 16)


def init_mamba(key, d_model: int, mc: MambaConfig, dtype=jnp.float32) -> PyTree:
    d_in = mc.expand * d_model
    rank = dt_rank_of(d_model, mc)
    keys = jax.random.split(key, 6)
    # S4D-real initialization for A; dt bias targets softplus^{-1}(dt)
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None, :],
                 (d_in, 1))
    dt_init = jnp.exp(
        jax.random.uniform(keys[4], (d_in,)) * (math.log(0.1) - math.log(0.001))
        + math.log(0.001)
    )
    dt_bias = jnp.log(jnp.expm1(dt_init))  # inverse softplus
    return {
        "in_proj": layers.init_linear(keys[0], d_model, 2 * d_in, dtype=dtype),
        "conv_w": jax.random.normal(keys[1], (mc.d_conv, d_in), dtype) * 0.1,
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": layers.init_linear(keys[2], d_in, rank + 2 * mc.d_state,
                                     dtype=dtype),
        "dt_proj": layers.init_linear(keys[3], rank, d_in, dtype=dtype),
        "dt_bias": dt_bias.astype(dtype),
        "A_log": jnp.log(a).astype(dtype),
        "D": jnp.ones((d_in,), dtype),
        "out_proj": layers.init_linear(keys[5], d_in, d_model, dtype=dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array, tail: Array | None = None) -> Array:
    """Depthwise causal conv over time. x: (B, T, C); w: (K, C).

    ``tail``: (B, K-1, C) previous inputs (decode / chunk continuation); zeros
    if None.  Implemented as K shifted adds — K is 4, this beats conv calls on
    both TPU and in compile time.
    """
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, T+K-1, C)
    t = x.shape[1]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + t] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _ssm_chunk(abar: Array, bx: Array, h0: Array) -> tuple[Array, Array]:
    """Within-chunk diagonal SSM via associative scan.

    abar, bx: (B, Q, C, N);  h0: (B, C, N).
    h_t = abar_t * h_{t-1} + bx_t.  Returns (h over chunk, final h).
    """

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, a2 * b1 + b2

    acc_a, acc_b = jax.lax.associative_scan(combine, (abar, bx), axis=1)
    h = acc_a * h0[:, None] + acc_b
    return h, h[:, -1]


def mamba_forward(
    params: PyTree,
    x: Array,
    mc: MambaConfig,
    *,
    chunk_size: int = 256,
    initial_state: MambaState | None = None,
    return_state: bool = False,
) -> tuple[Array, MambaState | None]:
    """Full-sequence mixer. x: (B, T, d_model) -> (B, T, d_model)."""
    b, t, d_model = x.shape
    d_in = mc.expand * d_model
    rank = dt_rank_of(d_model, mc)
    n = mc.d_state

    xz = layers.linear(params["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)

    conv_tail = initial_state.conv if initial_state is not None else None
    x_conv = jax.nn.silu(
        _causal_conv(x_in, params["conv_w"], params["conv_b"], conv_tail)
    )

    proj = layers.linear(params["x_proj"], x_conv)
    dt_raw, b_mat, c_mat = jnp.split(proj, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(
        layers.linear(params["dt_proj"], dt_raw)
        + params["dt_bias"].astype(x.dtype)
    ).astype(jnp.float32)                                   # (B, T, d_in)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))       # (d_in, N)

    # chunked scan: sequential over chunks, associative within
    q = min(chunk_size, t)
    n_chunks = -(-t // q)
    pad = n_chunks * q - t
    def padt(arr):
        return jnp.pad(arr, ((0, 0), (0, pad)) + ((0, 0),) * (arr.ndim - 2))
    dt_c = padt(dt).reshape(b, n_chunks, q, d_in)
    xc_c = padt(x_conv.astype(jnp.float32)).reshape(b, n_chunks, q, d_in)
    b_c = padt(b_mat.astype(jnp.float32)).reshape(b, n_chunks, q, n)
    c_c = padt(c_mat.astype(jnp.float32)).reshape(b, n_chunks, q, n)

    h0 = (
        initial_state.ssm
        if initial_state is not None
        else jnp.zeros((b, d_in, n), jnp.float32)
    )

    def chunk_step(h, inp):
        dt_i, xc_i, b_i, c_i = inp  # (B, Q, ...)
        abar = jnp.exp(dt_i[..., None] * a)                    # (B,Q,d_in,N)
        bx = (dt_i * xc_i)[..., None] * b_i[:, :, None, :]     # (B,Q,d_in,N)
        h_seq, h_last = _ssm_chunk(abar, bx, h)
        y = jnp.einsum("bqcn,bqn->bqc", h_seq, c_i)            # (B,Q,d_in)
        return h_last, y

    h_final, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            jnp.moveaxis(dt_c, 1, 0),
            jnp.moveaxis(xc_c, 1, 0),
            jnp.moveaxis(b_c, 1, 0),
            jnp.moveaxis(c_c, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, n_chunks * q, d_in)[:, :t]
    y = y + x_conv.astype(jnp.float32) * params["D"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = layers.linear(params["out_proj"], y)

    state = None
    if return_state:
        k = params["conv_w"].shape[0]
        tail_src = x_in if initial_state is None else jnp.concatenate(
            [initial_state.conv, x_in], axis=1
        )
        conv_tail = tail_src[:, -(k - 1):]
        if conv_tail.shape[1] < k - 1:
            conv_tail = jnp.pad(
                conv_tail, ((0, 0), (k - 1 - conv_tail.shape[1], 0), (0, 0))
            )
        state = MambaState(conv=conv_tail, ssm=h_final)
    return out, state


def mamba_decode_step(
    params: PyTree, x: Array, mc: MambaConfig, state: MambaState
) -> tuple[Array, MambaState]:
    """One-token step. x: (B, 1, d_model)."""
    b, _, d_model = x.shape
    d_in = mc.expand * d_model
    rank = dt_rank_of(d_model, mc)
    n = mc.d_state

    xz = layers.linear(params["in_proj"], x)
    x_in, z = jnp.split(xz, 2, axis=-1)       # (B, 1, d_in)

    x_conv = jax.nn.silu(
        _causal_conv(x_in, params["conv_w"], params["conv_b"], state.conv)
    )
    new_conv = jnp.concatenate([state.conv, x_in], axis=1)[:, 1:]

    proj = layers.linear(params["x_proj"], x_conv)
    dt_raw, b_mat, c_mat = jnp.split(proj, [rank, rank + n], axis=-1)
    dt = jax.nn.softplus(
        layers.linear(params["dt_proj"], dt_raw) + params["dt_bias"].astype(x.dtype)
    ).astype(jnp.float32)[:, 0]                # (B, d_in)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    abar = jnp.exp(dt[..., None] * a)          # (B, d_in, N)
    bx = (dt * x_conv.astype(jnp.float32)[:, 0])[..., None] * b_mat.astype(
        jnp.float32
    )[:, 0, None, :]
    h = abar * state.ssm + bx
    y = jnp.einsum("bcn,bn->bc", h, c_mat.astype(jnp.float32)[:, 0])
    y = y + x_conv.astype(jnp.float32)[:, 0] * params["D"].astype(jnp.float32)
    y = (y[:, None] * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = layers.linear(params["out_proj"], y)
    return out, MambaState(conv=new_conv, ssm=h)


def init_mamba_state(batch: int, d_model: int, mc: MambaConfig,
                     dtype=jnp.bfloat16) -> MambaState:
    d_in = mc.expand * d_model
    return MambaState(
        conv=jnp.zeros((batch, mc.d_conv - 1, d_in), dtype),
        ssm=jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
    )
