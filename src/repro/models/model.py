"""Model: config -> init / forward / prefill / decode_step entry points.

Every architecture exposes the same four callables, which is what lets the
serving layer (predictors, routing) treat heterogeneous experts uniformly —
the paper's predictor abstraction requires exactly this interface shape.

Outputs always include the **risk score head** (sigmoid scalar per sequence):
the raw expert score that MUSE's transformation pipeline (T^C -> A -> T^Q)
consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


class ModelOutput(NamedTuple):
    logits: Array       # (B, T, vocab) — LM / frame-unit logits
    risk_score: Array   # (B,) — raw expert score in [0, 1]
    moe_aux: Array      # () — load-balance auxiliary loss
    hidden: Array       # (B, T, d) final hidden states


class DecodeOutput(NamedTuple):
    logits: Array       # (B, vocab) next-token logits
    risk_score: Array   # (B,)
    cache: Any


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------
    def init(self, rng, dtype=jnp.float32) -> PyTree:
        cfg = self.cfg
        k_emb, k_stack, k_head, k_score = jax.random.split(rng, 4)
        params: dict[str, PyTree] = {
            "embed": layers.init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype),
            "stack": transformer.init_stack(k_stack, cfg, dtype),
            "final_norm": layers.init_rmsnorm(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.init_linear(
                k_head, cfg.d_model, cfg.vocab_size, dtype=dtype
            )
        if cfg.score_head:
            params["score_head"] = layers.init_linear(
                k_score, cfg.d_model, 1, bias=True, dtype=dtype
            )
        return params

    # -- shared pieces ---------------------------------------------------------
    def _embed_input(self, params, tokens, embeds, compute_dtype):
        if embeds is not None:
            return embeds.astype(compute_dtype)
        return layers.embed(params["embed"], tokens, compute_dtype)

    def _angles(self, batch: int, seq: int, offset, position_ids):
        cfg = self.cfg
        if cfg.mrope:
            if position_ids is None:
                position_ids = layers.text_position_ids(batch, seq, offset)
            return layers.mrope_angles(
                position_ids, cfg.head_dim, cfg.rope_theta, cfg.mrope_sections
            )
        pos = jnp.arange(seq) + jnp.asarray(offset)
        return layers.rope_angles(pos, cfg.head_dim, cfg.rope_theta)  # (T, half)

    def _heads(self, params, h, compute_dtype, logits_mode: str = "all"):
        cfg = self.cfg
        h_norm = layers.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        h_lm = h_norm[:, -1:] if logits_mode == "last" else h_norm
        if cfg.tie_embeddings:
            logits = h_lm @ params["embed"]["table"].astype(compute_dtype).T
        else:
            logits = layers.linear(params["lm_head"], h_lm)
        if cfg.score_head:
            # decoder: last-token hidden; encoder: mean pool
            pooled = (
                jnp.mean(h_norm, axis=1) if cfg.is_encoder_only else h_norm[:, -1]
            )
            raw = layers.linear(params["score_head"], pooled)[..., 0]
            score = jax.nn.sigmoid(raw.astype(jnp.float32))
        else:
            score = jnp.zeros(h.shape[0], jnp.float32)
        return logits, score, h_norm

    # -- full-sequence forward (train / eval / encoder serve) ----------------
    def forward(
        self,
        params: PyTree,
        tokens: Array | None = None,
        embeds: Array | None = None,
        *,
        position_ids: Array | None = None,
        remat: bool = False,
        compute_dtype=jnp.bfloat16,
        attn_impl: str = "reference",
        logits_mode: str = "all",
        act_pspec=None,
    ) -> ModelOutput:
        cfg = self.cfg
        x = self._embed_input(params, tokens, embeds, compute_dtype)
        b, t = x.shape[:2]
        angles = self._angles(b, t, 0, position_ids)
        x, _, aux = transformer.stack_forward(
            params["stack"], x, cfg, angles=angles, mode="forward",
            remat=remat, attn_impl=attn_impl, act_pspec=act_pspec,
        )
        logits, score, h = self._heads(params, x, compute_dtype, logits_mode)
        return ModelOutput(logits=logits, risk_score=score, moe_aux=aux, hidden=h)

    # -- prefill: build decode caches from a prompt --------------------------
    def prefill(
        self,
        params: PyTree,
        tokens: Array | None = None,
        embeds: Array | None = None,
        *,
        cache_capacity: int,
        position_ids: Array | None = None,
        compute_dtype=jnp.bfloat16,
        cache_dtype=jnp.bfloat16,
        attn_impl: str = "reference",
        logits_mode: str = "all",
        act_pspec=None,
    ) -> tuple[ModelOutput, list[PyTree]]:
        cfg = self.cfg
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode/prefill")
        x = self._embed_input(params, tokens, embeds, compute_dtype)
        b, t = x.shape[:2]
        angles = self._angles(b, t, 0, position_ids)
        cache = transformer.init_cache(cfg, b, cache_capacity, cache_dtype)
        x, new_cache, aux = transformer.stack_forward(
            params["stack"], x, cfg, angles=angles, mode="prefill",
            cache=cache, attn_impl=attn_impl, act_pspec=act_pspec,
        )
        logits, score, h = self._heads(params, x, compute_dtype, logits_mode)
        return ModelOutput(logits, score, aux, h), new_cache

    # -- decode: one token against an existing cache -------------------------
    def decode_step(
        self,
        params: PyTree,
        cache: list[PyTree],
        tokens: Array | None = None,
        embeds: Array | None = None,
        *,
        pos: Array | int,
        position_ids: Array | None = None,
        compute_dtype=jnp.bfloat16,
        attn_impl: str = "reference",
        act_pspec=None,
    ) -> DecodeOutput:
        cfg = self.cfg
        if not cfg.has_decode:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        x = self._embed_input(params, tokens, embeds, compute_dtype)
        b = x.shape[0]
        angles = self._angles(b, 1, pos, position_ids)
        x, new_cache, _ = transformer.stack_forward(
            params["stack"], x, cfg, angles=angles, mode="decode",
            cache=cache, cache_pos=pos, attn_impl=attn_impl,
            act_pspec=act_pspec,
        )
        logits, score, _ = self._heads(params, x, compute_dtype)
        return DecodeOutput(
            logits=logits[:, 0], risk_score=score, cache=new_cache
        )

    # -- convenience ----------------------------------------------------------
    def init_cache(self, batch: int, capacity: int, dtype=jnp.bfloat16):
        return transformer.init_cache(self.cfg, batch, capacity, dtype)

    def param_count(self, params: PyTree) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))
