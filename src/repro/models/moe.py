"""Mixture-of-Experts feed-forward with capacity-based dispatch.

GShard/Switch-style top-k routing expressed as dense one-hot einsums so the
whole block is jit/pjit friendly:

    tokens --router--> top-k experts --dispatch one-hot--> per-expert slots
           --expert SwiGLU (batched over E)--> combine weighted by gate probs

Experts are *expert-parallel*: the leading E axis of every expert weight is
sharded on the mesh "model" axis; the dispatch/combine einsums then lower to
the all-to-all-class collectives the roofline analysis tracks.

Honest-FLOPs note: compute per layer is E × capacity × ffn ≈
top_k × tokens × ffn × capacity_factor — i.e. proportional to *active*
parameters, not total (no dense-all-experts shortcut), so `cost_analysis`
reflects the real MoE arithmetic.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import MoEConfig

Array = jax.Array
PyTree = Any


def init_moe(key, d_model: int, mo: MoEConfig, dtype=jnp.float32) -> PyTree:
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    e, dff = mo.num_experts, mo.d_ff_expert
    import math
    scale = 1.0 / math.sqrt(d_model)
    params = {
        "router": layers.init_linear(kr, d_model, e, dtype=dtype),
        "gate": jax.random.uniform(kg, (e, d_model, dff), dtype, -scale, scale),
        "up": jax.random.uniform(ku, (e, d_model, dff), dtype, -scale, scale),
        "down": jax.random.uniform(kd, (e, dff, d_model), dtype,
                                   -1.0 / math.sqrt(dff), 1.0 / math.sqrt(dff)),
    }
    if mo.shared_expert:
        params["shared"] = layers.init_mlp(
            ks, d_model, mo.d_ff_shared or mo.d_ff_expert, dtype=dtype
        )
    return params


def _capacity(n_tokens: int, mo: MoEConfig) -> int:
    cap = int(n_tokens * mo.top_k / mo.num_experts * mo.capacity_factor)
    return max(cap, mo.top_k)


# token-chunk size for the dispatch scan: bounds the transient one-hot
# (chunk, E, cap_chunk) tensor that a single global dispatch would blow up to
# O(n·E·cap) (1.3e12 elements for a 400B MoE at 1M tokens).
DISPATCH_CHUNK = 4096


def _dispatch_chunk(params: PyTree, xc: Array, gate_vals: Array,
                    expert_idx: Array, mo: MoEConfig, cap: int) -> Array:
    """GShard-style capacity dispatch for ONE token chunk.

    xc: (c, d); gate_vals/expert_idx: (c, k).  Returns (c, d).
    """
    c, d = xc.shape
    e, k = mo.num_experts, mo.top_k

    def ep(t):
        """Expert-parallel constraint: pin the E axis to the "model" mesh
        axis so cross-device reductions of expert buffers become
        reduce-scatters of each rank's own experts (§Perf)."""
        if mo.ep_sharding_constraint:
            from jax.sharding import PartitionSpec as P
            spec = ["model"] + [None] * (t.ndim - 1)
            return jax.lax.with_sharding_constraint(t, P(*spec))
        return t

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)    # (c, k, e)
    flat_choice = onehot.reshape(c * k, e)
    pos_in_expert = jnp.cumsum(flat_choice, axis=0) * flat_choice - 1
    pos_in_expert = pos_in_expert.reshape(c, k, e)
    within_cap = (pos_in_expert < cap) & (pos_in_expert >= 0)  # dropped if over

    slot_onehot = jax.nn.one_hot(
        jnp.where(within_cap, pos_in_expert, -1), cap, dtype=xc.dtype
    )  # (c, k, e, cap)
    dispatch = jnp.sum(slot_onehot, axis=1)                    # (c, e, cap)
    combine = jnp.sum(
        slot_onehot * gate_vals[..., None, None].astype(xc.dtype), axis=1
    )  # (c, e, cap)

    # route tokens to expert buffers:  (e, cap, d).
    # The dispatch/combine einsums contract over sharded axes, so their
    # partial sums are what the mesh all-reduces: keep them in the input
    # dtype (bf16) instead of f32 accumulation — each output element sums
    # <= top_k one-hot-selected terms, so bf16 is exact for top-1 and
    # rounding-safe for small k, and the collective bytes halve (§Perf).
    acc = xc.dtype
    expert_in = ep(jnp.einsum("nec,nd->ecd", dispatch, xc,
                              preferred_element_type=acc))
    g = jax.nn.silu(ep(jnp.einsum("ecd,edf->ecf", expert_in,
                                  params["gate"].astype(xc.dtype))))
    u = ep(jnp.einsum("ecd,edf->ecf", expert_in, params["up"].astype(xc.dtype)))
    expert_out = ep(jnp.einsum("ecf,efd->ecd", g * u,
                               params["down"].astype(xc.dtype)))
    return jnp.einsum("nec,ecd->nd", combine, expert_out,
                      preferred_element_type=acc)               # (c, d)


def moe_forward(params: PyTree, x: Array, mo: MoEConfig,
                *, dispatch_chunk: int = DISPATCH_CHUNK
                ) -> tuple[Array, Array]:
    """x: (B, T, d) -> (out, aux_loss).

    Dispatch runs in token chunks under ``lax.scan`` so the transient
    (chunk, E, cap) one-hot stays VMEM-scale; capacity is per chunk
    (cap = chunk·top_k/E·capacity_factor), which matches how real MoE
    runtimes bound skew per microbatch.

    aux_loss is the standard load-balance loss: E · Σ_e f_e · p_e where f_e is
    the fraction of tokens whose top-1 choice is e and p_e the mean router
    probability of e (encourages uniform expert utilization).
    """
    b, t, d = x.shape
    n = b * t
    e, k = mo.num_experts, mo.top_k

    xf = x.reshape(n, d)
    logits = layers.linear(params["router"], xf).astype(jnp.float32)  # (n, e)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates, renormalized over the selected experts
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    chunk = min(dispatch_chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    if pad:
        xf_p = jnp.pad(xf, ((0, pad), (0, 0)))
        gate_p = jnp.pad(gate_vals, ((0, pad), (0, 0)))  # zero gates: no-op
        idx_p = jnp.pad(expert_idx, ((0, pad), (0, 0)))
    else:
        xf_p, gate_p, idx_p = xf, gate_vals, expert_idx
    cap = _capacity(chunk, mo)

    if n_chunks == 1:
        out = _dispatch_chunk(params, xf_p, gate_p, idx_p, mo, cap)
    else:
        def body(_, inp):
            xc, gc, ic = inp
            return None, _dispatch_chunk(params, xc, gc, ic, mo, cap)

        _, outs = jax.lax.scan(
            body, None,
            (xf_p.reshape(n_chunks, chunk, d),
             gate_p.reshape(n_chunks, chunk, k),
             idx_p.reshape(n_chunks, chunk, k)),
        )
        out = outs.reshape(n_chunks * chunk, d)
    out = out[:n]

    if mo.shared_expert:
        out = out + layers.mlp(params["shared"], xf)

    # load-balance auxiliary loss (Switch Transformer, Eq. 4-6)
    top1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    f = jnp.mean(top1, axis=0)
    p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f * p)

    return out.reshape(b, t, d), aux
