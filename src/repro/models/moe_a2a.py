"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The TPU-native alternative to the einsum/one-hot GShard dispatch in
``moe.py``: that formulation makes GSPMD all-reduce full (E, cap, d) expert
buffers across the token-sharded axes on *every* dispatch chunk — measured
at ~50 s/step of ICI time for llama4-maverick prefill (§Perf).  Here the
communication is what expert parallelism actually requires:

  * tokens are flat-sharded over (data x model); each device locally routes
    its n_local tokens into per-expert capacity slots (the one-hot is only
    (n_local, E, cap_local) — VMEM-scale, NO chunk scan needed);
  * one ``all_to_all`` over the "model" axis sends each expert's slots to
    the rank that owns it (bytes moved = tokens·d, the information-theoretic
    floor for EP dispatch);
  * expert FFN runs with FSDP'd weights: gate/up are column-parallel over
    the data axes (local ff shard, zero comms), down is row-parallel (one
    psum over the data axes);
  * the reverse ``all_to_all`` returns expert outputs; the combine is local.

Weight layout contract (enforced by launch/shardings.py when impl="a2a"):
  gate/up: (E@model, d, ff@data)      down: (E@model, ff@data, d)
  router:  replicated.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jax_compat
from repro.models import layers
from repro.models.config import MoEConfig

Array = jax.Array
PyTree = Any


def _local_dispatch(xf, gate_vals, expert_idx, e, cap):
    """One-hot dispatch of LOCAL tokens. xf: (n, d) -> (e, cap, d) + combine."""
    n = xf.shape[0]
    k = expert_idx.shape[-1]
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)        # (n, k, e)
    flat_choice = onehot.reshape(n * k, e)
    pos = jnp.cumsum(flat_choice, axis=0) * flat_choice - 1
    pos = pos.reshape(n, k, e)
    within = (pos < cap) & (pos >= 0)
    slot = jax.nn.one_hot(jnp.where(within, pos, -1), cap, dtype=xf.dtype)
    dispatch = jnp.sum(slot, axis=1)                               # (n, e, cap)
    combine = jnp.sum(slot * gate_vals[..., None, None].astype(xf.dtype),
                      axis=1)                                      # (n, e, cap)
    expert_in = jnp.einsum("nec,nd->ecd", dispatch, xf)
    return expert_in, combine


def moe_forward_a2a(
    params: PyTree,
    x: Array,
    mo: MoEConfig,
    *,
    model_axis: str = "model",
) -> tuple[Array, Array]:
    """x: (B, T, d) -> (out, aux). Must run under ``jax.set_mesh(mesh)``."""
    b, t, d = x.shape
    e, k = mo.num_experts, mo.top_k
    mesh = jax_compat.get_active_mesh()
    if model_axis not in mesh.shape:
        raise RuntimeError(
            "moe impl='a2a' needs the production mesh via jax.set_mesh(...)")
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    m = mesh.shape[model_axis]
    e_local = e // m

    in_specs = (
        P(None, None),                      # router (d, E) replicated
        P(model_axis, None, data_axes),     # gate  (E, d, ff)
        P(model_axis, None, data_axes),     # up    (E, d, ff)
        P(model_axis, data_axes, None),     # down  (E, ff, d)
        P(data_axes, model_axis, None),     # x     (B, T, d)
    )
    out_specs = (P(data_axes, model_axis, None), P())

    @jax_compat.shard_map(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
    def inner(router_w, gate_w, up_w, down_w, xl):
        bl, tl, _ = xl.shape
        n_local = bl * tl
        xf = xl.reshape(n_local, d)

        logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        cap = max(int(n_local * k / e * mo.capacity_factor), k)
        expert_in, combine = _local_dispatch(xf, gate_vals, expert_idx, e, cap)

        # ---- dispatch all-to-all over the model axis --------------------
        # (e, cap, d) -> (m_dest, e_local, cap, d); after the exchange dim 0
        # indexes the SOURCE rank, so transpose it under the expert dim to
        # lay tokens out as (e_local, m*cap, d).
        send = expert_in.reshape(m, e_local, cap, d)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        buf = recv.reshape(m, e_local, cap, d).transpose(1, 0, 2, 3) \
            .reshape(e_local, m * cap, d)                          # (E_l, C, d)

        # ---- expert FFN: EP (model) x TP (data) hybrid --------------------
        # Each data row holds DIFFERENT tokens but only an ff-slice of the
        # expert weights, so: all-gather the token buffers over the data
        # axes (every rank sees every row's tokens), run gate/up/down with
        # the local ff shard, then psum_scatter the down partial sums back —
        # the reduce half combines ff-slices, the scatter half returns each
        # row its own tokens.
        buf_all = buf
        for ax in reversed(data_axes):
            buf_all = jax.lax.all_gather(buf_all, ax, axis=1, tiled=True)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf_all,
                                   gate_w.astype(buf.dtype)))
        u = jnp.einsum("ecd,edf->ecf", buf_all, up_w.astype(buf.dtype))
        out_all = jnp.einsum("ecf,efd->ecd", g * u,
                             down_w.astype(buf.dtype))
        out_buf = out_all
        for ax in data_axes:
            out_buf = jax.lax.psum_scatter(out_buf, ax, scatter_dimension=1,
                                           tiled=True)

        # ---- return all-to-all + local combine ---------------------------
        # (e_local, m*cap, d) -> (m_dest, e_local, cap, d); after the
        # exchange dim 0 = source rank = owner of experts r*e_local+j, which
        # is exactly the original expert-major order.
        back = out_buf.reshape(e_local, m, cap, d).transpose(1, 0, 2, 3)
        ret = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                 concat_axis=0, tiled=True)
        expert_out = ret.reshape(e, cap, d)
        y = jnp.einsum("nec,ecd->nd", combine, expert_out)

        # ---- load-balance aux (global means via psum) ---------------------
        top1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
        f_sum = jnp.sum(top1, axis=0)
        p_sum = jnp.sum(probs, axis=0)
        count = jnp.asarray(n_local, jnp.float32)
        for ax in data_axes + (model_axis,):
            f_sum = jax.lax.psum(f_sum, ax)
            p_sum = jax.lax.psum(p_sum, ax)
            count = jax.lax.psum(count, ax)
        aux = e * jnp.sum((f_sum / count) * (p_sum / count))
        return y.reshape(bl, tl, d), aux

    out, aux = inner(params["router"]["w"], params["gate"], params["up"],
                     params["down"], x)
    if mo.shared_expert:
        out = out + layers.mlp(params["shared"], x)
    return out, aux
