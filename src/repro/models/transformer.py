"""Block composition + scanned layer stacks.

A *block* = sequence mixer (attn / mamba / mLSTM / sLSTM) + optional FFN
(dense SwiGLU or MoE), pre-norm residual.  The stack scans over *groups* —
one repetition of the config's ``layer_pattern`` — keeping the HLO for a
126-layer model the size of one pattern period.

Caches are pytrees aligned with the pattern: ``cache[i]`` is the state for
pattern position i, with every leaf carrying a leading ``n_groups`` axis so
the decode scan can thread it as scan xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import layers, mamba as mamba_mod, moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import KVCache
from repro.models.config import BlockSpec, ModelConfig

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, spec: BlockSpec, dtype=jnp.float32) -> PyTree:
    km, kf = jax.random.split(key)
    params: dict[str, PyTree] = {"mixer_norm": layers.init_rmsnorm(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        params["mixer"] = attn_mod.init_attention(km, cfg, dtype)
    elif spec.mixer == "mamba":
        params["mixer"] = mamba_mod.init_mamba(km, cfg.d_model, cfg.mamba, dtype)
    elif spec.mixer == "mlstm":
        params["mixer"] = xlstm_mod.init_mlstm(km, cfg.d_model, cfg.n_heads,
                                               cfg.xlstm, dtype)
    elif spec.mixer == "slstm":
        params["mixer"] = xlstm_mod.init_slstm(km, cfg.d_model, cfg.xlstm, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "mlp":
        params["ffn_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        params["ffn"] = layers.init_mlp(kf, cfg.d_model, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        params["ffn_norm"] = layers.init_rmsnorm(cfg.d_model, dtype)
        params["ffn"] = moe_mod.init_moe(kf, cfg.d_model, cfg.moe, dtype)
    return params


def init_group(key, cfg: ModelConfig, dtype=jnp.float32) -> list[PyTree]:
    keys = jax.random.split(key, len(cfg.layer_pattern))
    return [init_block(k, cfg, spec, dtype)
            for k, spec in zip(keys, cfg.layer_pattern)]


def init_stack(key, cfg: ModelConfig, dtype=jnp.float32) -> list[PyTree]:
    """Stacked params: each leaf has leading dim n_groups."""
    group_keys = jax.random.split(key, cfg.n_groups)
    groups = [init_group(k, cfg, dtype) for k in group_keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *groups)


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def init_cache(
    cfg: ModelConfig, batch: int, capacity: int, dtype=jnp.bfloat16
) -> list[PyTree]:
    """Fresh decode cache for one group, leaves stacked over n_groups."""
    def one(spec: BlockSpec) -> PyTree:
        if spec.mixer == "attn":
            cap = min(capacity, cfg.sliding_window) if cfg.sliding_window else capacity
            return KVCache(
                k=jnp.zeros((cfg.n_groups, batch, cap, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
                v=jnp.zeros((cfg.n_groups, batch, cap, cfg.n_kv_heads,
                             cfg.head_dim), dtype),
            )
        if spec.mixer == "mamba":
            s = mamba_mod.init_mamba_state(batch, cfg.d_model, cfg.mamba, dtype)
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_groups,) + a.shape, a.dtype), s
            )
        if spec.mixer == "mlstm":
            s = xlstm_mod.init_mlstm_state(batch, cfg.d_model, cfg.n_heads, cfg.xlstm)
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_groups,) + a.shape, a.dtype), s
            )
        if spec.mixer == "slstm":
            s = xlstm_mod.init_slstm_state(batch, cfg.d_model)
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_groups,) + a.shape, a.dtype), s
            )
        raise ValueError(spec.mixer)

    return [one(spec) for spec in cfg.layer_pattern]


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _mixer_forward(
    bparams: PyTree,
    x: Array,
    cfg: ModelConfig,
    spec: BlockSpec,
    *,
    angles: Array | None,
    mode: str,                # "forward" | "prefill" | "decode"
    cache: PyTree | None,
    cache_pos: Array | int,
    attn_impl: str,
) -> tuple[Array, PyTree | None]:
    if spec.mixer == "attn":
        if mode == "decode":
            return attn_mod.attention_forward(
                bparams, x, cfg, angles=angles, cache=cache,
                cache_pos=cache_pos, attn_impl=attn_impl,
            )
        out, _ = attn_mod.attention_forward(
            bparams, x, cfg, angles=angles, cache=None, attn_impl=attn_impl
        )
        new_cache = None
        if mode == "prefill":
            new_cache = attn_mod.prefill_kv(
                bparams, x, cfg, angles=angles,
                capacity=cache.k.shape[1] if cache is not None else x.shape[1],
            )
        return out, new_cache
    if spec.mixer == "mamba":
        if mode == "decode":
            return mamba_mod.mamba_decode_step(bparams, x, cfg.mamba, cache)
        return mamba_mod.mamba_forward(
            bparams, x, cfg.mamba, return_state=(mode == "prefill")
        )
    if spec.mixer == "mlstm":
        if mode == "decode":
            return xlstm_mod.mlstm_decode_step(
                bparams, x, cfg.n_heads, cfg.xlstm, cache
            )
        return xlstm_mod.mlstm_forward(
            bparams, x, cfg.n_heads, cfg.xlstm,
            return_state=(mode == "prefill"),
        )
    if spec.mixer == "slstm":
        if mode == "decode":
            return xlstm_mod.slstm_decode_step(bparams, x, cfg.xlstm, cache)
        return xlstm_mod.slstm_forward(
            bparams, x, cfg.xlstm, return_state=(mode == "prefill")
        )
    raise ValueError(spec.mixer)


def block_forward(
    bparams: PyTree,
    x: Array,
    cfg: ModelConfig,
    spec: BlockSpec,
    *,
    angles: Array | None,
    mode: str,
    cache: PyTree | None,
    cache_pos: Array | int,
    attn_impl: str,
) -> tuple[Array, PyTree | None, Array]:
    """Pre-norm residual block. Returns (x, new_cache, moe_aux)."""
    h = layers.rmsnorm(bparams["mixer_norm"], x, cfg.norm_eps)
    out, new_cache = _mixer_forward(
        bparams["mixer"], h, cfg, spec, angles=angles, mode=mode,
        cache=cache, cache_pos=cache_pos, attn_impl=attn_impl,
    )
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "mlp":
        h2 = layers.rmsnorm(bparams["ffn_norm"], x, cfg.norm_eps)
        x = x + layers.mlp(bparams["ffn"], h2)
    elif spec.ffn == "moe":
        h2 = layers.rmsnorm(bparams["ffn_norm"], x, cfg.norm_eps)
        if cfg.moe.impl == "a2a":
            from repro.models.moe_a2a import moe_forward_a2a
            out2, aux = moe_forward_a2a(bparams["ffn"], h2, cfg.moe)
        else:
            out2, aux = moe_mod.moe_forward(bparams["ffn"], h2, cfg.moe)
        x = x + out2
    return x, new_cache, aux


def stack_forward(
    stack_params: list[PyTree],
    x: Array,
    cfg: ModelConfig,
    *,
    angles: Array | None,
    mode: str = "forward",
    cache: list[PyTree] | None = None,
    cache_pos: Array | int = 0,
    remat: bool = False,
    attn_impl: str = "reference",
    act_pspec=None,
) -> tuple[Array, list[PyTree] | None, Array]:
    """Scan the group body over n_groups repetitions of the pattern.

    ``act_pspec``: optional PartitionSpec constraint re-applied to the
    residual stream after every block — the §Perf lever for
    sequence-parallel (shard T on "model") or weight-stationary decode
    (shard d on "data") layouts.

    Returns (x, new_cache_or_None, total_moe_aux).
    """
    n_pat = len(cfg.layer_pattern)
    has_cache_out = mode in ("prefill", "decode")

    def constrain(xx):
        if act_pspec is not None:
            return jax.lax.with_sharding_constraint(xx, act_pspec)
        return xx

    x = constrain(x)

    def group_body(carry, xs):
        xx, aux_acc = carry
        gparams, gcache = xs
        new_gcache = []
        for i, spec in enumerate(cfg.layer_pattern):
            c_in = gcache[i] if gcache is not None else None
            xx, c_out, aux = block_forward(
                gparams[i], xx, cfg, spec, angles=angles, mode=mode,
                cache=c_in, cache_pos=cache_pos, attn_impl=attn_impl,
            )
            xx = constrain(xx)
            new_gcache.append(c_out)
        ys = new_gcache if has_cache_out else None
        return (xx, aux_acc + aux), ys

    body = jax.checkpoint(group_body) if remat else group_body

    if mode == "decode":
        xs = (stack_params, cache)
    elif mode == "prefill":
        # cache provides capacities; its contents are ignored (rebuilt).
        xs = (stack_params, cache)
    else:
        xs = (stack_params, None)

    (x, aux), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, (ys if has_cache_out else None), aux
