"""xLSTM mixers (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM — chunkwise-parallel form (TPU adaptation, DESIGN.md §2):
  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
  h_t = (C_t q_t) / max(|n_t . q_t|, 1)
Within a chunk the decayed contributions are a causal Q×Q quadratic form with
decay D_ts = exp(gamma_t - gamma_s) (gamma = cumsum log f, computed stably via
log-sigmoid); across chunks the (B, H, d_k, d_v) matrix state is carried by a
sequential scan — the chunked-linear-attention shape that fits TPU MXU tiling.
Gates use sigmoid(i), sigmoid(f) (bounded, no max-stabilizer needed in the
parallel form; the exponential-gating stabilizer of the paper is kept in the
sLSTM cell where it is load-bearing).

sLSTM — sequential scan with the paper's exponential gating + stabilizer:
  m_t = max(f~ + m_{t-1}, i~);  i' = exp(i~ - m_t);  f' = exp(f~ + m_{t-1} - m_t)
  c_t = f' c_{t-1} + i' z_t ;  n_t = f' n_{t-1} + i' ;  h_t = o_t · c_t / n_t
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import XLSTMConfig

Array = jax.Array
PyTree = Any


class MLSTMState(NamedTuple):
    c: Array  # (B, H, d_k, d_v) matrix memory
    n: Array  # (B, H, d_k) normalizer


class SLSTMState(NamedTuple):
    c: Array  # (B, d)
    n: Array  # (B, d)
    h: Array  # (B, d)
    m: Array  # (B, d) stabilizer


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, xc: XLSTMConfig,
               dtype=jnp.float32) -> PyTree:
    """q/k/v are head-wise block-diagonal (the official LinearHeadwiseExpand):
    (H, hd, hd) per projection instead of full d_in x d_in — this is what
    keeps the 48-block xLSTM at the ~1-2B scale its name implies."""
    d_in = int(xc.mlstm_proj_factor * d_model)
    hd = d_in // n_heads
    keys = jax.random.split(key, 7)
    scale = 1.0 / math.sqrt(hd)
    def headwise(k):
        return jax.random.uniform(k, (n_heads, hd, hd), dtype, -scale, scale)
    return {
        "up": layers.init_linear(keys[0], d_model, 2 * d_in, dtype=dtype),
        "wq": headwise(keys[1]),
        "wk": headwise(keys[2]),
        "wv": headwise(keys[3]),
        "w_if": layers.init_linear(keys[4], d_in, 2 * n_heads, bias=True,
                                   dtype=dtype),
        "down": layers.init_linear(keys[6], d_in, d_model, dtype=dtype),
    }


def _mlstm_chunk(q, k, v, log_f, log_i, state: MLSTMState):
    """One chunk of the chunkwise-parallel mLSTM.

    q,k,v: (B, H, Q, d);  log_f/log_i: (B, H, Q);  state: C (B,H,d,d), n (B,H,d).
    Returns (h (B,H,Q,d), new_state).
    """
    bq = q.shape[2]
    gamma = jnp.cumsum(log_f, axis=-1)                     # (B,H,Q)
    # inter-chunk: state contribution decayed by gamma_t
    decay_t = jnp.exp(gamma)                               # (B,H,Q)
    h_inter = jnp.einsum("bhqk,bhkv->bhqv", q, state.c) * decay_t[..., None]
    n_inter = jnp.einsum("bhqk,bhk->bhq", q, state.n) * decay_t

    # intra-chunk: D_ts = exp(gamma_t - gamma_s + log_i_s), causal
    d_mat = gamma[..., :, None] - gamma[..., None, :] + log_i[..., None, :]
    mask = jnp.tril(jnp.ones((bq, bq), bool))
    d_mat = jnp.where(mask, d_mat, -jnp.inf)
    d_exp = jnp.exp(d_mat)                                 # (B,H,Q,Q)
    scores = jnp.einsum("bhqk,bhsk->bhqs", q, k) * d_exp
    h_intra = jnp.einsum("bhqs,bhsv->bhqv", scores, v)
    n_intra = jnp.sum(scores, axis=-1)

    num = h_inter + h_intra
    den = n_inter + n_intra
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

    # state update to end of chunk
    total_decay = jnp.exp(gamma[..., -1])                  # (B,H)
    per_s = jnp.exp(gamma[..., -1:] - gamma + log_i)       # (B,H,Q)
    c_new = state.c * total_decay[..., None, None] + jnp.einsum(
        "bhsk,bhsv->bhkv", k * per_s[..., None], v
    )
    n_new = state.n * total_decay[..., None] + jnp.einsum(
        "bhsk,bhs->bhk", k, per_s
    )
    return h, MLSTMState(c=c_new, n=n_new)


def mlstm_forward(
    params: PyTree,
    x: Array,
    n_heads: int,
    xc: XLSTMConfig,
    *,
    initial_state: MLSTMState | None = None,
    return_state: bool = False,
) -> tuple[Array, MLSTMState | None]:
    b, t, d_model = x.shape
    d_in = int(xc.mlstm_proj_factor * d_model)
    hd = d_in // n_heads
    scale = 1.0 / math.sqrt(hd)

    xm, z = jnp.split(layers.linear(params["up"], x), 2, axis=-1)
    xh = xm.reshape(b, t, n_heads, hd)
    def heads(w):
        return jnp.einsum(
            "bthd,hde->bhte", xh, params[w].astype(xh.dtype)
        ).astype(jnp.float32)
    q, k, v = heads("wq") * scale, heads("wk"), heads("wv")

    gates = layers.linear(params["w_if"], xm).astype(jnp.float32)  # (B,T,2H)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    log_i = jax.nn.log_sigmoid(i_raw).transpose(0, 2, 1)   # (B,H,T)
    log_f = jax.nn.log_sigmoid(f_raw).transpose(0, 2, 1)

    qc = min(xc.chunk_size, t)
    n_chunks = -(-t // qc)
    pad = n_chunks * qc - t
    def padt(a, axis):
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)
    if pad:
        q, k, v = (padt(a, 2) for a in (q, k, v))
        # pad forget gates with log(1)=0? safer: pad with very negative i
        log_i = padt(log_i, 2) + jnp.pad(
            jnp.zeros((b, n_heads, t)), ((0, 0), (0, 0), (0, pad)),
            constant_values=-1e9,
        )
        log_f = padt(log_f, 2)

    def split_chunks(a):  # (B,H,T,..) -> (n_chunks, B,H,Q,..)
        shp = a.shape
        return jnp.moveaxis(
            a.reshape(shp[0], shp[1], n_chunks, qc, *shp[3:]), 2, 0
        )

    state0 = initial_state or MLSTMState(
        c=jnp.zeros((b, n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((b, n_heads, hd), jnp.float32),
    )

    def step(state, inp):
        qi, ki, vi, lfi, lii = inp
        h, new_state = _mlstm_chunk(qi, ki, vi, lfi, lii, state)
        return new_state, h

    final_state, hs = jax.lax.scan(
        step, state0,
        (split_chunks(q), split_chunks(k), split_chunks(v),
         split_chunks(log_f), split_chunks(log_i)),
    )
    h = jnp.moveaxis(hs, 0, 2).reshape(b, n_heads, n_chunks * qc, hd)[:, :, :t]
    h = h.transpose(0, 2, 1, 3).reshape(b, t, d_in).astype(x.dtype)
    out = layers.linear(params["down"], h * jax.nn.silu(z))
    return out, (final_state if return_state else None)


def mlstm_decode_step(
    params: PyTree, x: Array, n_heads: int, xc: XLSTMConfig, state: MLSTMState
) -> tuple[Array, MLSTMState]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    b, _, d_model = x.shape
    d_in = int(xc.mlstm_proj_factor * d_model)
    hd = d_in // n_heads
    scale = 1.0 / math.sqrt(hd)

    xm, z = jnp.split(layers.linear(params["up"], x), 2, axis=-1)
    xh = xm.reshape(b, n_heads, hd)
    def heads(w):
        return jnp.einsum(
            "bhd,hde->bhe", xh, params[w].astype(xh.dtype)
        ).astype(jnp.float32)
    q, k, v = heads("wq") * scale, heads("wk"), heads("wv")
    gates = layers.linear(params["w_if"], xm).astype(jnp.float32).reshape(b, 2 * n_heads)
    i_g = jax.nn.sigmoid(gates[:, :n_heads])
    f_g = jax.nn.sigmoid(gates[:, n_heads:])

    c = state.c * f_g[..., None, None] + i_g[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k, v
    )
    n = state.n * f_g[..., None] + i_g[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, c)
    den = jnp.einsum("bhk,bhk->bh", q, n)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    h = h.reshape(b, 1, d_in).astype(x.dtype)
    out = layers.linear(params["down"], h * jax.nn.silu(z))
    return out, MLSTMState(c=c, n=n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d_model: int, xc: XLSTMConfig, dtype=jnp.float32) -> PyTree:
    keys = jax.random.split(key, 4)
    d_up = int(xc.slstm_proj_factor * d_model)
    return {
        "w_in": layers.init_linear(keys[0], d_model, 4 * d_model, bias=True,
                                   dtype=dtype),
        "w_rec": layers.init_linear(keys[1], d_model, 4 * d_model, dtype=dtype),
        "up": layers.init_linear(keys[2], d_model, d_up, dtype=dtype),
        "down": layers.init_linear(keys[3], d_up, d_model, dtype=dtype),
    }


def _slstm_cell(params: PyTree, x_t: Array, state: SLSTMState) -> tuple[Array, SLSTMState]:
    """One step of the exponential-gated sLSTM with stabilizer state m."""
    pre = layers.linear(params["w_in"], x_t).astype(jnp.float32) + layers.linear(
        params["w_rec"], state.h.astype(x_t.dtype)
    ).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_raw + state.m, i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(f_raw + state.m - m_new)
    c = f_g * state.c + i_g * jnp.tanh(z_raw)
    n = f_g * state.n + i_g
    h = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1.0)
    return h, SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_forward(
    params: PyTree,
    x: Array,
    xc: XLSTMConfig,
    *,
    initial_state: SLSTMState | None = None,
    return_state: bool = False,
) -> tuple[Array, SLSTMState | None]:
    b, t, d = x.shape
    state0 = initial_state or init_slstm_state(b, d)

    def step(state, x_t):
        h, new_state = _slstm_cell(params, x_t, state)
        return new_state, h

    final, hs = jax.lax.scan(step, state0, jnp.moveaxis(x, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)      # (B, T, d)
    up = jax.nn.gelu(layers.linear(params["up"], h))
    out = layers.linear(params["down"], up)
    return out, (final if return_state else None)


def slstm_decode_step(
    params: PyTree, x: Array, xc: XLSTMConfig, state: SLSTMState
) -> tuple[Array, SLSTMState]:
    h, new_state = _slstm_cell(params, x[:, 0], state)
    h = h[:, None].astype(x.dtype)
    out = layers.linear(params["down"], jax.nn.gelu(layers.linear(params["up"], h)))
    return out, new_state


def init_mlstm_state(batch: int, d_model: int, n_heads: int,
                     xc: XLSTMConfig) -> MLSTMState:
    d_in = int(xc.mlstm_proj_factor * d_model)
    hd = d_in // n_heads
    return MLSTMState(
        c=jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
        n=jnp.zeros((batch, n_heads, hd), jnp.float32),
    )


def init_slstm_state(batch: int, d_model: int) -> SLSTMState:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=z)
