"""Serving runtime: the MUSE data plane + rollout/calibration control plane."""
from repro.serving.batching import MicroBatcher, ServerBatcher
from repro.serving.calibration import (
    CalibrationController,
    CandidateReport,
    RefreshPolicy,
    RefreshResult,
)
from repro.serving.rollout import Replica, ReplicaSet, RollingUpdate
from repro.serving.server import FeatureStore, MuseServer, ServerConfig
from repro.serving.shadow import ShadowSink
from repro.serving.types import ScoringRequest, ScoringResponse, ShadowRecord

__all__ = [
    "MicroBatcher", "ServerBatcher", "Replica", "ReplicaSet", "RollingUpdate",
    "CalibrationController", "CandidateReport", "RefreshPolicy",
    "RefreshResult", "FeatureStore", "MuseServer", "ServerConfig",
    "ShadowSink", "ScoringRequest", "ScoringResponse", "ShadowRecord",
]
