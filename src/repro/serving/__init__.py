"""Serving runtime: the MUSE data plane + rollout/calibration control plane.

Stage/epoch model of the banked dispatch
----------------------------------------

A mixed-tenant window flows through three independently schedulable stages
(``MuseServer.run_models`` -> ``MuseServer.apply_transforms`` ->
``MuseServer.track``).  ``ServerBatcher`` runs them back-to-back on the
caller's thread (synchronous baseline); ``AsyncDispatchEngine`` pipelines
them on three single-worker stage executors, so window *N*'s expert models
execute while window *N−1* runs the banked transform kernel and window
*N−2*'s quantile-estimator updates land.

Consistency comes from two counters:

* **generation** — bumped by every atomic control-plane publish
  (``MuseServer.publish_quantile_maps``).  All served state lives in one
  immutable ``_ControlPlane`` (predictors + transform banks + generation)
  swapped in a single reference assignment; each stage snapshots the plane
  ONCE, so every response is internally consistent with exactly one bank
  generation (stamped as ``ScoringResponse.bank_generation``) and the
  generations observed by any one stream are monotone.
* **epoch** — bumped by the engine each time a control operation (e.g. a
  ``CalibrationController.refresh_fleet`` pass via
  ``AsyncDispatchEngine.schedule_refresh``) runs at a stage boundary on the
  track executor — serialized with the estimator reservoirs it reads while
  model/transform stages keep streaming.  In-flight windows finish on their
  snapshotted generation; the next stage picks up the published one.

Fleet calibration plane
-----------------------

A fleet of replicas behind a ``ReplicaSet`` is calibrated by ONE
:class:`~repro.serving.calibration.FleetCalibrationController`: it pulls
exact estimator checkpoints from every replica
(``MuseServer.snapshot_estimator_checkpoints``), merges them per (tenant,
predictor) with the mergeable-sketch reduction
(``StreamingQuantileEstimator.merge_checkpoints``, rank-error bound in
``core/quantiles.py``), runs gate/refit/validate once on the merged view,
and broadcasts the validated maps under a single FENCED fleet generation —
``publish_quantile_maps(..., generation=...)`` rejects anything not
strictly newer (``StaleGenerationError``), so stragglers keep serving
their complete old plane and late acks can never roll a replica back.
``ReplicaSet.dispatch(stream=...)`` adds generation-fenced session
routing on top, making ``bank_generation`` monotone per client stream
across the whole fleet; ``ReplicaSet.fleet_generation()`` audits
divergence.

Sharded serving topology
------------------------

``ServerConfig(tenant_shards=S)`` row-partitions every model-group
``TransformBank`` over an S-way "tenants" mesh axis
(:class:`~repro.core.transforms.ShardedTransformBank`): a replica shard
holds only its tenant rows (~1/S of the dense bank), the scaling move past
~10k tenants.  ``apply_transforms`` buckets each window's rows by owning
shard and launches the banked kernel per shard in ONE ``shard_map`` call
(:class:`~repro.serving.server.ShardedBankDispatcher`), gathering results
back in request order — scores match the dense path bitwise on f32, and
the same path rides under the async engine's stage pipeline untouched.

The calibration publish protocol is shard-oblivious by construction: the
fleet refresh fits candidates globally (pooled streams), and
``MuseServer.publish_quantile_maps`` rebuilds the dense bank AND its
per-shard sub-banks (scattering refreshed rows only into their owning
shard) inside the SAME single control-plane swap.  Generations therefore
stay fleet-monotone across shards — a window can never observe shard A at
generation g and shard B at g+1.

Tiered serving topology
-----------------------

``ServerConfig(tiering=TieringConfig(...))`` bounds DEVICE residency by
configuration instead of tenant count (the move past ~10^5 tenants on one
replica): the hottest tenants' bank rows live in a device bank, everything
else pages on demand from a host-memory :class:`HostBankStore` through a
bounded victim cache, and tenants that have not yet passed the Eq.-5
sample-size gate score through ONE shared Beta-mixture cold-start prior
row (``core/coldstart.py``).  The async engine prefetches pending windows'
cold rows before their transform stage dispatches
(``MuseServer.prefetch_transforms``), promotion/demotion is an explicit
generation-fenced control op (``TieredBankStore.rebalance``, driven by the
calibration controllers after each publish), and
``publish_quantile_maps`` lands refreshed maps in host rows AND every
device-resident copy atomically under one generation — hot, cold, and
freshly promoted tenants all serve the new parameters after the publish
returns.  Scores match a dense bank bitwise on f32 (same banked kernel,
slot-remapped rows).  See ``serving/tiering.py``.

Tiering COMPOSES with sharding: ``ServerConfig(tenant_shards=S,
tiering=...)`` gives every shard of the tenant mesh its own bounded hot
tier + victim cache over a per-shard slice of the host store
(:class:`~repro.serving.tiering.ShardedTieredBankStore`), scored in one
``shard_map`` launch per pass through the same dispatcher — device
residency is ``(hot+victims+1)·(2K+2N)·4`` bytes PER SHARD regardless of
tenant count, publishes land on every shard under ONE generation, and
scores still match the dense bank bitwise on f32.

Client decision loop + audit trail
----------------------------------

On top of the served scores sits the CLIENT side of the paper's contract:
:class:`~repro.serving.decision_loop.DecisionLoop` holds fixed per-tenant
thresholds over the *transformed* scores (grace / cooldown / instant-block
semantics) and emits a per-event :class:`~repro.serving.decision_loop.Decision`
keyed by request id; :class:`~repro.serving.audit.AuditLog` chains every
decision into a hash-chained, ``bank_generation``-stamped trail whose
``verify`` replays each entry — score bit-for-bit through the exact
generation's archived transform parameters
(:class:`~repro.serving.audit.GenerationLedger`), action through the pure
``decide`` function — and detects any tamper, splice, or truncation.
"""
from repro.serving.audit import (
    AuditEntry,
    AuditFailure,
    AuditLog,
    AuditVerification,
    GenerationLedger,
)
from repro.serving.batching import MicroBatcher, ServerBatcher
from repro.serving.calibration import (
    CalibrationController,
    CandidateReport,
    FleetCalibrationController,
    FleetRefreshResult,
    RefreshPolicy,
    RefreshResult,
    ReplicaPullFailure,
)
from repro.serving.decision_loop import (
    Decision,
    DecisionLoop,
    DecisionPolicy,
    decide,
)
from repro.serving.engine import AsyncDispatchEngine
from repro.serving.rollout import (
    FleetGenerationAudit,
    Replica,
    ReplicaSet,
    RollingUpdate,
)
from repro.serving.server import (
    FeatureStore,
    MuseServer,
    ServerConfig,
    ShardedBankDispatcher,
    StaleGenerationError,
)
from repro.serving.shadow import ShadowSink
from repro.serving.tiering import (
    HostBankStore,
    ShardedTieredBankStore,
    TieredBankStore,
    TieringConfig,
    prior_bank_row,
)
from repro.serving.types import ScoringRequest, ScoringResponse, ShadowRecord

__all__ = [
    "AsyncDispatchEngine", "AuditEntry", "AuditFailure", "AuditLog",
    "AuditVerification", "MicroBatcher", "ServerBatcher", "Replica",
    "ReplicaSet", "RollingUpdate", "CalibrationController", "CandidateReport",
    "Decision", "DecisionLoop", "DecisionPolicy", "decide",
    "FleetCalibrationController", "FleetGenerationAudit", "FleetRefreshResult",
    "GenerationLedger", "RefreshPolicy", "RefreshResult", "ReplicaPullFailure",
    "FeatureStore", "HostBankStore", "MuseServer", "ServerConfig",
    "ShardedBankDispatcher", "ShardedTieredBankStore",
    "StaleGenerationError", "ShadowSink",
    "ScoringRequest", "ScoringResponse", "ShadowRecord", "TieredBankStore",
    "TieringConfig", "prior_bank_row",
]
