"""Hash-chained, generation-stamped decision audit log.

Every client :class:`~repro.serving.decision_loop.Decision` is appended to
a tamper-evident chain, and every entry is *replayable*: given the exact
``bank_generation`` the decision was served under, ``verify`` reproduces
both the transformed score and the client action bit-for-bit.  This is the
OversightLogging contract (cf. the thesis repo's ``verify_audit.py``): an
alert raised months ago can be proven to have followed from exactly the
parameters served at that moment — or shown to have been tampered with.

Chain format
------------

Entry ``i`` is a pair ``(payload_i, digest_i)``:

  * ``payload_i`` — the decision record as CANONICAL JSON: all fields of
    ``Decision`` (``dataclasses.asdict``), serialized with sorted keys and
    compact separators.  Canonicalization makes the digest independent of
    field/insertion order — two logs of the same decisions chain
    identically regardless of how the records were assembled.
  * ``digest_i = sha256(digest_{i-1} || "\\n" || index_i || "\\n" ||
    payload_i)`` in hex, with ``digest_{-1} = sha256("muse-audit-v1")``
    (the genesis digest).  Binding the entry INDEX into the hash means a
    reordered or spliced log breaks the chain even if payload bytes are
    individually intact.

``head()`` is the latest digest.  Clients persist ``(head, length)``
out-of-band after each append batch; ``verify(expected_head=...,
expected_length=...)`` then also detects whole-tail truncation, which a
self-contained chain cannot (a truncated chain is internally consistent).

Replay contract
---------------

``verify(ledger=...)`` replays every entry against a
:class:`GenerationLedger` — an archive of the exact transform parameters
``(betas, weights, src_quantiles, ref_quantiles)`` each predictor served
under each ``bank_generation`` (recorded via ``record_server`` /
``record_replicas`` whenever a generation is first observed).  For each
entry it recomputes:

  1. **the score** — the recorded ``raw_scores`` row is pushed through the
     SAME banked kernel the data plane ran
     (:func:`repro.kernels.ops.score_pipeline_banked`, single-row bank) for
     the entry's generation; the result must equal the recorded ``score``
     EXACTLY (f32 bit-for-bit — per-row compute is batch-independent, the
     PR-5 kernel invariant);
  2. **the action** — :func:`repro.serving.decision_loop.decide` applied to
     the recorded (score, thresholds, grace, cooldown) state inputs must
     reproduce the recorded ``action``.

A generation missing from the ledger, or a ledger re-record that disagrees
with what was already archived for a (generation, predictor), is a
structured failure — never a silent skip.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, Mapping

import numpy as np

from repro.serving.decision_loop import Decision, decide

GENESIS = hashlib.sha256(b"muse-audit-v1").hexdigest()


def canonical_payload(record: Mapping | Decision) -> str:
    """Canonical JSON for one decision record (sorted keys, compact).

    The digest of an entry depends only on the record's VALUES — any
    field/insertion order produces the same bytes.
    """
    if isinstance(record, Decision):
        record = dataclasses.asdict(record)
    record = dict(record)
    if isinstance(record.get("raw_scores"), tuple):
        record["raw_scores"] = list(record["raw_scores"])
    return json.dumps(record, sort_keys=True, separators=(",", ":"))


def chain_digest(prev: str, index: int, payload: str) -> str:
    h = hashlib.sha256()
    h.update(prev.encode())
    h.update(b"\n")
    h.update(str(index).encode())
    h.update(b"\n")
    h.update(payload.encode())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class AuditEntry:
    index: int
    payload: str                      # canonical JSON decision record
    digest: str                       # chain digest AFTER this entry


@dataclasses.dataclass(frozen=True)
class AuditFailure:
    index: int                        # -1 for whole-log failures
    kind: str                         # chain|index|json|score_mismatch|...
    detail: str


@dataclasses.dataclass(frozen=True)
class AuditVerification:
    ok: bool
    entries: int
    head: str
    replayed: int                     # entries score-replayed via the ledger
    failures: tuple[AuditFailure, ...]


class GenerationLedger:
    """Archive of the exact per-generation transform parameters served.

    Keyed by ``(bank_generation, predictor)``; each value is the
    ``(betas, weights, src_quantiles, ref_quantiles)`` float32 tuple a
    single-row bank is rebuilt from at replay time.  ``record`` REFUSES a
    conflicting re-record: two replicas claiming different parameters for
    the same generation is exactly the provenance violation the fleet's
    fenced publish protocol exists to prevent, and the audit layer must
    surface it, not paper over it.
    """

    def __init__(self) -> None:
        self._rows: dict[tuple[int, str],
                         tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]] = {}

    def __len__(self) -> int:
        return len(self._rows)

    def generations(self) -> set[int]:
        return {g for g, _ in self._rows}

    def record(self, generation: int, predictor: str, betas, weights,
               src_quantiles, ref_quantiles) -> None:
        row = tuple(np.asarray(a, np.float32).reshape(-1)
                    for a in (betas, weights, src_quantiles, ref_quantiles))
        key = (generation, predictor)
        have = self._rows.get(key)
        if have is not None:
            if not all(np.array_equal(a, b) for a, b in zip(have, row)):
                raise ValueError(
                    f"ledger conflict: generation {generation} predictor "
                    f"{predictor!r} re-recorded with different parameters")
            return
        self._rows[key] = row

    def record_server(self, server: "object") -> int:
        """Archive every live predictor's pipeline under the server's
        CURRENT bank generation; returns that generation."""
        gen = server.bank_generation
        for name, pred in server.predictors.items():
            p = pred.pipeline
            self.record(gen, name, p.betas, p.weights, p.src_quantiles,
                        p.ref_quantiles)
        return gen

    def record_replicas(self, replica_set: "object") -> set[int]:
        """Archive every ready replica's served parameters; returns the set
        of generations recorded (divergent fleets record several)."""
        reps = getattr(replica_set, "ready_replicas", None)
        if reps is None:
            reps = list(getattr(replica_set, "replicas", replica_set))
        return {self.record_server(r.server) for r in reps}

    def params(self, generation: int, predictor: str):
        return self._rows.get((generation, predictor))

    def replay_score(self, entry_fields: Mapping, *, fused: bool = True
                     ) -> float:
        """Recompute the transformed score for one decoded entry.

        Rebuilds a single-row bank from the archived generation parameters
        and pushes the recorded raw scores through the same banked pipeline
        the data plane ran.  Raises ``KeyError`` if the generation was
        never archived.
        """
        key = (int(entry_fields["bank_generation"]),
               str(entry_fields["predictor"]))
        row = self._rows.get(key)
        if row is None:
            raise KeyError(f"generation {key[0]} predictor {key[1]!r} "
                           f"not in ledger")
        import jax.numpy as jnp

        from repro.core.transforms import banked_score_pipeline
        from repro.kernels import ops

        betas, weights, src, ref = row
        raws = np.asarray(entry_fields["raw_scores"], np.float32)[None]
        impl = ops.score_pipeline_banked if fused else banked_score_pipeline
        out = impl(jnp.asarray(raws), jnp.zeros((1,), jnp.int32),
                   jnp.asarray(betas[None]), jnp.asarray(weights[None]),
                   jnp.asarray(src[None]), jnp.asarray(ref[None]))
        return float(np.asarray(out)[0])


class AuditLog:
    """Append-only hash chain of client decisions (format above)."""

    def __init__(self) -> None:
        self.entries: list[AuditEntry] = []
        self._head = GENESIS

    def __len__(self) -> int:
        return len(self.entries)

    def head(self) -> str:
        return self._head

    def append(self, decision: Decision | Mapping) -> AuditEntry:
        payload = canonical_payload(decision)
        index = len(self.entries)
        digest = chain_digest(self._head, index, payload)
        entry = AuditEntry(index=index, payload=payload, digest=digest)
        self.entries.append(entry)
        self._head = digest
        return entry

    # ------------------------------------------------------------------ verify
    def verify(self, ledger: GenerationLedger | None = None, *,
               expected_head: str | None = None,
               expected_length: int | None = None,
               fused: bool = True) -> AuditVerification:
        """Walk the chain; optionally replay every entry against ``ledger``.

        Chain pass: recompute every digest from the payload bytes — a
        single flipped byte anywhere (payload or stored digest) fails the
        entry where the chain diverges.  ``expected_head`` /
        ``expected_length`` (persisted out-of-band by the client) addition-
        ally detect truncation.  Replay pass (when a ledger is given):
        score and action must reproduce exactly per the module contract.
        """
        failures: list[AuditFailure] = []
        prev = GENESIS
        replayed = 0
        for i, entry in enumerate(self.entries):
            if entry.index != i:
                failures.append(AuditFailure(i, "index",
                                             f"stored index {entry.index}"))
            digest = chain_digest(prev, i, entry.payload)
            if digest != entry.digest:
                failures.append(AuditFailure(
                    i, "chain", "recomputed digest differs from stored"))
                prev = entry.digest    # resync to localize later tampering
                continue
            prev = digest
            try:
                fields = json.loads(entry.payload)
            except ValueError as e:
                failures.append(AuditFailure(i, "json", str(e)))
                continue
            try:
                action = decide(float(fields["score"]),
                                float(fields["threshold"]),
                                float(fields["block_threshold"]),
                                bool(fields["grace"]),
                                int(fields["cooldown"]))
                if action != fields["action"]:
                    failures.append(AuditFailure(
                        i, "action_mismatch",
                        f"recorded {fields['action']!r}, replayed "
                        f"{action!r}"))
            except (KeyError, TypeError, ValueError) as e:
                failures.append(AuditFailure(i, "json",
                                             f"malformed record: {e}"))
                continue
            if ledger is not None:
                try:
                    score = ledger.replay_score(fields, fused=fused)
                except KeyError as e:
                    failures.append(AuditFailure(i, "unknown_generation",
                                                 str(e)))
                    continue
                replayed += 1
                if score != float(fields["score"]):
                    failures.append(AuditFailure(
                        i, "score_mismatch",
                        f"recorded {fields['score']!r}, replayed {score!r} "
                        f"under generation {fields['bank_generation']}"))
        if expected_length is not None and len(self.entries) != expected_length:
            failures.append(AuditFailure(
                -1, "truncated",
                f"{len(self.entries)} entries, expected {expected_length}"))
        if expected_head is not None and prev != expected_head:
            failures.append(AuditFailure(
                -1, "head_mismatch",
                f"head {prev[:16]}..., expected {expected_head[:16]}..."))
        return AuditVerification(
            ok=not failures, entries=len(self.entries), head=prev,
            replayed=replayed, failures=tuple(failures))
