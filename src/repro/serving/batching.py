"""Micro-batching: group in-flight requests per resolved predictor.

The serving layer is stateless (paper design principle #1); the batcher is a
per-replica, in-memory accumulation window — requests are grouped by their
resolved live predictor so one jitted executable call serves many tenants
(multi-tenancy & reuse, principle #2).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

from repro.serving.types import ScoringRequest


@dataclasses.dataclass
class MicroBatcher:
    """Accumulates requests; flushes per-key when size or age limits hit.

    ``clock`` is injectable for deterministic tests.
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    clock: Callable[[], float] = time.perf_counter

    def __post_init__(self) -> None:
        self._pending: dict[str, list[ScoringRequest]] = collections.defaultdict(list)
        self._oldest: dict[str, float] = {}

    def add(self, key: str, request: ScoringRequest) -> list[ScoringRequest] | None:
        """Returns a full batch to execute, or None if still accumulating."""
        pending = self._pending[key]
        if not pending:
            self._oldest[key] = self.clock()
        pending.append(request)
        if len(pending) >= self.max_batch:
            return self._take(key)
        return None

    def expired(self) -> list[tuple[str, list[ScoringRequest]]]:
        """All (key, batch) pairs whose window has aged out."""
        now = self.clock()
        out = []
        for key, t0 in list(self._oldest.items()):
            if (now - t0) * 1000.0 >= self.max_wait_ms and self._pending[key]:
                out.append((key, self._take(key)))
        return out

    def flush_all(self) -> list[tuple[str, list[ScoringRequest]]]:
        return [(k, self._take(k)) for k in list(self._pending) if self._pending[k]]

    def _take(self, key: str) -> list[ScoringRequest]:
        batch = self._pending[key]
        self._pending[key] = []
        self._oldest.pop(key, None)
        return batch

    @property
    def pending_count(self) -> int:
        return sum(len(v) for v in self._pending.values())
