"""Micro-batching: group in-flight requests per model group.

The serving layer is stateless (paper design principle #1); the batcher is a
per-replica, in-memory accumulation window.  Requests are grouped by the
*model group* of their resolved live predictor (``MuseServer.batch_key``) —
NOT per predictor — so one accumulated window spans every tenant/predictor
that shares an expert-model set, and its flush lands in
``MuseServer.score_batch``'s banked path as a single model executable call
plus a single tenant-indexed kernel dispatch (multi-tenancy & reuse,
principle #2).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

from repro.serving.types import ScoringRequest, ScoringResponse


@dataclasses.dataclass
class MicroBatcher:
    """Accumulates requests; flushes per-key when size or age limits hit.

    ``clock`` is injectable so ``expired()``-based flushes are testable
    without sleeps; the default is ``time.monotonic`` — wall-clock
    adjustments must never age (or un-age) a window.
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self) -> None:
        self._pending: dict[str, list[ScoringRequest]] = collections.defaultdict(list)
        self._oldest: dict[str, float] = {}

    def add(self, key: str, request: ScoringRequest) -> list[ScoringRequest] | None:
        """Returns a full batch to execute, or None if still accumulating."""
        pending = self._pending[key]
        if not pending:
            self._oldest[key] = self.clock()
        pending.append(request)
        if len(pending) >= self.max_batch:
            return self._take(key)
        return None

    def expired(self) -> list[tuple[str, list[ScoringRequest]]]:
        """All (key, batch) pairs whose window has aged out."""
        now = self.clock()
        out = []
        for key, t0 in list(self._oldest.items()):
            if (now - t0) * 1000.0 >= self.max_wait_ms and self._pending[key]:
                out.append((key, self._take(key)))
        return out

    def flush_all(self) -> list[tuple[str, list[ScoringRequest]]]:
        return [(k, self._take(k)) for k in list(self._pending) if self._pending[k]]

    def pending_for(self, key: str) -> int:
        return len(self._pending.get(key, ()))

    def pending_keys(self) -> list[str]:
        """Keys with a non-empty accumulating window (snapshot)."""
        return [k for k, v in self._pending.items() if v]

    def peek(self, key: str) -> list[ScoringRequest]:
        """Copy of one key's accumulating window WITHOUT flushing it.

        The async engine's prefetch pass reads pending window contents to
        stage cold tenant-bank rows before the window dispatches; peeking
        must not consume the window or touch its age clock."""
        return list(self._pending.get(key, ()))

    def take(self, key: str, n: int | None = None) -> list[ScoringRequest]:
        """Flush one key's pending window, or its first ``n`` requests.

        Used by the async engine's adaptive batching: when the model stage
        is backlogged the engine defers the flush and later takes the
        accumulated backlog in one (size-quantized) window.  A partial take
        keeps the key's age clock unchanged — the remainder is OLDER than a
        fresh window, so it must not be rejuvenated."""
        pending = self._pending.get(key)
        if not pending:
            return []
        if n is None or n >= len(pending):
            return self._take(key)
        batch, self._pending[key] = pending[:n], pending[n:]
        return batch

    def _take(self, key: str) -> list[ScoringRequest]:
        batch = self._pending[key]
        self._pending[key] = []
        self._oldest.pop(key, None)
        return batch

    @property
    def pending_count(self) -> int:
        return sum(len(v) for v in self._pending.values())


@dataclasses.dataclass
class ServerBatcher:
    """Glue between :class:`MicroBatcher` and the server's banked data path.

    Keys every request by ``server.batch_key`` (the resolved predictor's
    model group) and flushes full or aged-out windows straight into
    ``server.score_batch`` — which scores each window with one banked kernel
    dispatch regardless of how many tenants it mixes.

    This is the SYNCHRONOUS driver: a flush runs the whole dispatch (models,
    transform kernel, tracking) on the caller's thread before returning.
    ``serving/engine.py::AsyncDispatchEngine`` pipelines the same stages
    across windows instead — use it when throughput matters.

    ``server`` is any object with ``batch_key(intent)`` and
    ``score_batch(requests)`` (duck-typed to avoid a serving<->server import
    cycle).
    """

    server: Any
    batcher: MicroBatcher = dataclasses.field(default_factory=MicroBatcher)

    def submit(self, request: ScoringRequest) -> list[ScoringResponse] | None:
        """Enqueue; returns responses if this request filled its window."""
        key = self.server.batch_key(request.intent)
        batch = self.batcher.add(key, request)
        if batch is not None:
            return self.server.score_batch(batch)
        return None

    def poll(self) -> list[ScoringResponse]:
        """Flush aged-out windows (call from the serving loop's timer)."""
        out: list[ScoringResponse] = []
        for _, batch in self.batcher.expired():
            out.extend(self.server.score_batch(batch))
        return out

    def drain(self) -> list[ScoringResponse]:
        """Flush everything pending (shutdown / test epilogue)."""
        out: list[ScoringResponse] = []
        for _, batch in self.batcher.flush_all():
            out.extend(self.server.score_batch(batch))
        return out

    @property
    def pending_count(self) -> int:
        return self.batcher.pending_count
