"""Fleet-wide atomic calibration refresh — the T^Q control plane.

The paper's core promise (Sec. 3.1) is that retraining-induced score-
distribution shift never invalidates client thresholds: the Quantile Mapping
T^Q is refit from the live stream and swapped in minutes, fleet-wide, so a
model update is invisible to every tenant's alerting rules.  This module is
that control plane.  :class:`CalibrationController.refresh_fleet` runs one
pass of the update lifecycle; each step maps onto the paper:

  1. **Scan** — enumerate every live (tenant, predictor) score stream the
     server has accumulated (the unlabeled post-aggregation T^Q *input*
     distribution, Sec. 2.3.3 — fitting needs no labels).
  2. **Gate (Eq. 5)** — a stream is refit only once it holds at least
     ``n = z^2 (1-a) / (delta^2 a)`` samples, the Appendix-A bound ensuring
     the realized alert rate at the fitted threshold deviates from the
     target ``a`` by at most ``delta`` (relative) with confidence ``z``.
  3. **Refit** — ALL ready streams are refit in ONE vectorized pass
     (:func:`repro.core.quantiles.batch_sample_quantiles`): reservoirs are
     padded into a single matrix and every tenant's source quantile table
     comes out of one ``np.nanquantile`` call (Eq. 4's q^S_i, fleet-wide).
  4. **Validate** — each candidate T^Q is checked against the live stream
     before it may ship: monotone non-decreasing knots (rank preservation,
     the paper's ROC invariant), non-degenerate support coverage, and a
     drift bound — PSI of the candidate-mapped stream against the reference
     R plus a realized-alert-rate band (``serving/drift.py``).  A failed
     candidate is withheld; the old map keeps serving.
  5. **Publish (atomic)** — every validated map lands in ONE
     ``MuseServer.publish_quantile_maps`` call: all affected model-group
     ``TransformBank``s are rebuilt as new immutable objects stamped with a
     bumped generation, then the server's references are swapped wholesale.
     In-flight dispatches finish on the old bank; the next window sees the
     new one — no torn reads, no partially-refreshed fleet.

Wired into ``serving/rollout.py``, a model promotion triggers the refresh
automatically — the paper's "model lead time from weeks to minutes",
testable end-to-end (``tests/test_calibration_refresh.py``).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Mapping

import jax.numpy as jnp
import numpy as np

from repro.core.quantiles import batch_sample_quantiles
from repro.core.transforms import QuantileMap
from repro.serving.drift import realized_alert_rate, transformed_stream_psi


@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """Gating + validation knobs for one fleet refresh pass."""

    alert_rate: float = 0.01        # Eq. 5 target alert rate ``a``
    rel_error: float = 0.2          # Eq. 5 relative error ``delta``
    z: float = 1.96                 # Eq. 5 confidence (95%)
    n_levels: int = 256             # knots in the refitted T^Q tables
    psi_bound: float = 0.25         # candidate-vs-reference drift bound
    alert_rate_tolerance: float = 0.5   # |realized - a| / a bound at tau
    min_distinct_knots: int = 8     # support coverage: degenerate-fit guard
    drift_bins: int = 10


@dataclasses.dataclass(frozen=True)
class CandidateReport:
    """Per-(tenant, predictor) outcome of one refresh pass."""

    tenant: str
    predictor: str
    samples: int                     # total events the stream has observed
    status: str                      # "refreshed" | "not_ready" | "rejected"
    reasons: tuple[str, ...] = ()
    psi: float = math.nan
    realized_alert_rate: float = math.nan


@dataclasses.dataclass(frozen=True)
class RefreshResult:
    """Outcome of one ``refresh_fleet`` pass."""

    generation: int                  # server bank generation after the pass
    reports: tuple[CandidateReport, ...]
    refit_seconds: float
    validate_seconds: float
    publish_seconds: float
    # engine stage-boundary counter when the pass was scheduled from an
    # AsyncDispatchEngine (-1 for direct/synchronous invocations)
    epoch: int = -1

    def _with(self, status: str) -> list[CandidateReport]:
        return [r for r in self.reports if r.status == status]

    @property
    def refreshed(self) -> list[CandidateReport]:
        return self._with("refreshed")

    @property
    def rejected(self) -> list[CandidateReport]:
        return self._with("rejected")

    @property
    def not_ready(self) -> list[CandidateReport]:
        return self._with("not_ready")


class CalibrationController:
    """The calibration control plane for one :class:`MuseServer`.

    Owns the scan -> gate -> refit -> validate -> publish loop described in
    the module docstring.  The controller never mutates served state except
    through the server's atomic ``publish_quantile_maps`` — the data plane
    cannot observe a half-applied refresh.
    """

    def __init__(self, server: "object", ref_quantiles: np.ndarray,
                 policy: RefreshPolicy | None = None) -> None:
        self.server = server
        self.ref_quantiles = np.asarray(ref_quantiles, np.float64)
        self.policy = policy or RefreshPolicy()
        self.history: list[RefreshResult] = []

    # ------------------------------------------------------------------ scan
    def scan(self) -> dict[tuple[str, str], "object"]:
        """Step 1: every live (tenant, predictor) estimator stream."""
        return self.server.estimator_streams()

    def ready(self) -> dict[tuple[str, str], "object"]:
        """Step 2: streams past the Eq. 5 sample-size gate."""
        p = self.policy
        return {k: est for k, est in self.scan().items()
                if est.ready(p.alert_rate, p.rel_error, p.z)}

    @staticmethod
    def _support_coverage(src: np.ndarray, stream: np.ndarray) -> float:
        lo, hi = src[0], src[-1]
        span = max(hi - lo, 1e-12)
        return float(np.mean((stream >= lo - 0.01 * span)
                             & (stream <= hi + 0.01 * span)))

    # -------------------------------------------------------------- validate
    def _validate(self, src: np.ndarray, ref: np.ndarray, stream: np.ndarray,
                  recent: np.ndarray | None = None,
                  ) -> tuple[tuple[str, ...], float, float]:
        """Step 4 checks for one candidate against one live stream.

        ``recent`` is the stream's newest-samples window: the candidate was
        fitted on the (all-time, uniformly sampled) reservoir, so checking
        support coverage against the reservoir alone is vacuous — a shift
        that happened AFTER the reservoir filled is diluted to near
        invisibility there, but dominates the recent window and must fail
        coverage.  Returns (failure reasons, psi, realized alert rate);
        empty reasons means the candidate may ship for this stream.
        """
        p = self.policy
        reasons: list[str] = []
        if not np.isfinite(src).all():
            reasons.append("non_finite_knots")
        if np.any(np.diff(src) < -1e-9):
            reasons.append("non_monotone")
        if len(np.unique(src)) < p.min_distinct_knots:
            reasons.append("degenerate_support")
        if self._support_coverage(src, stream) < 0.99:
            reasons.append("support_coverage")
        if recent is not None and len(recent) \
                and self._support_coverage(src, recent) < 0.98:
            reasons.append("support_coverage_recent")
        if reasons:
            return tuple(reasons), math.nan, math.nan
        # drift bound: map the live stream through the candidate and compare
        # against R (np.interp == Eq. 4 on monotone tables, clipped to R)
        mapped = np.interp(stream, src, ref)
        drift = transformed_stream_psi(mapped, self.ref_quantiles,
                                       n_bins=p.drift_bins)
        rate = realized_alert_rate(mapped, self.ref_quantiles, p.alert_rate)
        if drift > p.psi_bound:
            reasons.append("psi_bound")
        if abs(rate - p.alert_rate) / p.alert_rate > p.alert_rate_tolerance:
            reasons.append("alert_rate_shift")
        return tuple(reasons), drift, rate

    # --------------------------------------------------------------- refresh
    def refresh_fleet(self, only: "set[tuple[str, str]] | None" = None,
                      *, epoch: int = -1) -> RefreshResult:
        """One full pass: scan, gate, vectorized refit, validate, publish.

        ``epoch`` is the engine stage-boundary counter when the pass is
        scheduled through ``AsyncDispatchEngine.schedule_refresh`` (stamped
        into the result; -1 for direct synchronous calls).

        ``only`` restricts the pass to the given (tenant, predictor) keys —
        the drift-triggered path (``drift.py::CalibrationRefreshController``)
        refreshes just its alarmed streams through the same gate/validate/
        atomic-publish machinery.  The restriction is widened to PREDICTOR
        granularity: a published map recalibrates every tenant on that
        predictor, so all of its live streams must join the pooled refit and
        the validation (otherwise a single alarmed tenant could silently
        shift its peers' alert rates — the veto invariant would be
        bypassed).  Returns a :class:`RefreshResult`; the publish (if any
        stream was refreshed) is a single atomic generation bump on the
        server.
        """
        p = self.policy
        streams = self.scan()
        if only is not None:
            preds = {pred for _, pred in only}
            streams = {k: v for k, v in streams.items() if k[1] in preds}
        ready = {k: est for k, est in streams.items()
                 if est.ready(p.alert_rate, p.rel_error, p.z)}
        not_ready_reports: dict[tuple[str, str], CandidateReport] = {
            (t, pred): CandidateReport(t, pred, est.count, "not_ready",
                                       reasons=("eq5_gate",))
            for (t, pred), est in streams.items() if (t, pred) not in ready
        }

        # Step 3: one vectorized refit across the whole ready fleet.  Ready
        # streams are grouped by predictor (the published unit); a predictor
        # serving several ready tenant streams is refit on the pooled
        # samples, and the pooled candidate must validate against EVERY
        # tenant's stream before it may ship.
        t0 = time.perf_counter()
        by_pred: dict[str, list[tuple[str, "object"]]] = {}
        for (tenant, pred), est in ready.items():
            by_pred.setdefault(pred, []).append((tenant, est))
        pred_names = sorted(by_pred)
        levels = np.linspace(0.0, 1.0, p.n_levels)
        pooled = [np.concatenate([est.values() for _, est in by_pred[n]])
                  for n in pred_names]
        src_tables = batch_sample_quantiles(pooled, levels)   # (R, n_levels)
        refit_s = time.perf_counter() - t0

        # Step 4: per-stream validation of each predictor's candidate.
        t0 = time.perf_counter()
        ref = np.interp(levels, np.linspace(0.0, 1.0, len(self.ref_quantiles)),
                        self.ref_quantiles)
        updates: dict[str, QuantileMap] = {}
        reports: list[CandidateReport] = []
        for row, pred in enumerate(pred_names):
            src = src_tables[row]
            ship = True
            stream_reports: list[CandidateReport] = []
            for tenant, est in by_pred[pred]:
                samples = est.values()
                recent = est.recent() if hasattr(est, "recent") else None
                reasons, drift, rate = self._validate(src, ref, samples,
                                                      recent)
                ok = not reasons
                ship = ship and ok
                stream_reports.append(CandidateReport(
                    tenant, pred, est.count,
                    "refreshed" if ok else "rejected", reasons, drift, rate))
            # NOT-ready peer streams of this predictor are recalibrated by
            # the publish too, yet never joined the pool — give them a
            # support-coverage vote (robust at small n, unlike PSI/rate):
            # traffic outside the candidate's support must veto the publish
            for (t2, p2), est in streams.items():
                if p2 != pred or (t2, p2) in ready:
                    continue
                peer_reasons: list[str] = []
                samples2 = est.values()
                if len(samples2) and \
                        self._support_coverage(src, samples2) < 0.99:
                    peer_reasons.append("support_coverage")
                recent2 = est.recent() if hasattr(est, "recent") else None
                if recent2 is not None and len(recent2) and \
                        self._support_coverage(src, recent2) < 0.98:
                    peer_reasons.append("support_coverage_recent")
                if peer_reasons:
                    ship = False
                    not_ready_reports[(t2, p2)] = dataclasses.replace(
                        not_ready_reports[(t2, p2)],
                        reasons=("eq5_gate", *peer_reasons))
            if ship:
                updates[pred] = QuantileMap(
                    src_quantiles=jnp.asarray(src, jnp.float32),
                    ref_quantiles=jnp.asarray(ref, jnp.float32))
                reports.extend(stream_reports)
            else:
                # withhold the whole predictor: publishing a map one of its
                # tenants rejects would shift that tenant's alert rate.
                # Streams that passed individually are marked as vetoed so
                # the report distinguishes "this stream failed" from "a
                # peer tenant on the shared predictor failed".
                reports.extend(
                    r if r.status == "rejected" else dataclasses.replace(
                        r, status="rejected", reasons=("vetoed_by_peer",))
                    for r in stream_reports)
        reports = list(not_ready_reports.values()) + reports
        validate_s = time.perf_counter() - t0

        # Step 5: one atomic publish for the entire fleet.
        t0 = time.perf_counter()
        generation = self.server.publish_quantile_maps(updates) \
            if updates else self.server.bank_generation
        publish_s = time.perf_counter() - t0

        result = RefreshResult(
            generation=generation, reports=tuple(reports),
            refit_seconds=refit_s, validate_seconds=validate_s,
            publish_seconds=publish_s, epoch=epoch)
        self.history.append(result)
        return result
