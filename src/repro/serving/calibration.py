"""Fleet-wide atomic calibration refresh — the T^Q control plane.

The paper's core promise (Sec. 3.1) is that retraining-induced score-
distribution shift never invalidates client thresholds: the Quantile Mapping
T^Q is refit from the live stream and swapped in minutes, fleet-wide, so a
model update is invisible to every tenant's alerting rules.  This module is
that control plane.  :class:`CalibrationController.refresh_fleet` runs one
pass of the update lifecycle; each step maps onto the paper:

  1. **Scan** — enumerate every live (tenant, predictor) score stream the
     server has accumulated (the unlabeled post-aggregation T^Q *input*
     distribution, Sec. 2.3.3 — fitting needs no labels).
  2. **Gate (Eq. 5)** — a stream is refit only once it holds at least
     ``n = z^2 (1-a) / (delta^2 a)`` samples, the Appendix-A bound ensuring
     the realized alert rate at the fitted threshold deviates from the
     target ``a`` by at most ``delta`` (relative) with confidence ``z``.
  3. **Refit** — ALL ready streams are refit in ONE vectorized pass
     (:func:`repro.core.quantiles.batch_sample_quantiles`): reservoirs are
     padded into a single matrix and every tenant's source quantile table
     comes out of one ``np.nanquantile`` call (Eq. 4's q^S_i, fleet-wide).
  4. **Validate** — each candidate T^Q is checked against the live stream
     before it may ship: monotone non-decreasing knots (rank preservation,
     the paper's ROC invariant), non-degenerate support coverage, and a
     drift bound — PSI of the candidate-mapped stream against the reference
     R plus a realized-alert-rate band (``serving/drift.py``).  A failed
     candidate is withheld; the old map keeps serving.
  5. **Publish (atomic)** — every validated map lands in ONE
     ``MuseServer.publish_quantile_maps`` call: all affected model-group
     ``TransformBank``s are rebuilt as new immutable objects stamped with a
     bumped generation, then the server's references are swapped wholesale.
     In-flight dispatches finish on the old bank; the next window sees the
     new one — no torn reads, no partially-refreshed fleet.

Wired into ``serving/rollout.py``, a model promotion triggers the refresh
automatically — the paper's "model lead time from weeks to minutes",
testable end-to-end (``tests/test_calibration_refresh.py``).

The fleet calibration plane
---------------------------

One :class:`CalibrationController` refreshes ONE replica.  A fleet behind a
load balancer needs more: refreshing each replica independently lets N
replicas expose N divergent ``bank_generation``s to the same tenant
mid-update.  :class:`FleetCalibrationController` lifts calibration out of
the replica into a fleet-level control plane:

  * **who fits** — the fleet controller PULLS an exact estimator checkpoint
    snapshot from every replica (``MuseServer.snapshot_estimator_checkpoints``,
    the PR-5 serialization as wire format), reduces them per (tenant,
    predictor) with ``StreamingQuantileEstimator.merge_checkpoints`` (a
    mergeable-sketch reduction with a documented rank-error bound, see
    ``core/quantiles.py``), and runs the Eq.-5 gate → vectorized refit →
    candidate validation ONCE on the merged view — the fit sees the union
    of what every replica saw.
  * **who publishes** — the fleet controller broadcasts the validated maps
    to every replica under ONE fleet-stamped target generation
    (``publish_quantile_maps(updates, generation=...)``); on engine-backed
    replicas the publish lands at a stage boundary
    (``AsyncDispatchEngine.schedule_control``).  Replica acks advance the
    fleet generation; per-replica pull or publish failures become
    structured report entries (``pull_failures`` / ``nacked``), never a
    raise mid-refresh, and a fully failed pass leaves the fleet generation
    unchanged.
  * **what fences** — a replica rejects any fleet publish that is not
    strictly newer than what it already serves
    (:class:`~repro.serving.server.StaleGenerationError`), so a late ack
    from a superseded pass can never roll a replica backwards; a straggler
    that never acks keeps serving its complete OLD plane (old maps, old
    generation — internally consistent), and the generation-fenced
    ``ReplicaSet.dispatch`` keeps every client stream on replicas at or
    above its observed generation, making ``bank_generation`` fleet-
    monotone per stream, not just per replica.

This module is the HOST-PULL BOUNDARY for fused device tracking
(``ServerConfig(track_device=True)``): while serving, per-window samples
accumulate in the :class:`~repro.kernels.quantile_track.DeviceQuantileTracker`
staging buffer and the host estimators lag behind.  Every scan entry
point the controllers use — ``estimator_streams``,
``snapshot_estimator_checkpoints``, ``calibration_ready``,
``fit_custom_quantile_map``, ``save_estimators`` — first drains the
device stage under the server's estimator lock, replaying the exact
original window boundaries, so everything here (Eq.-5 gates, merges,
refits, checkpoints) observes estimator state bitwise identical to
eager host tracking.  Nothing in this module needs to know which
tracking mode a replica runs.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Mapping

import jax.numpy as jnp
import numpy as np

from repro.core.quantiles import (
    StreamingQuantileEstimator,
    batch_sample_quantiles,
)
from repro.core.transforms import QuantileMap
from repro.serving.drift import realized_alert_rate, transformed_stream_psi


@dataclasses.dataclass(frozen=True)
class RefreshPolicy:
    """Gating + validation knobs for one fleet refresh pass."""

    alert_rate: float = 0.01        # Eq. 5 target alert rate ``a``
    rel_error: float = 0.2          # Eq. 5 relative error ``delta``
    z: float = 1.96                 # Eq. 5 confidence (95%)
    n_levels: int = 256             # knots in the refitted T^Q tables
    psi_bound: float = 0.25         # candidate-vs-reference drift bound
    alert_rate_tolerance: float = 0.5   # |realized - a| / a bound at tau
    min_distinct_knots: int = 8     # support coverage: degenerate-fit guard
    drift_bins: int = 10
    # which window the refit (and its validation) sees per stream:
    #   "reservoir" — the all-time uniform reservoir (default; right when
    #     the stream is stationary-but-miscalibrated, e.g. after a model
    #     promotion);
    #   "recent"    — the newest-samples ring (the Full-range-Calibration
    #     regime: a FAST-drifting malicious distribution is diluted to
    #     invisibility in the all-time reservoir, so a drift-triggered
    #     refresh must fit on what the stream looks like NOW).
    # The Eq.-5 gate still counts total observed events either way.
    fit_window: str = "reservoir"


@dataclasses.dataclass(frozen=True)
class CandidateReport:
    """Per-(tenant, predictor) outcome of one refresh pass."""

    tenant: str
    predictor: str
    samples: int                     # total events the stream has observed
    # "refreshed" | "not_ready" | "rejected" | "pull_failed"
    status: str
    reasons: tuple[str, ...] = ()
    psi: float = math.nan
    realized_alert_rate: float = math.nan


@dataclasses.dataclass(frozen=True)
class StreamSnapshot:
    """Materialized view of one (tenant, predictor) stream for a fit pass.

    The gate/refit/validate machinery operates on snapshots, not live
    estimators: a single-replica pass snapshots its server's streams, the
    fleet pass snapshots MERGED estimators — same fit code either way, and
    a stream whose estimator fails mid-pull surfaces as a structured
    ``pull_failed`` report instead of aborting the whole refresh.
    """

    tenant: str
    predictor: str
    count: int
    values: np.ndarray
    recent: np.ndarray
    ready: bool


@dataclasses.dataclass(frozen=True)
class RefreshResult:
    """Outcome of one ``refresh_fleet`` pass."""

    generation: int                  # server bank generation after the pass
    reports: tuple[CandidateReport, ...]
    refit_seconds: float
    validate_seconds: float
    publish_seconds: float
    # engine stage-boundary counter when the pass was scheduled from an
    # AsyncDispatchEngine (-1 for direct/synchronous invocations)
    epoch: int = -1

    def _with(self, status: str) -> list[CandidateReport]:
        return [r for r in self.reports if r.status == status]

    @property
    def refreshed(self) -> list[CandidateReport]:
        return self._with("refreshed")

    @property
    def rejected(self) -> list[CandidateReport]:
        return self._with("rejected")

    @property
    def not_ready(self) -> list[CandidateReport]:
        return self._with("not_ready")

    @property
    def pull_failed(self) -> list[CandidateReport]:
        return self._with("pull_failed")


class CalibrationController:
    """The calibration control plane for one :class:`MuseServer`.

    Owns the scan -> gate -> refit -> validate -> publish loop described in
    the module docstring.  The controller never mutates served state except
    through the server's atomic ``publish_quantile_maps`` — the data plane
    cannot observe a half-applied refresh.
    """

    def __init__(self, server: "object", ref_quantiles: np.ndarray,
                 policy: RefreshPolicy | None = None) -> None:
        self.server = server
        self.ref_quantiles = np.asarray(ref_quantiles, np.float64)
        self.policy = policy or RefreshPolicy()
        self.history: list[RefreshResult] = []

    # ------------------------------------------------------------------ scan
    def scan(self) -> dict[tuple[str, str], "object"]:
        """Step 1: every live (tenant, predictor) estimator stream."""
        return self.server.estimator_streams()

    def ready(self) -> dict[tuple[str, str], "object"]:
        """Step 2: streams past the Eq. 5 sample-size gate."""
        p = self.policy
        return {k: est for k, est in self.scan().items()
                if est.ready(p.alert_rate, p.rel_error, p.z)}

    @staticmethod
    def _support_coverage(src: np.ndarray, stream: np.ndarray) -> float:
        lo, hi = src[0], src[-1]
        span = max(hi - lo, 1e-12)
        return float(np.mean((stream >= lo - 0.01 * span)
                             & (stream <= hi + 0.01 * span)))

    # -------------------------------------------------------------- validate
    def _validate(self, src: np.ndarray, ref: np.ndarray, stream: np.ndarray,
                  recent: np.ndarray | None = None,
                  ) -> tuple[tuple[str, ...], float, float]:
        """Step 4 checks for one candidate against one live stream.

        ``recent`` is the stream's newest-samples window: the candidate was
        fitted on the (all-time, uniformly sampled) reservoir, so checking
        support coverage against the reservoir alone is vacuous — a shift
        that happened AFTER the reservoir filled is diluted to near
        invisibility there, but dominates the recent window and must fail
        coverage.  Returns (failure reasons, psi, realized alert rate);
        empty reasons means the candidate may ship for this stream.
        """
        p = self.policy
        reasons: list[str] = []
        if not np.isfinite(src).all():
            reasons.append("non_finite_knots")
        if np.any(np.diff(src) < -1e-9):
            reasons.append("non_monotone")
        if len(np.unique(src)) < p.min_distinct_knots:
            reasons.append("degenerate_support")
        if self._support_coverage(src, stream) < 0.99:
            reasons.append("support_coverage")
        if recent is not None and len(recent) \
                and self._support_coverage(src, recent) < 0.98:
            reasons.append("support_coverage_recent")
        if reasons:
            return tuple(reasons), math.nan, math.nan
        # drift bound: map the live stream through the candidate and compare
        # against R (np.interp == Eq. 4 on monotone tables, clipped to R)
        mapped = np.interp(stream, src, ref)
        drift = transformed_stream_psi(mapped, self.ref_quantiles,
                                       n_bins=p.drift_bins)
        rate = realized_alert_rate(mapped, self.ref_quantiles, p.alert_rate)
        if drift > p.psi_bound:
            reasons.append("psi_bound")
        if abs(rate - p.alert_rate) / p.alert_rate > p.alert_rate_tolerance:
            reasons.append("alert_rate_shift")
        return tuple(reasons), drift, rate

    # ------------------------------------------------------------- snapshot
    def _snapshot(self, streams: "Mapping[tuple[str, str], object]",
                  only: "set[tuple[str, str]] | None" = None,
                  ) -> tuple[dict[tuple[str, str], StreamSnapshot],
                             list[CandidateReport]]:
        """Materialize live estimators into :class:`StreamSnapshot`s.

        ``only`` is widened to PREDICTOR granularity here: a published map
        recalibrates every tenant on that predictor, so all of its live
        streams must join the pooled refit and the validation (otherwise a
        single alarmed tenant could silently shift its peers' alert rates —
        the veto invariant would be bypassed).  A stream whose estimator
        raises mid-read (its replica/predictor vanished between scan and
        pull) becomes a structured ``pull_failed`` report instead of
        aborting the pass.
        """
        p = self.policy
        if only is not None:
            preds = {pred for _, pred in only}
            streams = {k: v for k, v in streams.items() if k[1] in preds}
        snaps: dict[tuple[str, str], StreamSnapshot] = {}
        failures: list[CandidateReport] = []
        for (tenant, pred), est in streams.items():
            try:
                recent = np.asarray(est.recent(), np.float64) \
                    if hasattr(est, "recent") else np.empty(0, np.float64)
                snaps[(tenant, pred)] = StreamSnapshot(
                    tenant, pred, est.count,
                    np.asarray(est.values(), np.float64), recent,
                    est.ready(p.alert_rate, p.rel_error, p.z))
            except Exception as e:  # noqa: BLE001 — stream gone mid-scan
                failures.append(CandidateReport(
                    tenant, pred, 0, "pull_failed",
                    reasons=(f"pull:{type(e).__name__}",)))
        return snaps, failures

    # ------------------------------------------------------------------ plan
    def _plan(self, snaps: dict[tuple[str, str], StreamSnapshot],
              ) -> tuple[dict[str, QuantileMap], list[CandidateReport],
                         float, float]:
        """Steps 2–4 on materialized snapshots: gate, ONE vectorized refit,
        per-stream validation.  Returns (validated updates, reports,
        refit seconds, validate seconds) — publish is the caller's job (one
        atomic swap for a single server; a fenced fleet broadcast for the
        fleet plane)."""
        p = self.policy
        ready = {k: s for k, s in snaps.items() if s.ready}
        not_ready_reports: dict[tuple[str, str], CandidateReport] = {
            (t, pred): CandidateReport(t, pred, s.count, "not_ready",
                                       reasons=("eq5_gate",))
            for (t, pred), s in snaps.items() if (t, pred) not in ready
        }

        # Step 3: one vectorized refit across the whole ready fleet.  Ready
        # streams are grouped by predictor (the published unit); a predictor
        # serving several ready tenant streams is refit on the pooled
        # samples, and the pooled candidate must validate against EVERY
        # tenant's stream before it may ship.  ``fit_window`` picks WHICH
        # samples: the all-time reservoir, or (for fast-drift refreshes)
        # the recent ring — validated against the same window, since that
        # is the distribution the candidate will serve next.
        def fit_values(s: StreamSnapshot) -> np.ndarray:
            if p.fit_window == "recent" and len(s.recent):
                return s.recent
            return s.values

        t0 = time.perf_counter()
        by_pred: dict[str, list[StreamSnapshot]] = {}
        for (tenant, pred), s in ready.items():
            by_pred.setdefault(pred, []).append(s)
        pred_names = sorted(by_pred)
        levels = np.linspace(0.0, 1.0, p.n_levels)
        pooled = [np.concatenate([fit_values(s) for s in by_pred[n]])
                  for n in pred_names]
        src_tables = batch_sample_quantiles(pooled, levels)   # (R, n_levels)
        refit_s = time.perf_counter() - t0

        # Step 4: per-stream validation of each predictor's candidate.
        t0 = time.perf_counter()
        ref = np.interp(levels, np.linspace(0.0, 1.0, len(self.ref_quantiles)),
                        self.ref_quantiles)
        updates: dict[str, QuantileMap] = {}
        reports: list[CandidateReport] = []
        for row, pred in enumerate(pred_names):
            src = src_tables[row]
            ship = True
            stream_reports: list[CandidateReport] = []
            for s in by_pred[pred]:
                reasons, drift, rate = self._validate(
                    src, ref, fit_values(s),
                    s.recent if len(s.recent) else None)
                ok = not reasons
                ship = ship and ok
                stream_reports.append(CandidateReport(
                    s.tenant, pred, s.count,
                    "refreshed" if ok else "rejected", reasons, drift, rate))
            # NOT-ready peer streams of this predictor are recalibrated by
            # the publish too, yet never joined the pool — give them a
            # support-coverage vote (robust at small n, unlike PSI/rate):
            # traffic outside the candidate's support must veto the publish
            for (t2, p2), s in snaps.items():
                if p2 != pred or (t2, p2) in ready:
                    continue
                peer_reasons: list[str] = []
                if len(s.values) and \
                        self._support_coverage(src, s.values) < 0.99:
                    peer_reasons.append("support_coverage")
                if len(s.recent) and \
                        self._support_coverage(src, s.recent) < 0.98:
                    peer_reasons.append("support_coverage_recent")
                if peer_reasons:
                    ship = False
                    not_ready_reports[(t2, p2)] = dataclasses.replace(
                        not_ready_reports[(t2, p2)],
                        reasons=("eq5_gate", *peer_reasons))
            if ship:
                updates[pred] = QuantileMap(
                    src_quantiles=jnp.asarray(src, jnp.float32),
                    ref_quantiles=jnp.asarray(ref, jnp.float32))
                reports.extend(stream_reports)
            else:
                # withhold the whole predictor: publishing a map one of its
                # tenants rejects would shift that tenant's alert rate.
                # Streams that passed individually are marked as vetoed so
                # the report distinguishes "this stream failed" from "a
                # peer tenant on the shared predictor failed".
                reports.extend(
                    r if r.status == "rejected" else dataclasses.replace(
                        r, status="rejected", reasons=("vetoed_by_peer",))
                    for r in stream_reports)
        reports = list(not_ready_reports.values()) + reports
        validate_s = time.perf_counter() - t0
        return updates, reports, refit_s, validate_s

    # --------------------------------------------------------------- refresh
    def refresh_fleet(self, only: "set[tuple[str, str]] | None" = None,
                      *, epoch: int = -1) -> RefreshResult:
        """One full pass: scan, gate, vectorized refit, validate, publish.

        ``epoch`` is the engine stage-boundary counter when the pass is
        scheduled through ``AsyncDispatchEngine.schedule_refresh`` (stamped
        into the result; -1 for direct synchronous calls).

        ``only`` restricts the pass to the given (tenant, predictor) keys —
        the drift-triggered path (``drift.py::CalibrationRefreshController``)
        refreshes just its alarmed streams through the same gate/validate/
        atomic-publish machinery (widened to predictor granularity, see
        :meth:`_snapshot`).  Returns a :class:`RefreshResult`; the publish
        (if any stream was refreshed) is a single atomic generation bump on
        the server.
        """
        snaps, failures = self._snapshot(self.scan(), only)
        updates, reports, refit_s, validate_s = self._plan(snaps)

        # Step 5: one atomic publish for the entire server.
        t0 = time.perf_counter()
        generation = self.server.publish_quantile_maps(updates) \
            if updates else self.server.bank_generation
        if updates:
            # tiered topology: a publish may have just admitted tenants past
            # the Eq.-5 gate (their first calibrated map landed) — run one
            # promotion pass so they get real hot/victim slots instead of
            # paging on their next window.  No-op on non-tiered servers;
            # under tiered-over-sharded this rebalances every shard's tier
            # in one lockstep pass (per-shard clocks, one store op).
            rebalance = getattr(self.server, "rebalance_tiers", None)
            if rebalance is not None:
                rebalance()
        publish_s = time.perf_counter() - t0

        result = RefreshResult(
            generation=generation, reports=tuple(failures + reports),
            refit_seconds=refit_s, validate_seconds=validate_s,
            publish_seconds=publish_s, epoch=epoch)
        self.history.append(result)
        return result


# --------------------------------------------------------------------------
# Fleet-level calibration plane
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaPullFailure:
    """One replica whose estimator snapshot could not be pulled this pass."""

    replica_id: str
    error: str


@dataclasses.dataclass(frozen=True)
class FleetRefreshResult(RefreshResult):
    """Outcome of one fleet-wide refresh pass.

    Extends :class:`RefreshResult` (``generation`` is the fleet generation
    after the pass) with the broadcast ledger: which replicas acked the
    fenced publish, which rejected or failed it, which could not even be
    pulled, plus the merge cost of the sketch reduction.
    """

    fleet_generation: int = -1
    acked: tuple[str, ...] = ()
    nacked: tuple[str, ...] = ()
    pull_failures: tuple[ReplicaPullFailure, ...] = ()
    merged_streams: int = 0
    merge_seconds: float = 0.0


class FleetCalibrationController(CalibrationController):
    """One calibration plane for a FLEET of replicas.

    Replaces N independent per-replica ``CalibrationController`` passes
    (which let replicas expose divergent generations to the same tenant)
    with a single pull -> merge -> fit -> fenced-broadcast pass:

      1. **Pull** — exact estimator checkpoints from every replica
         (``MuseServer.snapshot_estimator_checkpoints``).  A replica that
         fails the pull becomes a :class:`ReplicaPullFailure` entry; the
         pass continues on the replicas that answered.
      2. **Merge** — per (tenant, predictor) reduction via
         ``StreamingQuantileEstimator.merge_checkpoints`` (rank-error bound
         documented in ``core/quantiles.py``).
      3. **Fit** — the inherited ``_snapshot``/``_plan`` machinery (Eq.-5
         gate, ONE vectorized refit, per-stream validation with peer veto)
         runs once, on the merged view.
      4. **Broadcast (fenced)** — validated maps go to every replica under
         one target generation strictly above every generation currently
         served anywhere in the fleet.  Each replica's update set is
         filtered to its live predictors (an empty filtered set is a
         generation fast-forward, still an ack).  Engine-backed replicas
         apply the publish at a stage boundary via
         ``AsyncDispatchEngine.schedule_control``.  Acks advance the fleet
         generation; a replica that nacks (or never acks) keeps serving its
         complete old plane and is fenced out by
         ``MuseServer.publish_quantile_maps(..., generation=...)`` from
         ever applying a superseded pass late.

    ``replica_set`` is anything exposing ``.replicas`` (a
    ``rollout.ReplicaSet``) or an iterable of objects with ``replica_id``,
    ``server`` and optional ``engine`` attributes.
    """

    def __init__(self, replica_set: "object", ref_quantiles: np.ndarray,
                 policy: RefreshPolicy | None = None,
                 publish_timeout: float = 60.0) -> None:
        super().__init__(None, ref_quantiles, policy)
        self.replica_set = replica_set
        self.publish_timeout = publish_timeout
        self._fleet_generation = 0
        # cumulative content of the fleet plane: every map ever published,
        # newest per predictor.  Broadcasting the UNION each pass (and on
        # ``align``) makes a generation's CONTENT fleet-consistent, not just
        # its stamp: a healed straggler or a freshly surged replica receives
        # the maps it missed, so the audit ledger's (generation, predictor)
        # -> parameters relation holds across every replica (the replay
        # contract in ``serving/audit.py`` depends on this).
        self._published: dict[str, QuantileMap] = {}

    # ----------------------------------------------------------------- fleet
    def _iter_replicas(self) -> list["object"]:
        reps = getattr(self.replica_set, "replicas", self.replica_set)
        return list(reps)

    def fleet_generation(self) -> int:
        """Highest generation the fleet plane has published or observed."""
        gen = self._fleet_generation
        for rep in self._iter_replicas():
            try:
                gen = max(gen, rep.server.bank_generation)
            except Exception:  # noqa: BLE001 — unreachable replica
                continue
        return gen

    # ------------------------------------------------------------ pull/merge
    def _pull_merged(self) -> tuple[
            dict[tuple[str, str], StreamingQuantileEstimator],
            tuple[ReplicaPullFailure, ...], float]:
        """Steps 1–2: pull every replica's checkpoints, merge per stream."""
        t0 = time.perf_counter()
        parts: dict[tuple[str, str], list[tuple[dict, dict]]] = {}
        failures: list[ReplicaPullFailure] = []
        for rep in self._iter_replicas():
            try:
                snap = rep.server.snapshot_estimator_checkpoints()
            except Exception as e:  # noqa: BLE001 — structured, not raised
                failures.append(ReplicaPullFailure(
                    str(getattr(rep, "replica_id", rep)),
                    f"{type(e).__name__}: {e}"))
                continue
            for key, ckpt in snap.items():
                parts.setdefault(key, []).append(ckpt)
        merged = {key: StreamingQuantileEstimator.merge_checkpoints(ps)
                  for key, ps in parts.items()}
        return merged, tuple(failures), time.perf_counter() - t0

    def scan(self) -> dict[tuple[str, str], "object"]:
        """Step 1 fleet-wide: the MERGED per-stream estimators."""
        merged, _, _ = self._pull_merged()
        return merged

    # -------------------------------------------------------------- publish
    def _publish_to(self, rep: "object", updates: dict[str, QuantileMap],
                    target: int) -> int:
        """Fenced publish of ``updates`` to one replica at ``target``.

        Filters to the replica's live predictors (an empty filtered set is
        a pure generation fast-forward).  Engine-backed replicas apply the
        swap at a stage boundary so no in-flight window straddles it.
        """
        live = set(rep.server.predictors)
        filtered = {p: m for p, m in updates.items() if p in live}
        engine = getattr(rep, "engine", None)
        if engine is not None and hasattr(engine, "schedule_control"):
            fut = engine.schedule_control(
                lambda srv=rep.server: srv.publish_quantile_maps(
                    filtered, generation=target))
            return fut.result(timeout=self.publish_timeout)
        return rep.server.publish_quantile_maps(filtered, generation=target)

    def align(self, rep: "object") -> int:
        """Fast-forward one (new/surged) replica to the fleet generation.

        A fenced publish of the plane's RETAINED maps (everything the fleet
        has ever published, newest per predictor): the replica's banks land
        on the current fleet generation with the same CONTENT its siblings
        serve, so the fenced ``ReplicaSet.dispatch`` can route generation-
        pinned streams to it immediately and a response stamped with
        generation *g* means the same transform parameters on every
        replica.  No-op if the replica is already at or above the fleet
        generation.
        """
        target = self.fleet_generation()
        if rep.server.bank_generation >= target:
            return rep.server.bank_generation
        return self._publish_to(rep, dict(self._published), target)

    # --------------------------------------------------------------- refresh
    def refresh_fleet(self, only: "set[tuple[str, str]] | None" = None,
                      *, epoch: int = -1) -> FleetRefreshResult:
        """One fleet pass: pull, merge, gate, refit, validate, broadcast.

        Never raises on per-replica failure: pull failures surface in
        ``result.pull_failures``, publish failures in ``result.nacked``.
        The fleet generation advances iff at least one replica acked the
        fenced broadcast; a fully failed (or updateless) pass leaves it
        unchanged.
        """
        merged, pull_failures, merge_s = self._pull_merged()
        snaps, failures = self._snapshot(merged, only)
        updates, reports, refit_s, validate_s = self._plan(snaps)

        t0 = time.perf_counter()
        acked: list[str] = []
        nacked: list[str] = []
        if updates:
            failed_ids = {f.replica_id for f in pull_failures}
            replicas = [r for r in self._iter_replicas()
                        if str(getattr(r, "replica_id", r)) not in failed_ids]
            # Fence strictly above everything served anywhere in the fleet:
            # a replica that raced ahead (e.g. a local publish) cannot force
            # a sibling to accept a non-monotone stamp.
            target = self._fleet_generation
            for rep in replicas:
                target = max(target, rep.server.bank_generation)
            target += 1
            # broadcast the cumulative plane content (retained maps +
            # this pass's updates): a replica that nacked an earlier pass
            # heals to full content on its next ack, keeping (generation ->
            # parameters) fleet-consistent for the audit replay contract.
            broadcast = {**self._published, **updates}
            for rep in replicas:
                rid = str(getattr(rep, "replica_id", rep))
                try:
                    self._publish_to(rep, broadcast, target)
                except Exception as e:  # noqa: BLE001 — straggler/stale
                    nacked.append(rid)
                    reports.append(CandidateReport(
                        f"replica:{rid}", "*", 0, "pull_failed",
                        reasons=(f"publish:{type(e).__name__}",)))
                else:
                    acked.append(rid)
                    # tiered replicas: promote freshly admitted tenants now
                    # that the fenced broadcast landed on this replica
                    rebalance = getattr(rep.server, "rebalance_tiers", None)
                    if rebalance is not None:
                        try:
                            rebalance()
                        except Exception:  # noqa: BLE001 — best-effort
                            pass
            if acked:
                self._fleet_generation = target
                self._published = broadcast
        publish_s = time.perf_counter() - t0

        result = FleetRefreshResult(
            generation=self._fleet_generation,
            reports=tuple(failures + reports),
            refit_seconds=refit_s, validate_seconds=validate_s,
            publish_seconds=publish_s, epoch=epoch,
            fleet_generation=self._fleet_generation,
            acked=tuple(acked), nacked=tuple(nacked),
            pull_failures=pull_failures, merged_streams=len(snaps),
            merge_seconds=merge_s)
        self.history.append(result)
        return result
