"""Client-side decision loop over transformed scores.

MUSE's whole calibration machinery exists so that a CLIENT can hold a fixed
business rule — "alert on the top ``a`` fraction of traffic, hard-block the
extreme tail" — while models retrain and T^Q maps refresh underneath it.
This module is that client: a per-tenant threshold harness over the
*transformed* (post-T^Q) scores, with the grace / cooldown / instant-block
semantics of production fraud-ops decision loops (cf. the IoT-guard
``decision_loop.py`` referenced in the ROADMAP):

  * **thresholds** — ``tau`` is the ``(1 - alert_rate)`` quantile of the
    shared reference distribution R, ``tau_block`` the ``(1 - block_rate)``
    quantile; both are fixed client-side constants precisely because T^Q
    keeps mapping every tenant's live distribution onto R;
  * **grace** — a tenant's first ``grace_events`` events only observe
    (no alerts): a freshly onboarded stream is still cold-starting its
    calibration and must not page an analyst on day zero;
  * **instant block** — a score at or above ``tau_block`` blocks
    immediately, grace or not (the one rule that never defers);
  * **cooldown** — after a block, ``cooldown_events`` subsequent events are
    suppressed to "allow": the fraud-ops analogue of alarm damping, so one
    burst cannot flood the review queue.

Every event produces a :class:`Decision` keyed by the originating request
id, carrying the full replay witness: the served score, the raw expert
scores, the ``bank_generation`` provenance stamp, both thresholds, and the
loop-state inputs (grace flag, cooldown counter) that the pure
:func:`decide` function consumed.  Feeding decisions to an
``audit.AuditLog`` makes the whole loop tamper-evident and bit-for-bit
replayable — see ``serving/audit.py`` for the chain + replay contract.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from repro.serving.types import ScoringRequest, ScoringResponse


@dataclasses.dataclass(frozen=True)
class DecisionPolicy:
    """Client-side thresholding knobs (all in reference-distribution terms)."""

    alert_rate: float = 0.02          # alert on the top ``a`` of R
    block_rate: float = 0.0005        # instant-block on the extreme tail
    grace_events: int = 0             # observe-only warmup per tenant
    cooldown_events: int = 0          # post-block alert damping

    def thresholds(self, ref_quantiles: np.ndarray
                   ) -> tuple[float, float]:
        """(tau, tau_block) — the (1-a) and (1-b) quantiles of R."""
        tq = np.asarray(ref_quantiles, np.float64)
        levels = np.linspace(0.0, 1.0, len(tq))
        tau = float(np.interp(1.0 - self.alert_rate, levels, tq))
        tau_block = float(np.interp(1.0 - self.block_rate, levels, tq))
        return tau, max(tau_block, tau)


def decide(score: float, threshold: float, block_threshold: float,
           in_grace: bool, cooldown: int) -> str:
    """The pure decision function: (score, thresholds, state) -> action.

    Deliberately free of any hidden state so an audit replay can recompute
    the action from an entry's recorded fields alone (the replay contract
    in ``serving/audit.py``).
    """
    if score >= block_threshold:
        return "block"                # instant block outranks grace/cooldown
    if in_grace or cooldown > 0:
        return "allow"
    if score >= threshold:
        return "alert"
    return "allow"


@dataclasses.dataclass(frozen=True)
class Decision:
    """One per-event client decision, keyed by request id.

    Carries everything ``audit.verify`` needs to reproduce it bit-for-bit:
    the raw expert scores + ``bank_generation`` reproduce ``score`` through
    the exact generation's transform pipeline, and (``threshold``,
    ``block_threshold``, ``grace``, ``cooldown``) reproduce ``action``
    through :func:`decide`.
    """

    request_id: int
    tenant: str
    predictor: str
    score: float
    raw_scores: tuple[float, ...]
    bank_generation: int
    threshold: float
    block_threshold: float
    action: str                       # "allow" | "alert" | "block"
    seq: int                          # per-tenant event sequence number
    grace: bool                       # tenant was in grace BEFORE this event
    cooldown: int                     # cooldown counter BEFORE this event


@dataclasses.dataclass
class _TenantState:
    seq: int = 0
    cooldown: int = 0
    events: int = 0
    alerts: int = 0
    blocks: int = 0


class DecisionLoop:
    """Per-tenant threshold harness over served :class:`ScoringResponse`s.

    ``process`` consumes one dispatched window (requests + their aligned
    responses), advances each tenant's state machine, and returns the
    per-event :class:`Decision`s in request order.  When an ``audit`` log
    is attached every decision is appended to the hash chain as it is made
    — the decision and its tamper-evident record are never out of sync.
    """

    def __init__(self, policy: DecisionPolicy, ref_quantiles: np.ndarray,
                 audit: "object | None" = None) -> None:
        self.policy = policy
        self.tau, self.tau_block = policy.thresholds(ref_quantiles)
        self.audit = audit
        self._tenants: dict[str, _TenantState] = {}

    # ------------------------------------------------------------------ state
    def state(self, tenant: str) -> _TenantState:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantState()
        return st

    def realized_rates(self) -> dict[str, dict[str, float]]:
        """Per-tenant alert/block rates over everything processed so far."""
        out = {}
        for t, st in self._tenants.items():
            n = max(st.events, 1)
            out[t] = {"events": st.events,
                      "alert_rate": st.alerts / n,
                      "block_rate": st.blocks / n}
        return out

    def reset_counters(self) -> None:
        """Zero the per-tenant alert/block counters (e.g. at a measurement
        window boundary) without touching grace/cooldown progression."""
        for st in self._tenants.values():
            st.events = st.alerts = st.blocks = 0

    # ---------------------------------------------------------------- process
    def process(self, requests: Sequence[ScoringRequest],
                responses: Iterable[ScoringResponse]) -> list[Decision]:
        decisions: list[Decision] = []
        for req, resp in zip(requests, responses):
            tenant = req.intent.tenant
            st = self.state(tenant)
            in_grace = st.seq < self.policy.grace_events
            cooldown = st.cooldown
            action = decide(resp.score, self.tau, self.tau_block,
                            in_grace, cooldown)
            d = Decision(
                request_id=resp.request_id, tenant=tenant,
                predictor=resp.predictor, score=resp.score,
                raw_scores=tuple(resp.raw_scores),
                bank_generation=resp.bank_generation,
                threshold=self.tau, block_threshold=self.tau_block,
                action=action, seq=st.seq, grace=in_grace,
                cooldown=cooldown)
            st.seq += 1
            st.events += 1
            if cooldown > 0:
                st.cooldown -= 1
            if action == "alert":
                st.alerts += 1
            elif action == "block":
                st.blocks += 1
                st.cooldown = self.policy.cooldown_events
            if self.audit is not None:
                self.audit.append(d)
            decisions.append(d)
        return decisions
