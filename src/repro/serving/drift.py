"""Closed-loop distribution-drift monitoring + automated calibration refresh.

Implements the paper's FIRST roadmap item (Sec. 5): "automatically trigger
background re-fitting of the Quantile Mapping, based on a closed-loop
distribution drift monitoring, ensuring stability between model retrains."

Mechanism:
  * every served (tenant, predictor) score stream feeds a rolling window;
  * drift of the *post-T^Q* distribution against the reference R is measured
    with PSI (population stability index — the industry-standard banking
    drift score) and JSD;
  * when PSI exceeds the alarm threshold AND the Eq.-5 sample-size gate for
    the raw-score stream is open, the controller re-fits the tenant's source
    quantiles from live raw scores and hot-swaps T^Q — no deployment event
    needed, closing the loop the paper leaves open.

PSI interpretation (standard): < 0.1 stable, 0.1-0.25 moderate, > 0.25 action.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


def psi(observed: np.ndarray, expected: np.ndarray, eps: float = 1e-6) -> float:
    """Population Stability Index between two discrete distributions."""
    o = np.asarray(observed, np.float64) + eps
    e = np.asarray(expected, np.float64) + eps
    o /= o.sum()
    e /= e.sum()
    return float(np.sum((o - e) * np.log(o / e)))


def reference_bin_masses(ref_quantiles: np.ndarray, edges: np.ndarray,
                         levels: np.ndarray | None = None) -> np.ndarray:
    """Expected bin masses of the reference distribution R at ``edges``."""
    tq = np.asarray(ref_quantiles, np.float64)
    if levels is None:
        levels = np.linspace(0.0, 1.0, len(tq))
    cdf = np.interp(edges, tq, levels, left=0.0, right=1.0)
    return np.diff(cdf)


def transformed_stream_psi(transformed_scores: np.ndarray,
                           ref_quantiles: np.ndarray,
                           n_bins: int = 10) -> float:
    """PSI of an (already T^Q-mapped) score sample against the reference R.

    The calibration controller's candidate-validation bound: a refreshed
    T^Q applied to the very stream it was fitted on must land close to R —
    a large PSI here means the fit is untrustworthy (degenerate support,
    poisoned stream), and the candidate must not be published.
    """
    s = np.asarray(transformed_scores, np.float64).ravel()
    if len(s) == 0:
        return float("inf")
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    expected = reference_bin_masses(ref_quantiles, edges)
    counts, _ = np.histogram(np.clip(s, 0.0, 1.0), bins=edges)
    return psi(counts / len(s), expected)


def realized_alert_rate(transformed_scores: np.ndarray,
                        ref_quantiles: np.ndarray,
                        target_alert_rate: float,
                        levels: np.ndarray | None = None) -> float:
    """Fraction of scores above the reference alert threshold.

    The client threshold tau is the (1 - a) quantile of R (a = target alert
    rate); the paper's headline invariant is that a calibration refresh keeps
    the realized rate at tau within the Eq.-5 error band of a.
    """
    tq = np.asarray(ref_quantiles, np.float64)
    if levels is None:
        levels = np.linspace(0.0, 1.0, len(tq))
    tau = float(np.interp(1.0 - target_alert_rate, levels, tq))
    s = np.asarray(transformed_scores, np.float64).ravel()
    if len(s) == 0:
        return float("nan")
    return float(np.mean(s >= tau))


@dataclasses.dataclass
class DriftMonitor:
    """Rolling-window drift detector for one (tenant, predictor) stream."""

    ref_quantiles: np.ndarray
    window: int = 20_000
    n_bins: int = 10
    psi_alarm: float = 0.25

    def __post_init__(self) -> None:
        self._buf = np.empty(self.window, np.float64)
        self._n = 0
        self._total = 0
        self.edges = np.linspace(0.0, 1.0, self.n_bins + 1)
        self.expected = reference_bin_masses(self.ref_quantiles, self.edges)

    def update(self, served_scores: np.ndarray) -> None:
        s = np.asarray(served_scores, np.float64).ravel()
        for v in s:  # ring buffer
            self._buf[self._total % self.window] = v
            self._total += 1
        self._n = min(self._total, self.window)

    @property
    def count(self) -> int:
        return self._total

    def current_psi(self) -> float:
        if self._n < self.n_bins * 20:  # too little data to bin
            return 0.0
        counts, _ = np.histogram(self._buf[: self._n], bins=self.edges)
        return psi(counts / self._n, self.expected)

    def drifted(self) -> bool:
        return self.current_psi() > self.psi_alarm


@dataclasses.dataclass
class CalibrationRefreshController:
    """The closed loop: monitor drift -> gate on Eq. 5 -> refresh T^Q.

    Wire into a MuseServer with ``attach``; afterwards every ``score_batch``
    feeds the monitors and ``tick`` applies any due refreshes.
    """

    # MuseServer; may be None when ``fleet`` is set (the fleet plane then
    # supplies both the Eq.-5 gate and the refresh machinery)
    server: "object | None"
    ref_quantiles: np.ndarray
    psi_alarm: float = 0.25
    window: int = 20_000
    # ticks an alarmed-but-rejected stream sits out before the next refresh
    # attempt — a persistently poisoned stream must not re-run the pooled
    # refit + validation of its whole predictor on every tick
    reject_cooldown: int = 5
    refreshes: list[tuple[str, str, float]] = dataclasses.field(
        default_factory=list)
    # rejected/vetoed attempts, for operators: (tenant, predictor, reasons)
    rejections: list[tuple[str, str, tuple[str, ...]]] = dataclasses.field(
        default_factory=list)
    # optional calibration.FleetCalibrationController: when set, tick()
    # routes due refreshes through the fleet plane (merged sketches, one
    # fenced broadcast) instead of a single-server CalibrationController
    fleet: "object | None" = None

    def __post_init__(self) -> None:
        self._monitors: dict[tuple[str, str], DriftMonitor] = {}
        self._cooldown: dict[tuple[str, str], int] = {}

    def observe(self, tenant: str, predictor: str,
                served_scores: np.ndarray) -> None:
        key = (tenant, predictor)
        mon = self._monitors.get(key)
        if mon is None:
            mon = DriftMonitor(self.ref_quantiles, window=self.window,
                               psi_alarm=self.psi_alarm)
            self._monitors[key] = mon
        mon.update(served_scores)

    def attach(self) -> None:
        """Wrap server.score_batch so served scores feed the monitors."""
        inner = self.server.score_batch

        def wrapped(requests):
            responses = inner(requests)
            by_key: dict[tuple[str, str], list[float]] = {}
            for req, resp in zip(requests, responses):
                by_key.setdefault((req.intent.tenant, resp.predictor),
                                  []).append(resp.score)
            for (tenant, pred), scores in by_key.items():
                self.observe(tenant, pred, np.asarray(scores))
            return responses

        self.server.score_batch = wrapped

    def tick(self) -> list[tuple[str, str, float]]:
        """Run one control-loop pass; returns refreshes performed.

        Drift-alarmed streams past the Eq.-5 gate are refreshed through
        ``CalibrationController.refresh_fleet(only=...)`` — the SAME
        gate/validate/atomic-publish machinery as the fleet-wide pass, so a
        poisoned or degenerate stream that trips the drift alarm can never
        ship an unvalidated T^Q, and all due refreshes land as ONE bank
        generation instead of a swap per stream.
        """
        for key in list(self._cooldown):
            self._cooldown[key] -= 1
            if self._cooldown[key] <= 0:
                del self._cooldown[key]
        alarmed = {(t, p): mon.current_psi()
                   for (t, p), mon in self._monitors.items()
                   if mon.drifted() and (t, p) not in self._cooldown}
        if not alarmed:
            return []
        if self.fleet is not None:
            # fleet mode: the Eq.-5 gate must see what the FLEET saw, not
            # any single replica — replicas come and go across rolling
            # updates, and each holds only its shard of a tenant's events.
            # Gate on the merged per-stream estimators (the same view the
            # refresh itself will fit on); ``server`` may be None here.
            pol = self.fleet.policy
            merged = self.fleet.scan()
            due = {}
            for key, psi_val in alarmed.items():
                est = merged.get(key)
                if est is not None and est.ready(pol.alert_rate,
                                                 pol.rel_error, pol.z):
                    due[key] = psi_val
        else:
            due = {key: psi_val for key, psi_val in alarmed.items()
                   if self.server.calibration_ready(*key)}
        if not due:
            return []
        if self.fleet is not None:
            # fleet path: same gate/validate machinery, but on merged
            # replica sketches, published as one fenced fleet generation
            result = self.fleet.refresh_fleet(only=set(due))
        else:
            # local import: calibration.py imports this module's validators
            from repro.serving.calibration import (
                CalibrationController,
                RefreshPolicy,
            )
            cfg = self.server.config
            ctrl = CalibrationController(
                self.server, self.ref_quantiles,
                RefreshPolicy(alert_rate=cfg.refresh_alert_rate,
                              rel_error=cfg.refresh_rel_error,
                              psi_bound=self.psi_alarm))
            result = ctrl.refresh_fleet(only=set(due))
        refreshed_keys = {(r.tenant, r.predictor) for r in result.refreshed}
        for rep in result.rejected:
            self.rejections.append((rep.tenant, rep.predictor, rep.reasons))
        for key in due:
            if key not in refreshed_keys:   # rejected or vetoed: back off
                self._cooldown[key] = self.reject_cooldown
        done = []
        for rep in result.refreshed:
            key = (rep.tenant, rep.predictor)
            # refresh_fleet widens to predictor granularity, so peers of an
            # alarmed tenant may be refreshed without an alarm of their own:
            # report their current (sub-alarm) PSI
            psi_val = due.get(key)
            if psi_val is None:
                mon = self._monitors.get(key)
                psi_val = mon.current_psi() if mon is not None else 0.0
            # reset the window so the new transformation is judged fresh
            self._monitors[key] = DriftMonitor(
                self.ref_quantiles, window=self.window,
                psi_alarm=self.psi_alarm)
            done.append((rep.tenant, rep.predictor, psi_val))
        self.refreshes.extend(done)
        return done
