"""Closed-loop distribution-drift monitoring + automated calibration refresh.

Implements the paper's FIRST roadmap item (Sec. 5): "automatically trigger
background re-fitting of the Quantile Mapping, based on a closed-loop
distribution drift monitoring, ensuring stability between model retrains."

Mechanism:
  * every served (tenant, predictor) score stream feeds a rolling window;
  * drift of the *post-T^Q* distribution against the reference R is measured
    with PSI (population stability index — the industry-standard banking
    drift score) and JSD;
  * when PSI exceeds the alarm threshold AND the Eq.-5 sample-size gate for
    the raw-score stream is open, the controller re-fits the tenant's source
    quantiles from live raw scores and hot-swaps T^Q — no deployment event
    needed, closing the loop the paper leaves open.

PSI interpretation (standard): < 0.1 stable, 0.1-0.25 moderate, > 0.25 action.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np


def psi(observed: np.ndarray, expected: np.ndarray, eps: float = 1e-6) -> float:
    """Population Stability Index between two discrete distributions."""
    o = np.asarray(observed, np.float64) + eps
    e = np.asarray(expected, np.float64) + eps
    o /= o.sum()
    e /= e.sum()
    return float(np.sum((o - e) * np.log(o / e)))


def reference_bin_masses(ref_quantiles: np.ndarray, edges: np.ndarray,
                         levels: np.ndarray | None = None) -> np.ndarray:
    """Expected bin masses of the reference distribution R at ``edges``."""
    tq = np.asarray(ref_quantiles, np.float64)
    if levels is None:
        levels = np.linspace(0.0, 1.0, len(tq))
    cdf = np.interp(edges, tq, levels, left=0.0, right=1.0)
    return np.diff(cdf)


@dataclasses.dataclass
class DriftMonitor:
    """Rolling-window drift detector for one (tenant, predictor) stream."""

    ref_quantiles: np.ndarray
    window: int = 20_000
    n_bins: int = 10
    psi_alarm: float = 0.25

    def __post_init__(self) -> None:
        self._buf = np.empty(self.window, np.float64)
        self._n = 0
        self._total = 0
        self.edges = np.linspace(0.0, 1.0, self.n_bins + 1)
        self.expected = reference_bin_masses(self.ref_quantiles, self.edges)

    def update(self, served_scores: np.ndarray) -> None:
        s = np.asarray(served_scores, np.float64).ravel()
        for v in s:  # ring buffer
            self._buf[self._total % self.window] = v
            self._total += 1
        self._n = min(self._total, self.window)

    @property
    def count(self) -> int:
        return self._total

    def current_psi(self) -> float:
        if self._n < self.n_bins * 20:  # too little data to bin
            return 0.0
        counts, _ = np.histogram(self._buf[: self._n], bins=self.edges)
        return psi(counts / self._n, self.expected)

    def drifted(self) -> bool:
        return self.current_psi() > self.psi_alarm


@dataclasses.dataclass
class CalibrationRefreshController:
    """The closed loop: monitor drift -> gate on Eq. 5 -> refresh T^Q.

    Wire into a MuseServer with ``attach``; afterwards every ``score_batch``
    feeds the monitors and ``tick`` applies any due refreshes.
    """

    server: "object"              # MuseServer
    ref_quantiles: np.ndarray
    psi_alarm: float = 0.25
    window: int = 20_000
    refreshes: list[tuple[str, str, float]] = dataclasses.field(
        default_factory=list)

    def __post_init__(self) -> None:
        self._monitors: dict[tuple[str, str], DriftMonitor] = {}

    def observe(self, tenant: str, predictor: str,
                served_scores: np.ndarray) -> None:
        key = (tenant, predictor)
        mon = self._monitors.get(key)
        if mon is None:
            mon = DriftMonitor(self.ref_quantiles, window=self.window,
                               psi_alarm=self.psi_alarm)
            self._monitors[key] = mon
        mon.update(served_scores)

    def attach(self) -> None:
        """Wrap server.score_batch so served scores feed the monitors."""
        inner = self.server.score_batch

        def wrapped(requests):
            responses = inner(requests)
            by_key: dict[tuple[str, str], list[float]] = {}
            for req, resp in zip(requests, responses):
                by_key.setdefault((req.intent.tenant, resp.predictor),
                                  []).append(resp.score)
            for (tenant, pred), scores in by_key.items():
                self.observe(tenant, pred, np.asarray(scores))
            return responses

        self.server.score_batch = wrapped

    def tick(self) -> list[tuple[str, str, float]]:
        """Run one control-loop pass; returns refreshes performed."""
        done = []
        for (tenant, pred), mon in self._monitors.items():
            if not mon.drifted():
                continue
            if not self.server.calibration_ready(tenant, pred):
                continue  # Eq.-5 gate closed: not enough raw samples yet
            drift = mon.current_psi()
            qm = self.server.fit_custom_quantile_map(
                tenant, pred, self.ref_quantiles)
            self.server.swap_transformation(pred, qm)
            # reset the window so the new transformation is judged fresh
            self._monitors[(tenant, pred)] = DriftMonitor(
                self.ref_quantiles, window=self.window,
                psi_alarm=self.psi_alarm)
            done.append((tenant, pred, drift))
        self.refreshes.extend(done)
        return done
