"""Async banked dispatch engine: stage-pipelined serving (ROADMAP item).

``ServerBatcher`` (the synchronous baseline) flushes a model-group window and
runs the whole banked dispatch — expert models, transform kernel, estimator
tracking — back-to-back on the caller's thread.  On mixed-tenant traffic
that serializes two expensive phases that have no data dependency across
windows: window *N*'s expert models could execute while window *N−1*'s raw
scores run through the banked transform kernel.

:class:`AsyncDispatchEngine` is that overlap made explicit.  It drives the
three stage methods the server exposes (``run_models`` /
``apply_transforms`` / ``track``) on three single-worker stage executors:

    submit ─► MicroBatcher ─► [models] ─► [transforms] ─► [track]
                 window N+1     window N     window N−1      window N−2

Each executor is a one-thread FIFO, so windows flow through every stage in
launch order (per-key response order == submission order) while DIFFERENT
stages of consecutive windows run concurrently — XLA executions release the
GIL, so model execution genuinely overlaps the banked kernel.

Consistency model (the "epoch-safe" part):

* Every stage reads served state through ONE ``server.plane`` snapshot — a
  mutually consistent (predictors, banks, generation) triple, because every
  control-plane operation swaps the whole plane in a single reference
  assignment.  A window whose transform stage snapshotted generation *g*
  scores ALL of its rows under *g*; the next window picks up *g+1* — no
  torn reads, with or without a concurrent publisher thread.
* ``schedule_refresh`` enqueues a ``CalibrationController.refresh_fleet``
  pass on the track executor: it runs BETWEEN stage boundaries, serialized
  with the estimator-reservoir updates it reads, while the model/transform
  stages keep streaming.  Each scheduled control operation bumps the
  engine's ``epoch`` counter, stamped into the returned ``RefreshResult``.
* ``poll()`` is self-scheduling: ``start()`` arms a timer that flushes
  aged-out windows and re-arms itself — no external serving loop needed.
* ``drain()`` is a real barrier: it flushes everything pending, then pushes
  a sentinel through each stage executor in pipeline order, so on return
  every window submitted before the drain has fully cleared all three
  stages (and its futures are resolved).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable

import numpy as np

from repro.serving.batching import MicroBatcher
from repro.serving.types import ScoringRequest, ScoringResponse


@dataclasses.dataclass
class _Window:
    """One flushed model-group window travelling through the stage pipeline."""

    key: str
    requests: list[ScoringRequest]
    pred_names: list[str]                      # live predictor per row
    shadow_jobs: list[tuple[list[int], list[str]]]
    futures: list[Future | None]     # None for submit_many (drain-collected)
    routing_version: str
    t0: float = 0.0                            # dispatch start (models stage)
    raws: np.ndarray | None = None
    shadow_raws: list[np.ndarray] = dataclasses.field(default_factory=list)
    raw_cache: dict = dataclasses.field(default_factory=dict)
    error: BaseException | None = None


class AsyncDispatchEngine:
    """Event-loop driver pipelining the server's banked dispatch stages.

    Duck-types the server interface the rollout layer needs
    (``score_batch``) so a :class:`~repro.serving.rollout.Replica` can serve
    through an engine transparently.

    ``clock`` feeds the internal :class:`MicroBatcher` (injectable for
    deterministic age-flush tests); ``poll_interval_ms`` defaults to half
    the window age limit.
    """

    def __init__(self, server: Any, *, max_batch: int = 64,
                 max_wait_ms: float = 2.0,
                 poll_interval_ms: float | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 batcher: MicroBatcher | None = None,
                 adaptive_batch_cap: int | None = None,
                 facade_timeout_s: float = 120.0) -> None:
        """``adaptive_batch_cap``: enable dynamic window growth.  When the
        key's model stage is still busy with the previous window, a full
        ``max_batch`` window is NOT dispatched immediately — arrivals keep
        accumulating and the next dispatch takes the whole backlog as ONE
        window (bounded by the cap).  Arrival is decoupled from dispatch —
        the adaptive batching a synchronous batcher cannot do — so a
        backlogged pipeline amortizes per-window model/kernel dispatch
        costs instead of queueing fixed-size windows.  None = fixed-size
        windows (default).

        ``facade_timeout_s`` bounds each future wait inside the
        ``score_batch`` facade — a wedged stage surfaces as a loud timeout
        instead of hanging the caller forever, and slower lanes (the
        8-device sharded CI pass first runs uncompiled shard_map windows)
        can widen it without patching the wait sites."""
        self.server = server
        if adaptive_batch_cap is not None and adaptive_batch_cap < max_batch:
            raise ValueError("adaptive_batch_cap must be >= max_batch")
        self._facade_timeout_s = facade_timeout_s
        self._base_batch = max_batch
        self._adaptive = adaptive_batch_cap is not None
        self._cap = adaptive_batch_cap or max_batch
        self.batcher = batcher if batcher is not None else MicroBatcher(
            max_batch=self._cap, max_wait_ms=max_wait_ms, clock=clock)
        self._inflight_models: dict[str, int] = {}
        self._poll_interval_s = (
            (poll_interval_ms if poll_interval_ms is not None
             else self.batcher.max_wait_ms / 2.0) / 1000.0)
        self._lock = threading.Lock()
        # model stage: ONE single-worker executor PER model group — windows
        # of the same key stay FIFO (ordering guarantee) while independent
        # expert groups overlap on separate cores (their executables share
        # nothing).  Transform + track stay global single-workers: the bank
        # path and the estimator reservoirs are serialized by construction.
        self._models: dict[str, ThreadPoolExecutor] = {}
        self._transforms = ThreadPoolExecutor(
            1, thread_name_prefix="muse-transforms")
        self._track = ThreadPoolExecutor(1, thread_name_prefix="muse-track")
        # submit-time metadata keyed by request identity (FIFO per object,
        # so resubmitting the same request object is still well-defined);
        # the future slot is None for submit_many (drain-collected)
        self._meta: dict[int, list[tuple[Future | None, Any]]] = {}
        self._completed: list[ScoringResponse] = []
        self.completed_dropped = 0   # evictions from an un-drained buffer
        # stage failures, newest-last (windows whose futures carry the same
        # exception; submit_many windows have no futures, so this list is
        # the ONLY place a bulk-ingestion caller can see a dropped window)
        self.errors: list[tuple[str, BaseException]] = []
        # real faults raised by the anti-stall prefetch hook (bad tenant id,
        # torn store ref, ...).  Prefetch is best-effort so these never kill
        # a poll tick or a window, but silently eating them turns a real bug
        # into an invisible throughput cliff (every window pays the cold
        # stall the prefetch was meant to hide) — so they are counted here
        # and appended to ``errors``.  Expected benign races (the window
        # dispatched or the predictor undeployed between collection and
        # prefetch -> KeyError) are NOT counted.
        self.prefetch_errors = 0
        # poll-tick failures (exceptions escaping poll(); the tick chain
        # survives them — see _poll_tick) and track-stage failures (the
        # stage must never kill serving, but a recurring fault would
        # otherwise be an invisible calibration-freshness cliff)
        self.tick_errors = 0
        self.track_errors = 0
        self.window_log: list[dict] = []       # per-window dispatch records
        self._epoch = 0
        self._running = False
        self._closed = False
        self._poll_timer: threading.Timer | None = None
        # tiered-store anti-stall prefetch (serving/tiering.py): servers
        # that page cold bank rows from host memory expose
        # ``prefetch_transforms``; the engine stages pending windows' rows
        # into the victim cache before their transform stage dispatches
        self._prefetchable = bool(getattr(server, "prefetch_enabled", False))

    # ------------------------------------------------------------- lifecycle
    @property
    def epoch(self) -> int:
        """Count of control-plane operations applied at stage boundaries."""
        return self._epoch

    @property
    def pending_count(self) -> int:
        return self.batcher.pending_count

    def start(self) -> "AsyncDispatchEngine":
        """Arm the self-scheduling poll timer (idempotent)."""
        with self._lock:
            if self._running or self._closed:
                return self
            self._running = True
        self._arm_poll()
        return self

    def _arm_poll(self) -> None:
        # armed UNDER the lock: checking _running/_closed outside it raced
        # with close() — close could cancel the already-fired timer and
        # then lose to this re-arm, leaving a live timer polling into
        # shut-down executors.  Holding the lock across check + start makes
        # cancel-then-never-rearm atomic with close's _closed flip.
        with self._lock:
            if not self._running or self._closed:
                return
            t = threading.Timer(self._poll_interval_s, self._poll_tick)
            t.daemon = True
            self._poll_timer = t
            t.start()

    def _poll_tick(self) -> None:
        # try/finally: an exception escaping poll() must not silently kill
        # the re-arm chain (the engine would stop flushing aged windows
        # with no visible signal) — it is counted instead
        try:
            self.poll()
        except BaseException as e:  # noqa: BLE001 — surface via metric
            with self._lock:
                self.tick_errors += 1
                self.errors.append(("poll", e))
                if len(self.errors) > 256:
                    del self.errors[:128]
        finally:
            self._arm_poll()     # poll reschedules itself

    def close(self, timeout: float | None = 30.0) -> list[ScoringResponse]:
        """Stop polling, drain every in-flight window, shut the stages down.

        Returns the responses completed since the last ``take_completed``.
        """
        with self._lock:
            if self._closed:
                return []
            self._closed = True
            self._running = False
            if self._poll_timer is not None:
                self._poll_timer.cancel()
        out = self.drain(timeout=timeout)
        for pool in self._models.values():
            pool.shutdown(wait=True)
        self._transforms.shutdown(wait=True)
        self._track.shutdown(wait=True)
        return out

    def __enter__(self) -> "AsyncDispatchEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- intake
    def submit(self, request: ScoringRequest) -> Future:
        """Enqueue one request; returns a Future[ScoringResponse].

        The future resolves when the request's window clears the transform
        stage (responses never wait on estimator tracking).
        """
        fut: Future = Future()
        with self._lock:
            if self._closed:
                raise RuntimeError("engine is closed")
            res = self.server.routing.resolve(request.intent)
            key = self.server.group_key(res)
            self._meta.setdefault(id(request), []).append((fut, res))
            batch = self.batcher.add(key, request) or self._take_ready(key)
            if batch:
                self._launch_locked(self._build_window(key, batch))
        return fut

    def _take_ready(self, key: str) -> list[ScoringRequest]:
        """Adaptive dispatch decision (caller holds the lock): flush once
        the base window size is reached AND the key's model stage is idle;
        while it is busy, keep accumulating (the batcher caps the growth).
        Window sizes are quantized to base·2^k ≤ cap so the serving shapes
        stay bounded (one XLA specialization per growth step, not one per
        arbitrary backlog length)."""
        if not self._adaptive or self._inflight_models.get(key):
            return []
        n = self.batcher.pending_for(key)
        if n < self._base_batch:
            return []
        size = self._base_batch
        while size * 2 <= min(n, self._cap):
            size *= 2
        return self.batcher.take(key, size)

    def submit_many(self, requests: list[ScoringRequest]) -> None:
        """Bulk ingestion: enqueue a request stream without per-request
        futures (responses are collected via ``drain``/``take_completed``).

        One lock acquisition and no Future/metadata churn per request —
        the per-request Python of ``submit`` is what contends with the
        stage threads at high offered load.
        """
        it = iter(requests)
        while True:
            chunk = list(itertools.islice(it, 64))
            if not chunk:
                break
            # chunked lock scope: the stages start consuming while the rest
            # of the stream is still being enqueued
            with self._lock:
                if self._closed:
                    raise RuntimeError("engine is closed")
                resolve = self.server.routing.resolve
                group_key = self.server.group_key
                for request in chunk:
                    res = resolve(request.intent)
                    key = group_key(res)
                    self._meta.setdefault(id(request), []).append((None, res))
                    batch = self.batcher.add(key, request) \
                        or self._take_ready(key)
                    if batch:
                        self._launch_locked(self._build_window(key, batch))

    def poll(self) -> int:
        """Flush aged-out windows into the pipeline; returns windows launched.

        Safe to call manually, but ``start()`` makes it self-scheduling."""
        pending: list[tuple[str, list[str]]] = []
        with self._lock:
            if self._closed:
                # a tick that fired just before close() finished must not
                # launch windows into draining/shut-down executors
                return 0
            n = 0
            for key, batch in self.batcher.expired():
                self._launch_locked(self._build_window(key, batch))
                n += 1
            if self._prefetchable:
                # still-accumulating windows: collect their live predictor
                # names under the lock, prefetch OUTSIDE it (a host->device
                # row copy must not block submitters)
                for key in self.batcher.pending_keys():
                    names = []
                    for req in self.batcher.peek(key):
                        meta = self._meta.get(id(req))
                        if meta:
                            names.append(meta[0][1].live)
                    if names:
                        pending.append((key, names))
        for key, names in pending:
            try:
                # create=False: speculative pending contents only warm
                # stores that already exist (a window may never dispatch
                # with exactly this predictor subset)
                self.server.prefetch_transforms(names, create=False)
            except KeyError:
                # expected race: the window dispatched / the predictor was
                # undeployed between the locked collection above and this
                # call — the names no longer resolve; nothing to warm
                continue
            except Exception as e:  # noqa: BLE001 — must never kill poll
                self._note_prefetch_error(key, e)
        return n

    def flush(self) -> int:
        """Force every pending window (full or not) into the pipeline."""
        with self._lock:
            n = 0
            for key, batch in self.batcher.flush_all():
                self._launch_locked(self._build_window(key, batch))
                n += 1
        return n

    def _launch_locked(self, win: _Window) -> None:
        """Enqueue a window on its key's model lane (caller holds the lock).

        Take-from-batcher and pool-enqueue happen under ONE lock hold: two
        launcher threads (submitter, poll timer, backlog pickup) can never
        invert same-key windows, so the per-key FIFO guarantee is real."""
        pool = self._models.get(win.key)
        if pool is None:
            pool = self._models.setdefault(win.key, ThreadPoolExecutor(
                1, thread_name_prefix=f"muse-models-{len(self._models)}"))
        self._inflight_models[win.key] = \
            self._inflight_models.get(win.key, 0) + 1
        pool.submit(self._model_stage, win)

    def drain(self, timeout: float | None = 30.0) -> list[ScoringResponse]:
        """Flush + barrier: block until all prior windows clear every stage.

        The stage executors are single-worker FIFOs and each stage enqueues
        the next, so sentinels pushed in pipeline order prove quiescence.
        Returns (and clears) the completed-response buffer.
        """
        self.flush()
        pools = list(self._models.values()) + [self._transforms, self._track]
        for pool in pools:
            pool.submit(lambda: None).result(timeout=timeout)
        return self.take_completed()

    def take_completed(self) -> list[ScoringResponse]:
        """Pop responses completed so far (transform-stage completion order)."""
        with self._lock:
            out = self._completed
            self._completed = []
        return out

    def score_batch(self, requests: list[ScoringRequest]
                    ) -> list[ScoringResponse]:
        """Synchronous facade (Replica duck-type): submit, flush, await.

        Windows formed from ``requests`` still pipeline across the stage
        executors; the call returns when every response future resolves.
        NOTE: the flush also releases other callers' partial windows.
        """
        futs = [self.submit(r) for r in requests]
        self.flush()
        responses = [f.result(timeout=self._facade_timeout_s) for f in futs]
        # this call consumed its responses via futures — drop them from the
        # drain buffer, or a long-lived facade-only replica leaks memory
        ids = {r.request_id for r in responses}
        with self._lock:
            self._completed = [r for r in self._completed
                               if r.request_id not in ids]
        return responses

    # ---------------------------------------------------------- control ops
    def schedule_control(self, fn: Callable[[], Any]) -> Future:
        """Run ``fn`` at the next stage boundary; returns Future[fn()].

        The generic control-plane entry point: ``fn`` executes on the track
        executor, serialized with estimator-reservoir updates and between
        windows, while the model/transform stages keep streaming.  Each
        scheduled operation bumps the engine ``epoch``.  The fleet
        calibration plane uses this to land fenced
        ``publish_quantile_maps(..., generation=...)`` swaps on
        engine-backed replicas so no in-flight window straddles the swap.
        """
        fut: Future = Future()

        def op() -> None:
            try:
                with self._lock:
                    self._epoch += 1
                fut.set_result(fn())
            except BaseException as e:  # noqa: BLE001 — surface via future
                fut.set_exception(e)

        self._track.submit(op)
        return fut

    def schedule_refresh(self, controller: Any,
                         only: "set[tuple[str, str]] | None" = None) -> Future:
        """Schedule ``controller.refresh_fleet`` at the next stage boundary.

        A :meth:`schedule_control` wrapper that stamps the engine epoch into
        the refresh: serialized with the estimator-reservoir updates the
        refit reads, while model/transform stages keep streaming.  In-flight
        windows finish on their snapshotted generation; the next transform
        stage picks up the published one.  Returns a Future[RefreshResult].
        """
        fut: Future = Future()

        def op() -> None:
            try:
                with self._lock:
                    self._epoch += 1
                    epoch = self._epoch
                fut.set_result(controller.refresh_fleet(only, epoch=epoch))
            except BaseException as e:  # noqa: BLE001 — surface via future
                fut.set_exception(e)

        self._track.submit(op)
        return fut

    # --------------------------------------------------------------- stages
    def _build_window(self, key: str, batch: list[ScoringRequest]) -> _Window:
        """Assemble a window from a flushed batch (caller holds the lock)."""
        futures, pred_names = [], []
        shadow_groups: dict[tuple[str, ...], tuple[list[int], list[str]]] = {}
        predictors = self.server.predictors
        for i, req in enumerate(batch):
            fut, res = self._meta[id(req)].pop(0)
            if not self._meta[id(req)]:
                del self._meta[id(req)]
            futures.append(fut)
            pred_names.append(res.live)
            for s in res.shadows:
                gkey = predictors[s].model_names
                idxs, names = shadow_groups.setdefault(gkey, ([], []))
                idxs.append(i)
                names.append(s)
        return _Window(
            key=key, requests=batch, pred_names=pred_names,
            shadow_jobs=list(shadow_groups.values()), futures=futures,
            routing_version=self.server.routing.version)

    def _note_prefetch_error(self, key: str, exc: BaseException) -> None:
        """Record a non-race prefetch fault: the window still dispatches
        (it just pays the cold-miss stall the prefetch would have hidden),
        so nothing fails a future — but the fault is counted and kept in
        ``errors`` so a recurring bug is visible instead of a silent
        throughput cliff."""
        with self._lock:
            self.prefetch_errors += 1
            self.errors.append((key, exc))
            if len(self.errors) > 256:
                del self.errors[:128]

    def _fail(self, win: _Window, exc: BaseException) -> None:
        with self._lock:
            self.errors.append((win.key, exc))
            if len(self.errors) > 256:
                del self.errors[:128]
        for fut in win.futures:
            if fut is not None and not fut.done():
                fut.set_exception(exc)

    def _model_stage(self, win: _Window) -> None:
        """Stage 1: expert-model execution (live + shadow groups)."""
        try:
            win.t0 = time.perf_counter()
            plane = self.server.plane           # per-STAGE snapshot
            idxs = list(range(len(win.requests)))
            win.raws = self.server.run_models(
                win.requests, idxs, win.pred_names, win.raw_cache, plane)
            for s_idxs, s_names in win.shadow_jobs:
                win.shadow_raws.append(self.server.run_models(
                    win.requests, s_idxs, s_names, win.raw_cache, plane))
            if self._prefetchable:
                # this window's transform stage is next: stage its cold bank
                # rows NOW, overlapped with the previous window's kernel
                # (create=True — the names-tuple is exactly what the
                # transform stage will dispatch with)
                try:
                    self.server.prefetch_transforms(
                        win.pred_names, plane, create=True)
                except KeyError:
                    # expected race: a predictor in this window was
                    # undeployed after the stage-time plane snapshot —
                    # the transform stage below resolves against a fresh
                    # plane and fails (or serves) on its own terms
                    pass
                except Exception as e:  # noqa: BLE001 — best-effort warm-up
                    self._note_prefetch_error(win.key, e)
        except BaseException as e:  # noqa: BLE001 — deliver via futures
            win.error = e
        self._transforms.submit(self._transform_stage, win)
        # adaptive backlog pickup: the model lane is free again — take the
        # (quantized) backlog accumulated for this key as ONE window
        with self._lock:
            self._inflight_models[win.key] -= 1
            if not self._closed:
                batch = self._take_ready(win.key)
                if batch:
                    self._launch_locked(self._build_window(win.key, batch))

    def _transform_stage(self, win: _Window) -> None:
        """Stage 2: banked kernel + response delivery (live + shadows)."""
        if win.error is not None:
            self._fail(win, win.error)
            return
        try:
            plane = self.server.plane           # fresh per-STAGE snapshot
            scores, bank, tenant_idx = self.server.apply_transforms(
                win.raws, win.pred_names, plane)
            latency_ms = (time.perf_counter() - win.t0) * 1000.0
            responses = self.server.build_responses(
                win.requests, list(range(len(win.requests))), win.pred_names,
                scores, win.raws, bank, win.routing_version, latency_ms)
            for (s_idxs, s_names), s_raws in zip(win.shadow_jobs,
                                                 win.shadow_raws):
                s_scores, _, _ = self.server.apply_transforms(
                    s_raws, s_names, plane)
                self.server.write_shadow_records(
                    win.requests, s_idxs, s_names, s_scores, s_raws,
                    win.routing_version)
            self.server.bump_metric("requests", len(win.requests))
            with self._lock:
                self._completed.extend(responses)
                # bound an un-drained buffer (a futures-only caller that
                # never drains must not leak); evictions are counted
                if len(self._completed) > 65536:
                    drop = len(self._completed) - 65536
                    del self._completed[:drop]
                    self.completed_dropped += drop
                self.window_log.append({
                    "key": win.key, "size": len(win.requests),
                    "latency_ms": latency_ms,
                    "bank_generation": bank.generation})
                if len(self.window_log) > 8192:  # bound long-running growth
                    del self.window_log[:4096]
            for fut, resp in zip(win.futures, responses):
                if fut is not None:
                    fut.set_result(resp)
            self._track.submit(self._track_stage, win, bank, tenant_idx)
        except BaseException as e:  # noqa: BLE001 — deliver via futures
            self._fail(win, e)

    def _track_stage(self, win: _Window, bank, tenant_idx) -> None:
        """Stage 3: estimator-reservoir updates (a stage behind responses)."""
        try:
            self.server.track(win.requests, list(range(len(win.requests))),
                              win.pred_names, win.raws, bank, tenant_idx)
        except BaseException as e:  # noqa: BLE001 — must never kill serving
            # counted + kept in errors: a recurring track fault silently
            # starves calibration of samples (the refresh gate never opens)
            with self._lock:
                self.track_errors += 1
                self.errors.append((win.key, e))
                if len(self.errors) > 256:
                    del self.errors[:128]
