"""Rolling deployments (paper Sec. 2.5.2 / Fig. 3 / Fig. 5).

Simulates the Kubernetes rolling update MUSE relies on, with the properties
that matter for the paper's claims:

  * replicas are versioned, stateless scoring instances (routing table +
    transformation pipelines); model containers live in a SHARED pool —
    updating transformations re-provisions zero models;
  * maxSurge=1 / maxUnavailable=0 semantics: a new replica is created, warmed
    up (real XLA compilation — the JVM-JIT analogue), and only then marked
    ready; an old replica is drained after;
  * a round-robin load balancer serves live traffic continuously during the
    update, recording per-request latency so the Fig.-5 "no SLO violation
    during rollout" claim is measurable;
  * generation-fenced session routing: ``ReplicaSet.dispatch(stream=...)``
    pins each client stream to replicas at or above the stream's observed
    ``bank_generation`` high-water mark, and ``fleet_generation()`` audits
    per-replica divergence — together with the fleet calibration plane
    (``calibration.FleetCalibrationController``, wired in via
    ``RollingUpdate(fleet_calibration=...)``) this makes generation stamps
    fleet-monotone per stream even while replicas are mid-publish or
    straggling.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Iterator

import numpy as np

from repro.serving.types import ScoringRequest, ScoringResponse
from repro.serving import warmup as warmup_mod


@dataclasses.dataclass
class Replica:
    replica_id: int
    server: "object"            # MuseServer (duck-typed)
    version: str
    ready: bool = False
    warmup_seconds: float = 0.0
    served: int = 0
    # optional AsyncDispatchEngine serving this replica's traffic: requests
    # route through its pipelined stage path instead of the synchronous
    # score_batch (duck-typed: needs score_batch; close() used on drain)
    engine: "object | None" = None

    def serve(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        self.served += len(requests)
        target = self.engine if self.engine is not None else self.server
        return target.score_batch(requests)

    @property
    def bank_generation(self) -> int:
        """Transform-bank generation this replica currently serves."""
        return self.server.bank_generation


@dataclasses.dataclass(frozen=True)
class FleetGenerationAudit:
    """Snapshot of every ready replica's served bank generation.

    ``divergent`` is the condition the fleet calibration plane exists to
    prevent: two ready replicas answering the same load balancer with
    different generations, so a client stream bouncing between them can
    watch its ``bank_generation`` stamp go BACKWARDS mid-conversation.
    """

    per_replica: tuple[tuple[int, int], ...]   # (replica_id, bank_generation)
    min_generation: int
    max_generation: int

    @property
    def divergent(self) -> bool:
        return self.min_generation != self.max_generation


class ReplicaSet:
    """Round-robin load balancer over ready replicas.

    ``dispatch(..., stream=...)`` adds generation-fenced session routing:
    the set remembers the highest ``bank_generation`` each client stream
    has observed and only routes that stream to replicas serving at or
    above it, so per-stream generation stamps are monotone across the
    whole fleet even while a fleet publish (or a straggler) leaves
    replicas temporarily divergent.

    Stream floors are BOUNDED state: entries idle longer than
    ``stream_floor_ttl`` seconds are evicted, and when the table exceeds
    ``max_tracked_streams`` the least-recently-dispatched entries go first.
    Within the TTL a revived stream keeps its floor (still refuses
    rollback routing); after it, the stream re-fences from scratch — by
    then every in-fence replica has long converged past the old floor, so
    forgetting it is safe, whereas remembering every stream id ever seen
    is an unbounded leak on a long-lived balancer.
    """

    def __init__(self, replicas: list[Replica],
                 *, stream_floor_ttl: float = 3600.0,
                 max_tracked_streams: int = 100_000,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.replicas = replicas
        self._rr = itertools.count()
        self.stream_floor_ttl = stream_floor_ttl
        self.max_tracked_streams = max_tracked_streams
        self._clock = clock
        # per-stream (generation high-water mark, last-dispatch time);
        # insertion order is LRU order — touches re-insert (dict preserves
        # insertion order, so the first key is always the coldest stream)
        self._stream_floor: dict[str, tuple[int, float]] = {}

    # ------------------------------------------------------- floor eviction
    def _touch_floor(self, stream: str, floor: int) -> None:
        self._stream_floor.pop(stream, None)
        self._stream_floor[stream] = (floor, self._clock())
        self._evict_floors()

    def _evict_floors(self) -> None:
        now = self._clock()
        ttl = self.stream_floor_ttl
        expired = [s for s, (_, seen) in self._stream_floor.items()
                   if now - seen > ttl]
        for s in expired:
            del self._stream_floor[s]
        while len(self._stream_floor) > self.max_tracked_streams:
            # LRU: the first key is the least recently dispatched stream
            self._stream_floor.pop(next(iter(self._stream_floor)))

    @property
    def ready_replicas(self) -> list[Replica]:
        return [r for r in self.replicas if r.ready]

    @property
    def pod_count(self) -> int:
        return len(self.replicas)

    def fleet_generation(self) -> FleetGenerationAudit:
        """Audit helper: which generation is each ready replica serving?

        Before the fleet calibration plane, independent per-replica
        refreshes made ``audit.divergent`` the steady state during any
        update; under ``FleetCalibrationController`` the fleet converges to
        one generation per pass (stragglers excepted — and those are
        exactly what the fenced ``dispatch`` routes around).
        """
        reps = self.ready_replicas or self.replicas
        gens = tuple((r.replica_id, r.bank_generation) for r in reps)
        values = [g for _, g in gens] or [-1]
        return FleetGenerationAudit(gens, min(values), max(values))

    def stream_floor(self, stream: str) -> int:
        """Highest generation the given client stream has observed (-1 if
        the stream has never dispatched, or its floor entry expired)."""
        entry = self._stream_floor.get(stream)
        if entry is None:
            return -1
        floor, seen = entry
        if self._clock() - seen > self.stream_floor_ttl:
            return -1
        return floor

    def tracked_streams(self) -> int:
        """Number of stream-floor entries currently held (bounded by
        ``max_tracked_streams``; TTL-expired entries may still count until
        the next dispatch sweeps them)."""
        return len(self._stream_floor)

    def dispatch(self, requests: list[ScoringRequest],
                 stream: str | None = None) -> list[ScoringResponse]:
        """Route one batch to a ready replica (round-robin).

        With ``stream``, routing is generation-fenced: only replicas whose
        ``bank_generation`` is at or above the stream's high-water mark are
        eligible, and the mark advances to the highest generation stamped
        on the responses.  A stream that saw generation *g* can therefore
        never be answered under *g' < g*, no matter how divergent the
        fleet momentarily is.  Raises if no ready replica satisfies the
        floor (every up-to-date replica gone — an availability violation,
        not a silent rollback).
        """
        ready = self.ready_replicas
        if not ready:
            raise RuntimeError("no ready replicas — availability violated")
        if stream is None:
            replica = ready[next(self._rr) % len(ready)]
            return replica.serve(requests)
        floor = self.stream_floor(stream)
        eligible = [r for r in ready if r.bank_generation >= floor]
        if not eligible:
            raise RuntimeError(
                f"no ready replica at generation >= {floor} for stream "
                f"{stream!r} — refusing to serve a generation rollback")
        replica = eligible[next(self._rr) % len(eligible)]
        responses = replica.serve(requests)
        seen = max((r.bank_generation for r in responses), default=floor)
        self._touch_floor(stream, max(seen, floor))
        return responses


@dataclasses.dataclass
class RolloutEvent:
    t: float
    kind: str        # "surge" | "ready" | "drain" | "done"
    replica_id: int
    pod_count: int


class RollingUpdate:
    """maxSurge=1, maxUnavailable=0 rolling replacement of all replicas."""

    def __init__(
        self,
        replica_set: ReplicaSet,
        make_server: Callable[[], "object"],
        new_version: str,
        *,
        schema_dim: int,
        warmup_batch_sizes: tuple[int, ...] = (1, 8, 64),
        calibration_factory: Callable[["object"], "object"] | None = None,
        engine_factory: Callable[["object"], "object"] | None = None,
        fleet_calibration: "object | None" = None,
    ) -> None:
        """``calibration_factory``: optional ``server -> CalibrationController``
        hook.  When set, every promoted replica triggers a fleet calibration
        refresh right after its warm-up — the paper's Sec.-3.1 lifecycle
        where a model promotion automatically refits T^Q from the live
        streams the replica carries (no out-of-band operator step).

        ``engine_factory``: optional ``server -> AsyncDispatchEngine`` hook
        (must return a STARTED engine).  When set, every promoted replica
        serves through its own pipelined engine, the promotion refresh is
        scheduled at a stage boundary via ``engine.schedule_refresh``
        (never a quiesce), and a drained replica's engine is closed — its
        barrier guarantees no in-flight window is dropped.

        ``fleet_calibration``: optional
        ``calibration.FleetCalibrationController`` bound to this replica
        set.  When set it REPLACES the per-replica ``calibration_factory``
        path: a surged replica is generation-aligned (``align``, an empty
        fenced publish) right after warm-up so fenced session routing can
        use it immediately, and the promotion refresh is ONE fleet pass —
        pull + merge every replica's estimator sketches, fit once on the
        merged view, broadcast under a single fenced fleet generation —
        instead of N divergent per-replica publishes."""
        self.rs = replica_set
        self.make_server = make_server
        self.new_version = new_version
        self.schema_dim = schema_dim
        self.warmup_batch_sizes = warmup_batch_sizes
        self.calibration_factory = calibration_factory
        self.engine_factory = engine_factory
        self.fleet_calibration = fleet_calibration
        self.refreshes: list["object"] = []   # RefreshResult per promotion
        self._next_id = max((r.replica_id for r in replica_set.replicas),
                            default=-1) + 1
        self.events: list[RolloutEvent] = []
        self._t0 = time.perf_counter()

    def _log(self, kind: str, rid: int) -> None:
        self.events.append(RolloutEvent(
            t=time.perf_counter() - self._t0, kind=kind, replica_id=rid,
            pod_count=self.rs.pod_count,
        ))

    def steps(self) -> Iterator[str]:
        """Generator: yields after each state transition so the driver can
        interleave live traffic between transitions (Fig. 5 measurement)."""
        old = [r for r in self.rs.replicas]
        for victim in old:
            # surge: create the new replica (not yet ready)
            new = Replica(self._next_id, self.make_server(), self.new_version)
            self._next_id += 1
            if self.engine_factory is not None:
                new.engine = self.engine_factory(new.server)
            self.rs.replicas.append(new)
            self._log("surge", new.replica_id)
            yield "surged"

            # warm-up: compile every predictor at serving shapes BEFORE ready
            t0 = time.perf_counter()
            warmup_mod.warm_up(new.server, self.schema_dim,
                               batch_sizes=self.warmup_batch_sizes)
            new.warmup_seconds = time.perf_counter() - t0
            # tiered topology: a surged replica starts with EMPTY tiers (the
            # warm-up calls predictors directly, never the banked path) —
            # adopt the victim replica's hotness/admission state so the new
            # replica's first windows hit a promoted hot set instead of
            # paging its whole working set through the victim cache.
            # Hotness snapshots are GLOBAL-row-indexed, so this also warms
            # across topologies (single-tier victim -> tiered-over-sharded
            # surge and vice versa; see ShardedTieredBankStore).
            if hasattr(new.server, "warm_tiers_from"):
                new.server.warm_tiers_from(victim.server)
            if self.fleet_calibration is not None:
                # generation-align the fresh replica BEFORE it takes traffic:
                # an empty fenced publish fast-forwards its banks to the
                # fleet generation, so fenced session routing never has to
                # quarantine the newest replica behind old streams' floors.
                self.fleet_calibration.align(new)
            new.ready = True
            self._log("ready", new.replica_id)
            yield "warmed"

            # model promotion -> automatic fleet calibration refresh: refit
            # every ready (tenant, predictor) stream and publish one new
            # transform-bank generation atomically before the old replica
            # drains (clients never see the un-refreshed new model for
            # longer than one warm-up window)
            if self.fleet_calibration is not None:
                # ONE fleet pass replaces N per-replica refreshes: merged
                # sketches from every replica (new one included), one fit,
                # one fenced broadcast — no divergent generations behind
                # the load balancer while the rollout is mid-flight.
                self.refreshes.append(self.fleet_calibration.refresh_fleet())
                self._log("calibrate", new.replica_id)
                yield "calibrated"
            elif self.calibration_factory is not None:
                ctrl = self.calibration_factory(new.server)
                if new.engine is not None \
                        and hasattr(new.engine, "schedule_refresh"):
                    # refresh lands at a stage boundary of the live engine:
                    # in-flight windows finish on their snapshotted
                    # generation, the next transform stage picks up the new.
                    # Bounded wait: a wedged track executor must abort the
                    # promotion loudly, not hang the fleet mid-surge.
                    self.refreshes.append(
                        new.engine.schedule_refresh(ctrl).result(
                            timeout=300.0))
                else:
                    self.refreshes.append(ctrl.refresh_fleet())
                self._log("calibrate", new.replica_id)
                yield "calibrated"

            # drain the old replica (maxUnavailable=0: only after new is ready)
            victim.ready = False
            self.rs.replicas.remove(victim)
            if victim.engine is not None and hasattr(victim.engine, "close"):
                victim.engine.close()   # barrier: no in-flight window dropped
            self._log("drain", victim.replica_id)
            yield "drained"
        self._log("done", -1)

    def run_with_traffic(
        self,
        traffic: Iterator[list[ScoringRequest]],
        *,
        batches_per_transition: int = 5,
    ) -> list[dict]:
        """Drive the rollout while continuously serving traffic.

        Returns a timeline of {t, pod_count, ready_count, latency_ms, version}
        samples — the Fig.-5 reproduction data.
        """
        timeline: list[dict] = []

        def serve_some() -> None:
            for _ in range(batches_per_transition):
                reqs = next(traffic)
                t0 = time.perf_counter()
                resp = self.rs.dispatch(reqs)
                lat = (time.perf_counter() - t0) * 1000.0
                timeline.append({
                    "t": time.perf_counter() - self._t0,
                    "pod_count": self.rs.pod_count,
                    "ready_count": len(self.rs.ready_replicas),
                    "latency_ms": lat,
                    "version": resp[0].routing_version,
                    "batch": len(reqs),
                })

        serve_some()
        for _ in self.steps():
            serve_some()
        serve_some()
        return timeline
