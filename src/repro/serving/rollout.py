"""Rolling deployments (paper Sec. 2.5.2 / Fig. 3 / Fig. 5).

Simulates the Kubernetes rolling update MUSE relies on, with the properties
that matter for the paper's claims:

  * replicas are versioned, stateless scoring instances (routing table +
    transformation pipelines); model containers live in a SHARED pool —
    updating transformations re-provisions zero models;
  * maxSurge=1 / maxUnavailable=0 semantics: a new replica is created, warmed
    up (real XLA compilation — the JVM-JIT analogue), and only then marked
    ready; an old replica is drained after;
  * a round-robin load balancer serves live traffic continuously during the
    update, recording per-request latency so the Fig.-5 "no SLO violation
    during rollout" claim is measurable.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Iterator

import numpy as np

from repro.serving.types import ScoringRequest, ScoringResponse
from repro.serving import warmup as warmup_mod


@dataclasses.dataclass
class Replica:
    replica_id: int
    server: "object"            # MuseServer (duck-typed)
    version: str
    ready: bool = False
    warmup_seconds: float = 0.0
    served: int = 0
    # optional AsyncDispatchEngine serving this replica's traffic: requests
    # route through its pipelined stage path instead of the synchronous
    # score_batch (duck-typed: needs score_batch; close() used on drain)
    engine: "object | None" = None

    def serve(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        self.served += len(requests)
        target = self.engine if self.engine is not None else self.server
        return target.score_batch(requests)


class ReplicaSet:
    """Round-robin load balancer over ready replicas."""

    def __init__(self, replicas: list[Replica]) -> None:
        self.replicas = replicas
        self._rr = itertools.count()

    @property
    def ready_replicas(self) -> list[Replica]:
        return [r for r in self.replicas if r.ready]

    @property
    def pod_count(self) -> int:
        return len(self.replicas)

    def dispatch(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        ready = self.ready_replicas
        if not ready:
            raise RuntimeError("no ready replicas — availability violated")
        replica = ready[next(self._rr) % len(ready)]
        return replica.serve(requests)


@dataclasses.dataclass
class RolloutEvent:
    t: float
    kind: str        # "surge" | "ready" | "drain" | "done"
    replica_id: int
    pod_count: int


class RollingUpdate:
    """maxSurge=1, maxUnavailable=0 rolling replacement of all replicas."""

    def __init__(
        self,
        replica_set: ReplicaSet,
        make_server: Callable[[], "object"],
        new_version: str,
        *,
        schema_dim: int,
        warmup_batch_sizes: tuple[int, ...] = (1, 8, 64),
        calibration_factory: Callable[["object"], "object"] | None = None,
        engine_factory: Callable[["object"], "object"] | None = None,
    ) -> None:
        """``calibration_factory``: optional ``server -> CalibrationController``
        hook.  When set, every promoted replica triggers a fleet calibration
        refresh right after its warm-up — the paper's Sec.-3.1 lifecycle
        where a model promotion automatically refits T^Q from the live
        streams the replica carries (no out-of-band operator step).

        ``engine_factory``: optional ``server -> AsyncDispatchEngine`` hook
        (must return a STARTED engine).  When set, every promoted replica
        serves through its own pipelined engine, the promotion refresh is
        scheduled at a stage boundary via ``engine.schedule_refresh``
        (never a quiesce), and a drained replica's engine is closed — its
        barrier guarantees no in-flight window is dropped."""
        self.rs = replica_set
        self.make_server = make_server
        self.new_version = new_version
        self.schema_dim = schema_dim
        self.warmup_batch_sizes = warmup_batch_sizes
        self.calibration_factory = calibration_factory
        self.engine_factory = engine_factory
        self.refreshes: list["object"] = []   # RefreshResult per promotion
        self._next_id = max((r.replica_id for r in replica_set.replicas),
                            default=-1) + 1
        self.events: list[RolloutEvent] = []
        self._t0 = time.perf_counter()

    def _log(self, kind: str, rid: int) -> None:
        self.events.append(RolloutEvent(
            t=time.perf_counter() - self._t0, kind=kind, replica_id=rid,
            pod_count=self.rs.pod_count,
        ))

    def steps(self) -> Iterator[str]:
        """Generator: yields after each state transition so the driver can
        interleave live traffic between transitions (Fig. 5 measurement)."""
        old = [r for r in self.rs.replicas]
        for victim in old:
            # surge: create the new replica (not yet ready)
            new = Replica(self._next_id, self.make_server(), self.new_version)
            self._next_id += 1
            if self.engine_factory is not None:
                new.engine = self.engine_factory(new.server)
            self.rs.replicas.append(new)
            self._log("surge", new.replica_id)
            yield "surged"

            # warm-up: compile every predictor at serving shapes BEFORE ready
            t0 = time.perf_counter()
            warmup_mod.warm_up(new.server, self.schema_dim,
                               batch_sizes=self.warmup_batch_sizes)
            new.warmup_seconds = time.perf_counter() - t0
            new.ready = True
            self._log("ready", new.replica_id)
            yield "warmed"

            # model promotion -> automatic fleet calibration refresh: refit
            # every ready (tenant, predictor) stream and publish one new
            # transform-bank generation atomically before the old replica
            # drains (clients never see the un-refreshed new model for
            # longer than one warm-up window)
            if self.calibration_factory is not None:
                ctrl = self.calibration_factory(new.server)
                if new.engine is not None \
                        and hasattr(new.engine, "schedule_refresh"):
                    # refresh lands at a stage boundary of the live engine:
                    # in-flight windows finish on their snapshotted
                    # generation, the next transform stage picks up the new.
                    # Bounded wait: a wedged track executor must abort the
                    # promotion loudly, not hang the fleet mid-surge.
                    self.refreshes.append(
                        new.engine.schedule_refresh(ctrl).result(
                            timeout=300.0))
                else:
                    self.refreshes.append(ctrl.refresh_fleet())
                self._log("calibrate", new.replica_id)
                yield "calibrated"

            # drain the old replica (maxUnavailable=0: only after new is ready)
            victim.ready = False
            self.rs.replicas.remove(victim)
            if victim.engine is not None and hasattr(victim.engine, "close"):
                victim.engine.close()   # barrier: no in-flight window dropped
            self._log("drain", victim.replica_id)
            yield "drained"
        self._log("done", -1)

    def run_with_traffic(
        self,
        traffic: Iterator[list[ScoringRequest]],
        *,
        batches_per_transition: int = 5,
    ) -> list[dict]:
        """Drive the rollout while continuously serving traffic.

        Returns a timeline of {t, pod_count, ready_count, latency_ms, version}
        samples — the Fig.-5 reproduction data.
        """
        timeline: list[dict] = []

        def serve_some() -> None:
            for _ in range(batches_per_transition):
                reqs = next(traffic)
                t0 = time.perf_counter()
                resp = self.rs.dispatch(reqs)
                lat = (time.perf_counter() - t0) * 1000.0
                timeline.append({
                    "t": time.perf_counter() - self._t0,
                    "pod_count": self.rs.pod_count,
                    "ready_count": len(self.rs.ready_replicas),
                    "latency_ms": lat,
                    "version": resp[0].routing_version,
                    "batch": len(reqs),
                })

        serve_some()
        for _ in self.steps():
            serve_some()
        serve_some()
        return timeline
