"""MuseServer: the scoring data plane (paper Fig. 1).

Request path:  intent -> routing (live + shadows) -> feature enrichment ->
expert models -> T^C -> A -> T^Q -> response; shadow scores go to the sink.

A mixed-tenant micro-batch is grouped by *model group* (the predictor's
expert-model set): one model executable call produces raw scores for the
whole group, and one tenant-indexed banked kernel dispatch
(:func:`repro.kernels.ops.score_pipeline_banked`) applies every predictor's
T^C/A/T^Q in a single ``pallas_call`` — no per-predictor Python loop.

The banked dispatch is split into three independently schedulable stages so
the async engine (``serving/engine.py``) can pipeline them across windows:

  * :meth:`MuseServer.run_models`       — expert-model execution (raw scores)
  * :meth:`MuseServer.apply_transforms` — ONE banked T^C/A/T^Q kernel call
  * :meth:`MuseServer.track`            — quantile-estimator reservoir updates

Each stage reads served state through a :class:`_ControlPlane` snapshot —
ONE attribute read yields a mutually consistent (predictors, banks,
generation) triple, because every control-plane operation (deploy,
decommission, calibration publish) swaps the whole plane in a single
reference assignment.  A stage that snapshotted the old plane finishes on
the old generation; the next stage pickup sees the complete new one — no
torn reads, even with a concurrent publish from another thread.

The server is the *data plane*; control-plane operations (deploying
predictors, publishing routing tables, triggering calibration refreshes) are
explicit methods invoked by the rollout controller — never by clients.

Sharded serving topology
------------------------

With ``ServerConfig(tenant_shards=S)`` the server serves every model-group
bank as a :class:`~repro.core.transforms.ShardedTransformBank` row-
partitioned over an S-way "tenants" mesh axis
(:func:`repro.launch.mesh.make_tenant_mesh`): each device holds ONLY its
tenant rows (~1/S of the dense bank).  ``apply_transforms`` then routes
through :class:`ShardedBankDispatcher` — rows of a window are bucketed by
owning shard on the host, every shard runs the banked Pallas kernel on its
LOCAL sub-bank inside one ``shard_map`` launch, and results gather back in
request order.  The per-row compute is the same kernel as the dense path,
so sharded and dense scores agree bitwise on f32.

Calibration publishes keep their atomicity across shards: the fleet refresh
fits candidates globally, and ``publish_quantile_maps`` rebuilds the dense
bank AND its per-shard sub-banks (scattering only into each row's owning
shard) inside the same single control-plane swap — one fleet-monotone
generation, never a torn per-shard mix.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro import jax_compat
from repro.core.predictor import Predictor, PredictorSpec, deploy_predictor
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.registry import ModelPool
from repro.core.routing import Intent, RoutingTable
from repro.core.transforms import (
    QuantileMap,
    ShardedTransformBank,
    TENANT_AXIS,
    TransformBank,
    banked_score_pipeline,
)
from repro.kernels import ops
from repro.kernels.quantile_track import DeviceQuantileTracker
from repro.serving.shadow import ShadowSink
from repro.serving.tiering import (
    HostBankStore,
    ShardedTieredBankStore,
    TieredBankStore,
    TieringConfig,
)
from repro.serving.types import (
    ScoringRequest,
    ScoringResponse,
    ShadowRecord,
    StaleGenerationError,
)

__all__ = [
    "FeatureStore", "MuseServer", "ServerConfig", "ShardedBankDispatcher",
    "StaleGenerationError",  # canonical home is serving/types.py
]


class FeatureStore:
    """Per-tenant derived-feature lookup (paper's 'Easy Feature Evolution').

    Models may require wider feature vectors than the client payload carries;
    the store supplies the model-specific derived features so new model
    versions deploy without client payload changes.
    """

    def __init__(self) -> None:
        self._store: dict[str, np.ndarray] = {}

    def put(self, tenant: str, derived: np.ndarray) -> None:
        self._store[tenant] = np.asarray(derived, np.float32)

    def enrich(self, intent: Intent, features: np.ndarray, target_dim: int
               ) -> np.ndarray:
        features = np.asarray(features, np.float32)
        if features.shape[-1] >= target_dim:
            return features[..., :target_dim]
        derived = self._store.get(intent.tenant)
        pad_width = target_dim - features.shape[-1]
        if derived is None:
            pad = np.zeros(features.shape[:-1] + (pad_width,), np.float32)
        else:
            reps = -(-pad_width // len(derived))
            pad = np.tile(derived, reps)[:pad_width]
            pad = np.broadcast_to(pad, features.shape[:-1] + (pad_width,))
        return np.concatenate([features, pad], axis=-1)


def stream_seed(key: tuple[str, str]) -> int:
    """Deterministic RNG seed for a (tenant, predictor) estimator stream.

    The old derivation hashed ``"/".join(key)`` unconditionally, which
    collided for ``("a/b", "c")`` vs ``("a", "b/c")`` — identical seeds
    mean identical reservoir acceptance sequences for supposedly
    independent streams.  The join IS injective when no component
    contains the separator (split on "/" inverts it), so that case keeps
    the legacy digest — existing deployments with ordinary tenant /
    predictor names don't have every stream's acceptance sequence
    reshuffled.  Ambiguous keys (a "/" inside a component) switch to
    length-prefix framing, led by a ``0xff`` byte: 0xff never occurs in
    UTF-8 output, so the framed namespace is disjoint from every legacy
    payload and the combined map is injective.  Checkpointed streams
    carry their full RNG state, so restores of old checkpoints stay
    exact across this change."""
    if any("/" in part for part in key):
        payload = b"\xff" + b"".join(
            len(part := p.encode()).to_bytes(4, "big") + part for p in key)
    else:
        payload = "/".join(key).encode()
    return zlib.crc32(payload)


@dataclasses.dataclass
class ServerConfig:
    track_quantiles: bool = True
    quantile_capacity: int = 131072
    # newest-samples ring per estimator stream: sized so a "recent"-window
    # refresh (RefreshPolicy.fit_window) sees roughly the drift timescale
    # of interest (e.g. ~a day of a tenant's traffic for the adversarial
    # campaign suite), not the all-time reservoir
    recent_capacity: int = 4096
    refresh_alert_rate: float = 0.01   # Eq. 5 gating for auto-refresh readiness
    refresh_rel_error: float = 0.2
    # fused tenant-indexed Pallas dispatch; False falls back to the pure-jnp
    # banked oracle (same semantics, no pallas_call)
    fused_kernel: bool = True
    # row-shard every model-group bank over an S-way "tenants" mesh axis
    # (1 = dense single-replica banks, the default).  Requires >= S jax
    # devices; see the module docstring's "Sharded serving topology".
    tenant_shards: int = 1
    # tiered tenant-bank store (serving/tiering.py): hot rows on device,
    # cold rows host-paged through a bounded victim cache, un-gated tenants
    # through the cold-start prior.  None = fully device-resident banks.
    # Composes with tenant_shards > 1: each shard of the tenant mesh gets
    # its own hot tier + victim cache over a per-shard host store
    # (ShardedTieredBankStore — bounded residency PER SHARD).
    tiering: TieringConfig | None = None
    # fused device tracking (kernels/quantile_track.py): the track stage
    # becomes one device dispatch (banked pre_quantile aggregate + scatter
    # into per-stream staging buffers); host estimators materialize only at
    # the calibration plane's pull boundary (Eq.-5 gating, checkpoint
    # snapshots, fleet merge).  Bitwise-identical estimator state to eager
    # host tracking — see the exactness contract in quantile_track.py.
    track_device: bool = False
    # per-stream device staging capacity (samples buffered between pulls);
    # a stream spills to host when its staging would overflow
    track_staging: int = 4096


def _shape_bucket(n: int) -> int:
    """Next power of two >= n: serving batches are padded up to a bucket so
    the set of XLA specializations stays bounded (one per bucket, not one
    per arbitrary window length — an adaptive engine window or a remainder
    flush would otherwise each pay a fresh compile on the hot path)."""
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass(frozen=True)
class _BankEntry:
    """A cached model-group bank pinned to the pipelines it was built from.

    ``pipelines`` is the identity witness: a ``publish_quantile_maps`` /
    redeploy replaces pipeline objects, so a stale entry fails the identity
    check and is rebuilt.  The bank itself carries the generation it was
    published under (see :class:`~repro.core.transforms.TransformBank`).
    ``sharded`` is the row-partitioned view served when
    ``ServerConfig.tenant_shards > 1`` — always built/updated alongside the
    dense bank in the SAME control-plane swap, so their generations agree.
    ``tiered`` is the hot/victim/prior tiered store served when
    ``ServerConfig.tiering`` is set; it replaces the dense bank entirely
    (``bank`` is None) so device residency stays bounded by the configured
    hot-tier capacity instead of the group's tenant count."""

    pipelines: tuple[Any, ...]
    bank: TransformBank | None
    sharded: ShardedTransformBank | None = None
    tiered: TieredBankStore | ShardedTieredBankStore | None = None


@dataclasses.dataclass(frozen=True)
class _TieredWindowBank:
    """The per-window 'bank' a tiered dispatch hands downstream stages.

    A :class:`TieredBankStore` is mutable (a publish can land right after a
    window scores), so ``apply_transforms`` wraps the store with the
    generation the window ACTUALLY scored under — ``build_responses`` reads
    a dispatch-time provenance stamp, exactly like the immutable dense
    bank's, and ``track`` fits estimators through the same rows the window
    served."""

    store: TieredBankStore | ShardedTieredBankStore
    generation: int

    def pre_quantile(self, expert_scores, tenant_idx):
        return self.store.pre_quantile(expert_scores, tenant_idx)


class ShardedBankDispatcher:
    """shard_map-driven banked dispatch over a tenant-sharded bank.

    The data-plane half of the sharded topology: a window's rows are
    bucketed by owning shard on the host (the bank's global→local remap),
    packed into one (S, Bs, K) batch padded per shard, and every shard runs
    the banked kernel against ONLY its local (Tl, ·) sub-bank inside a
    single ``shard_map`` launch over the "tenants" axis.  Results gather
    back into request order on the host.  Shard buckets pad their tenant
    vector edge-wise so a single-tenant bucket keeps the kernel's uniform-
    block fast path.

    Per-row compute is the identical kernel the dense path runs, and rows
    are computed independently of batch/bank shape — sharded scores match
    the dense path BITWISE on f32 (asserted by tests/test_sharded_bank.py).
    """

    def __init__(self, mesh: Any, *, fused: bool = True) -> None:
        self.mesh = mesh
        self.fused = fused
        self._launch_fn: Any = None

    def _launch(self) -> Any:
        if self._launch_fn is None:
            fused = self.fused

            def per_shard(sc, ti, b, w, qs, qr):
                impl = ops.score_pipeline_banked if fused \
                    else banked_score_pipeline
                return impl(sc[0], ti[0], b[0], w[0], qs[0], qr[0])[None]

            spec = PartitionSpec(TENANT_AXIS)
            self._launch_fn = jax.jit(jax_compat.shard_map(
                per_shard, mesh=self.mesh, in_specs=(spec,) * 6,
                out_specs=spec, check_vma=False))
        return self._launch_fn

    def run_packed(self, packed: np.ndarray, pidx: np.ndarray,
                   betas: Any, weights: Any, src_quantiles: Any,
                   ref_quantiles: Any) -> np.ndarray:
        """One shard_map launch over an already-packed (S, Bs, ·) window
        against explicit (S, R, ·) per-shard parameter stacks.

        The raw launch entry: ``__call__`` buckets/packs a window against
        a :class:`ShardedTransformBank` and lands here; the tiered-over-
        sharded store (``serving/tiering.ShardedTieredBankStore``) packs
        slot-remapped buckets itself and calls this directly with its
        stacked per-shard tier views — same mesh, same compiled launch.
        """
        with self.mesh:
            return np.asarray(self._launch()(
                jnp.asarray(packed), jnp.asarray(pidx), betas,
                weights, src_quantiles, ref_quantiles))

    def _run(self, packed: np.ndarray, pidx: np.ndarray,
             sbank: ShardedTransformBank) -> np.ndarray:
        """One shard_map launch over the packed (S, Bs, ·) window."""
        return self.run_packed(packed, pidx, sbank.betas, sbank.weights,
                               sbank.src_quantiles, sbank.ref_quantiles)

    @staticmethod
    def _pack_bucket(packed, pidx, shard, rows_raws, rows_idx, bs):
        """Place one shard's rows, edge-padding the tenant vector so a
        single-tenant bucket keeps the kernel's uniform-block fast path."""
        n = len(rows_idx)
        packed[shard, :n] = rows_raws
        pidx[shard, :n] = rows_idx
        if n and n < bs:
            pidx[shard, n:] = pidx[shard, n - 1]

    def __call__(self, raws: np.ndarray, tenant_idx: np.ndarray,
                 sbank: ShardedTransformBank) -> np.ndarray:
        raws = np.asarray(raws, np.float32)
        shard_ids, local_ids = sbank.locate(tenant_idx)
        s = sbank.num_shards
        if s == 1:
            # single-shard degenerate case: skip the bucketing entirely
            # (no argsort, no fancy-index gather) so S=1 costs the same as
            # the dense path — the bench's no-regression bar
            b = len(local_ids)
            bs = _shape_bucket(b) if b else 1
            packed = np.zeros((1, bs, raws.shape[-1]), np.float32)
            pidx = np.zeros((1, bs), np.int32)
            self._pack_bucket(packed, pidx, 0, raws, local_ids, bs)
            return self._run(packed, pidx, sbank)[0, :b]
        counts = np.bincount(shard_ids, minlength=s)
        bs = _shape_bucket(int(counts.max())) if counts.max() else 1
        order = np.argsort(shard_ids, kind="stable")
        packed = np.zeros((s, bs, raws.shape[-1]), np.float32)
        pidx = np.zeros((s, bs), np.int32)
        buckets: list[np.ndarray] = []
        start = 0
        for shard in range(s):
            rows = order[start:start + counts[shard]]
            start += counts[shard]
            buckets.append(rows)
            if len(rows):
                self._pack_bucket(packed, pidx, shard, raws[rows],
                                  local_ids[rows], bs)
        out = self._run(packed, pidx, sbank)
        result = np.empty(len(shard_ids), np.float32)
        for shard, rows in enumerate(buckets):
            result[rows] = out[shard, :len(rows)]
        return result


@dataclasses.dataclass(frozen=True)
class _ControlPlane:
    """One immutable view of everything a dispatch stage reads.

    ``predictors`` and ``banks`` are plain dicts, but the PLANE object is
    what gets swapped: every control-plane mutation builds fresh dicts and
    replaces ``MuseServer._plane`` in a single reference assignment, so a
    stage that reads ``server.plane`` once can never observe predictors of
    one generation with banks of another.  ``banks`` doubles as the lazy
    bank-build cache; inserting a missing entry is idempotent and therefore
    safe to do from a dispatch stage (a concurrently swapped-out plane just
    drops the cached entry — never serves stale parameters).
    """

    predictors: dict[str, Predictor]
    banks: dict[tuple[str, ...], _BankEntry]
    generation: int


class MuseServer:
    def __init__(self, routing: RoutingTable,
                 config: ServerConfig | None = None) -> None:
        self.pool = ModelPool()
        self.routing = routing
        self.sink = ShadowSink()
        self.features = FeatureStore()
        self.config = config or ServerConfig()
        # per (tenant, predictor) streaming estimators for calibration refresh
        self._estimators: dict[tuple[str, str], StreamingQuantileEstimator] = {}
        # estimator MUTATION (track stage) vs whole-state SNAPSHOT
        # (save_estimators) must not interleave: a checkpoint written while
        # an update is mid-flight would pair arrays with meta (seen counts,
        # ring pointer, RNG state) from different moments — a torn restore
        self._estimator_lock = threading.Lock()
        # fused device tracking: staged aggregates live in device buffers
        # owned by this control plane; every tracker call (append on the
        # track stage, sync at calibration pulls) runs under the estimator
        # lock, which is what serializes staging against materialization
        self._tracker: DeviceQuantileTracker | None = None
        if self.config.track_quantiles and self.config.track_device:
            self._tracker = DeviceQuantileTracker(
                self._apply_tracked,
                staging_capacity=self.config.track_staging)
        # THE served control-plane state: swapped wholesale on every deploy /
        # decommission / calibration publish (never mutated across a publish).
        # A dispatch stage snapshots it once, so an in-flight window finishes
        # on the old generation and the next stage sees the new one — no
        # torn reads.
        self._plane = _ControlPlane(predictors={}, banks={}, generation=0)
        # sharded topology: one mesh + dispatcher per server when configured.
        # With tiering ALSO set, the dispatcher serves the composed
        # tiered-over-sharded stores (per-shard hot tiers, one shard_map
        # launch per window) instead of fully-resident sharded banks.
        self._sharded_dispatch: ShardedBankDispatcher | None = None
        if self.config.tenant_shards > 1:
            from repro.launch.mesh import make_tenant_mesh
            self._sharded_dispatch = ShardedBankDispatcher(
                make_tenant_mesh(self.config.tenant_shards),
                fused=self.config.fused_kernel)
        # tiered topology: stateful stores OUTSIDE the plane (hotness, seen
        # counts and victim-cache residency survive plane swaps); the plane's
        # bank entries hold references, _tier_lock guards the dict itself
        self._tiered_stores: dict[
            tuple[str, ...], TieredBankStore | ShardedTieredBankStore] = {}
        self._tier_lock = threading.Lock()
        # predictors routed through the cold-start prior until their stream
        # re-passes the Eq.-5 gate (applied to stores built later, too)
        self._cold_names: set[str] = set()
        self.metrics: dict[str, float] = {
            "requests": 0, "shadow_evals": 0, "kernel_dispatches": 0,
            "model_group_calls": 0, "model_calls": 0, "bank_generation": 0,
            "shard_dispatches": 0, "tier_dispatches": 0,
            # uniform-block fast-path coverage of the fused banked kernel:
            # blocks whose rows all share one tenant skip the one-hot gather
            # matmuls (see kernels/score_pipeline.py).  uniform/total over
            # all dense fused dispatches = the serving-side skip rate.
            "skip_blocks_uniform": 0, "skip_blocks_total": 0,
            # windows staged by the fused device tracker (vs eager host
            # fallbacks; spills/fallbacks also count on the tracker itself)
            "track_staged_windows": 0}
        # dict `+=` is load/add/store — racy once the engine runs stages on
        # several threads (e.g. two model-group lanes); serialize the bumps
        self._metrics_lock = threading.Lock()
        # control-plane mutations are read-modify-writes of _plane; two
        # concurrent mutators (e.g. deploy on the main thread vs a refresh
        # publish on the engine's track thread) must not lose each other's
        # update, so every mutator holds this lock across its RMW.  Dispatch
        # stages never take it — they only snapshot the reference.
        self._control_lock = threading.Lock()

    def bump_metric(self, key: str, n: float = 1) -> None:
        with self._metrics_lock:
            self.metrics[key] += n

    # ------------------------------------------------------------ plane views
    @property
    def plane(self) -> _ControlPlane:
        """The current control-plane snapshot (ONE consistent read)."""
        return self._plane

    @property
    def predictors(self) -> dict[str, Predictor]:
        return self._plane.predictors

    @property
    def _banks(self) -> dict[tuple[str, ...], _BankEntry]:
        return self._plane.banks

    @property
    def bank_generation(self) -> int:
        """Monotone counter of atomic calibration publishes."""
        return self._plane.generation

    # ------------------------------------------------------------------ control
    def deploy(self, spec: PredictorSpec,
               model_factories: Mapping[str, Callable[[], Any]],
               model_costs: Mapping[str, float] | None = None) -> Predictor:
        pred = deploy_predictor(spec, self.pool, model_factories, model_costs)
        with self._control_lock:
            plane = self._plane
            # an in-place redeploy changes served parameters under an
            # existing name, so it must bump the generation: otherwise two
            # responses scored before/after it would carry the same
            # ``bank_generation`` stamp for different T^C/A/T^Q.  (Cached
            # banks pinned to the dead pipeline fail the identity check and
            # rebuild lazily.)  First-time deploys leave the counter alone.
            gen = plane.generation + (1 if spec.name in plane.predictors
                                      else 0)
            predictors = dict(plane.predictors)
            predictors[spec.name] = pred
            self._plane = dataclasses.replace(plane, predictors=predictors,
                                              generation=gen)
            self.metrics["bank_generation"] = gen
        return pred

    def decommission(self, name: str) -> None:
        with self._control_lock:
            plane = self._plane
            predictors = dict(plane.predictors)
            pred = predictors.pop(name)
            # drop cached banks referencing the dead predictor's pipeline.
            # dict() first: a concurrent dispatch stage may lazily insert a
            # cache entry mid-iteration (the copy itself is GIL-atomic).
            # Generation bumps so a later deploy under the same name cannot
            # serve different parameters under an already-used stamp.
            banks = {k: v for k, v in dict(plane.banks).items()
                     if name not in k}
            gen = plane.generation + 1
            self._plane = dataclasses.replace(plane, predictors=predictors,
                                              banks=banks, generation=gen)
            self.metrics["bank_generation"] = gen
        pred.release(self.pool)
        # and its estimator streams: a future predictor redeployed under the
        # same name has a different score distribution — refitting T^Q from
        # the dead model's stream would publish a miscalibrated map.  Staged
        # device samples die with the streams (drop_where), so a redeploy
        # under the same name can never materialize the dead model's scores.
        with self._estimator_lock:
            if self._tracker is not None:
                self._tracker.drop_where(lambda k: k[1] == name)
            self._estimators = {k: v for k, v in self._estimators.items()
                                if k[1] != name}
        # tiered stores holding the dead predictor's host row die with it
        # (row indices are positions in the names tuple — unpatchable)
        with self._tier_lock:
            self._tiered_stores = {k: v for k, v in self._tiered_stores.items()
                                   if name not in k}
        self._cold_names.discard(name)

    def publish_routing(self, table: RoutingTable) -> None:
        """Atomic routing swap — the transparent model switching primitive."""
        missing = [n for n in table.referenced_predictors()
                   if n not in self.predictors]
        if missing:
            raise KeyError(f"routing references undeployed predictors: {missing}")
        self.routing = table

    def swap_transformation(self, predictor_name: str, qm: QuantileMap) -> None:
        """T^Q_v0 -> T^Q_v1 without touching models (Sec. 3.1)."""
        self.publish_quantile_maps({predictor_name: qm})

    def publish_quantile_maps(self, updates: Mapping[str, QuantileMap],
                              *, generation: int | None = None) -> int:
        """Atomically publish refreshed T^Q maps for MANY predictors at once.

        The fleet-wide calibration refresh (Sec. 3.1, `serving/calibration.py`)
        lands here: every updated predictor pipeline AND every affected
        model-group bank is rebuilt first, then the whole control plane is
        swapped in one reference assignment under a bumped generation.  A
        dispatch stage that already snapshotted the old plane finishes on the
        old parameters; the next stage sees the complete new generation —
        a batch can never mix rows from two calibration versions.

        ``generation`` is the fleet fencing hook: when given (a fleet-stamped
        broadcast), the publish lands under exactly that generation and is
        REJECTED with :class:`StaleGenerationError` unless it is strictly
        newer than the replica's current one — a late ack from a superseded
        fleet pass can never roll transformations backwards.  A fenced
        publish also re-stamps every cached bank (touched or not) to the
        fleet generation, so response provenance stamps are fleet-monotone,
        and an EMPTY fenced publish fast-forwards a lagging replica (e.g. a
        freshly surged one) to the fleet generation without changing maps.

        Returns the new bank generation.
        """
        with self._control_lock:
            return self._publish_quantile_maps_locked(updates, generation)

    def _publish_quantile_maps_locked(self, updates: Mapping[str, QuantileMap],
                                      generation: int | None = None) -> int:
        plane = self._plane
        missing = [n for n in updates if n not in plane.predictors]
        if missing:
            raise KeyError(f"unknown predictors: {missing}")
        if generation is None:
            if not updates:
                return plane.generation
            gen = plane.generation + 1
        else:
            # generation fencing: only strictly-forward fleet publishes land
            if generation <= plane.generation:
                raise StaleGenerationError(generation, plane.generation)
            gen = generation

        new_predictors = dict(plane.predictors)
        for name, qm in updates.items():
            pred = new_predictors[name]
            new_predictors[name] = pred.with_updated_pipeline(
                pred.pipeline.with_quantile_map(qm))

        new_banks: dict[tuple[str, ...], _BankEntry] = {}
        # dict() first: a dispatch stage on another thread may lazily insert
        # a bank-cache entry mid-iteration (the copy itself is GIL-atomic)
        for key, entry in dict(plane.banks).items():
            touched = {i: updates[n] for i, n in enumerate(key) if n in updates}
            if entry.tiered is not None:
                store = entry.tiered
                entry_fresh = len(entry.pipelines) == len(key) and all(
                    ep is plane.predictors[n].pipeline
                    for ep, n in zip(entry.pipelines, key))
                if not entry_fresh:
                    # host rows came from a dead pipeline — drop the entry;
                    # the next dispatch rebuilds the store from the live
                    # pipelines (re-adopting its hotness state)
                    continue
                try:
                    if touched:
                        # publish into BOTH tiers in ONE locked store op:
                        # host rows rewritten + every device-resident copy
                        # (hot or victim) scattered under the new generation
                        store.apply_updates(touched, generation=gen)
                    elif generation is not None:
                        # fenced publish: fast-forward untouched stores so
                        # later provenance stamps stay fleet-monotone
                        store.apply_updates({}, generation=gen)
                except ValueError:
                    continue  # a table wider than the store: rebuild lazily
                pipelines = tuple(new_predictors[n].pipeline for n in key)
                store.source_pipelines = pipelines
                new_banks[key] = _BankEntry(pipelines, None, tiered=store)
                continue
            if not touched:
                if generation is None:
                    new_banks[key] = entry
                else:
                    # fenced publish: even untouched banks re-stamp to the
                    # fleet generation, so every response served after the
                    # ack carries a fleet-monotone provenance stamp
                    new_banks[key] = _BankEntry(
                        entry.pipelines,
                        entry.bank.with_rows({}, generation=gen),
                        None if entry.sharded is None
                        else entry.sharded.with_rows({}, generation=gen))
                continue
            pipelines = tuple(new_predictors[n].pipeline for n in key)
            # the with_rows fast path (scatter only the refreshed T^Q rows)
            # is sound only if the cached bank was built from the predictors'
            # CURRENT pipelines; a predictor redeployed in place leaves a
            # stale entry whose other rows carry the dead pipeline's T^C/A —
            # patching and re-pinning it would serve stale parameters forever
            entry_fresh = len(entry.pipelines) == len(key) and all(
                ep is plane.predictors[n].pipeline
                for ep, n in zip(entry.pipelines, key))
            bank = sharded = None
            if entry_fresh:
                try:
                    bank = entry.bank.with_rows(touched, generation=gen)
                    # the sharded sub-banks take the SAME refreshed rows,
                    # scattered into their owning shards, under the SAME
                    # generation — published in the one plane swap below
                    if entry.sharded is not None:
                        sharded = entry.sharded.with_rows(
                            touched, generation=gen)
                except ValueError:
                    bank = sharded = None  # a table wider than the bank
            if bank is None:
                bank = TransformBank.from_params(
                    [(p.betas, p.weights, p.src_quantiles, p.ref_quantiles)
                     for p in pipelines], generation=gen)
            if sharded is None and self._sharded_dispatch is not None:
                sharded = ShardedTransformBank.from_dense(
                    bank, self.config.tenant_shards)
            new_banks[key] = _BankEntry(pipelines, bank, sharded)

        # the publish point: ONE whole-plane swap, never in-place edits
        self._plane = _ControlPlane(new_predictors, new_banks, gen)
        self.metrics["bank_generation"] = gen
        return gen

    # ------------------------------------------------------------------- data
    def _model_dim(self, pred: Predictor) -> int:
        dims = [h.metadata.get("feature_dim") for h in pred._handles]
        dims = [d for d in dims if d]
        return max(dims) if dims else 0

    def batch_key(self, intent: Intent) -> str:
        """Micro-batching key: the resolved predictor's model group.

        Requests from different tenants/predictors that share the same
        expert-model set batch together — one executable call plus one
        banked kernel dispatch serves the whole window."""
        return self.group_key(self.routing.resolve(intent))

    def group_key(self, resolution) -> str:
        """``batch_key`` for an already-resolved intent — the async engine
        resolves once at submit and derives the key from the resolution
        (no double resolve), through this one source of truth."""
        return "+".join(self.predictors[resolution.live].model_names)

    def build_responses(self, requests, idxs: list[int],
                        pred_names: list[str], scores: np.ndarray,
                        raws: np.ndarray, bank: TransformBank,
                        routing_version: str, latency_ms: float
                        ) -> list[ScoringResponse]:
        """Assemble one window's responses (shared by sync + async drivers;
        ``tolist`` conversions are C-speed).  Row ``j`` answers request
        ``requests[idxs[j]]``."""
        score_list = scores.tolist()
        raw_rows = np.atleast_2d(raws).tolist()
        return [
            ScoringResponse(
                request_id=requests[i].request_id,
                score=score_list[j],
                predictor=pred_names[j],
                routing_version=routing_version,
                latency_ms=latency_ms,
                raw_scores=tuple(raw_rows[j]),
                bank_generation=bank.generation,
            )
            for j, i in enumerate(idxs)
        ]

    def write_shadow_records(self, requests, idxs: list[int],
                             shadow_names: list[str], scores: np.ndarray,
                             raws: np.ndarray, routing_version: str) -> None:
        """Sink one shadow window's records (shared by sync + async)."""
        score_list = scores.tolist()
        raw_rows = np.atleast_2d(raws).tolist()
        for j, i in enumerate(idxs):
            self.sink.write(ShadowRecord(
                request_id=requests[i].request_id,
                tenant=requests[i].intent.tenant,
                predictor=shadow_names[j],
                score=score_list[j],
                raw_scores=tuple(raw_rows[j]),
                routing_version=routing_version,
            ))
            self.bump_metric("shadow_evals")

    def _bank_for(self, names: tuple[str, ...],
                  plane: _ControlPlane | None = None) -> _BankEntry:
        """Build (or fetch) the stacked transform bank for these predictors.

        Cache entries pin the source pipelines; a ``publish_quantile_maps`` /
        redeploy replaces the pipeline object, failing the identity check
        and rebuilding the bank — banks never serve stale parameters.
        ``plane`` is the stage-time snapshot; lookups go through it so a
        concurrent publish can't produce a torn read.  Under a sharded
        topology the entry carries the row-partitioned sub-banks too (built
        in the same insertion, same generation)."""
        plane = self._plane if plane is None else plane
        pipelines = tuple(plane.predictors[n].pipeline for n in names)
        cached = plane.banks.get(names)
        if cached is not None and len(cached.pipelines) == len(pipelines) \
                and all(a is b for a, b in zip(cached.pipelines, pipelines)):
            return cached
        if self.config.tiering is not None:
            entry = _BankEntry(pipelines, None,
                               tiered=self._tiered_store_for(names, pipelines))
            plane.banks[names] = entry
            return entry
        bank = TransformBank.from_params(
            [(p.betas, p.weights, p.src_quantiles, p.ref_quantiles)
             for p in pipelines], generation=plane.generation)
        sharded = None
        if self._sharded_dispatch is not None:
            sharded = ShardedTransformBank.from_dense(
                bank, self.config.tenant_shards)
        entry = _BankEntry(pipelines, bank, sharded)
        plane.banks[names] = entry
        return entry

    def _tiered_store_for(
            self, names: tuple[str, ...], pipelines: tuple[Any, ...]
    ) -> TieredBankStore | ShardedTieredBankStore:
        """Fetch (or build) the stateful tiered store for a model group.

        Stores live OUTSIDE the control plane so hotness/admission state
        survives plane swaps; ``source_pipelines`` is the same identity
        witness the bank cache uses, so a redeploy-stale store is rebuilt
        from the live pipelines here — adopting the old store's hotness so
        the hot set carries over.  Under a sharded topology the store is
        the composed :class:`ShardedTieredBankStore` (per-shard hot tiers
        over per-shard host slices, dispatched through this server's
        mesh dispatcher); its global-indexed hotness snapshot lets the
        adoption below cross topologies too."""
        with self._tier_lock:
            store = self._tiered_stores.get(names)
            if store is not None \
                    and store.source_pipelines is not None \
                    and len(store.source_pipelines) == len(pipelines) \
                    and all(a is b for a, b in
                            zip(store.source_pipelines, pipelines)):
                return store
            host = HostBankStore.from_rows(
                [(p.betas, p.weights, p.src_quantiles, p.ref_quantiles)
                 for p in pipelines])
            if self._sharded_dispatch is not None:
                fresh: TieredBankStore | ShardedTieredBankStore = \
                    ShardedTieredBankStore(
                        host, self.config.tenant_shards, self.config.tiering,
                        dispatcher=self._sharded_dispatch,
                        generation=self._plane.generation)
            else:
                fresh = TieredBankStore(host, self.config.tiering,
                                        generation=self._plane.generation)
            fresh.source_pipelines = pipelines
            if store is not None:
                fresh.adopt_hotness(store.hotness_snapshot())
            cold = [i for i, n in enumerate(names) if n in self._cold_names]
            if cold:
                fresh.mark_cold(cold)
            fresh.rebalance()
            self._tiered_stores[names] = fresh
            return fresh

    def score(self, request: ScoringRequest) -> ScoringResponse:
        return self.score_batch([request])[0]

    # ----------------------------------------------------- dispatch stages
    def run_models(self, requests: list[ScoringRequest], idxs: list[int],
                   pred_names: list[str],
                   raw_cache: dict[tuple[tuple[str, ...], int], np.ndarray]
                   | None = None,
                   plane: _ControlPlane | None = None) -> np.ndarray:
        """Stage 1 of a banked dispatch: execute the window's expert models.

        One model executable call produces raw scores for the whole
        (possibly multi-predictor) window; ``pred_names[j]`` is the predictor
        for row ``j``.  ``raw_cache`` carries (model group, request index)
        -> raw-score rows across dispatches of one batch, so live and shadow
        windows sharing a model group run the experts once (shadow dedup).
        Returns the (B, K) raw-score matrix.
        """
        plane = self._plane if plane is None else plane
        bank_names = tuple(sorted(set(pred_names)))
        pred0 = plane.predictors[bank_names[0]]
        group = pred0.model_names
        dim = self._model_dim(pred0) or len(requests[idxs[0]].features)
        rows: list[np.ndarray | None] = [None] * len(idxs)
        fresh = list(range(len(idxs)))
        if raw_cache is not None:
            fresh = []
            for j, i in enumerate(idxs):
                hit = raw_cache.get((group, i))
                if hit is None:
                    fresh.append(j)
                else:
                    rows[j] = hit
        if fresh:
            feats = self._window_features(requests, idxs, fresh, dim)
            pad = _shape_bucket(len(fresh)) - len(fresh)
            if pad:  # bucketed batch shape: no per-length recompiles
                feats = np.concatenate(
                    [feats, np.zeros((pad,) + feats.shape[1:], np.float32)])
            computed = np.asarray(pred0.raw_scores(feats))[:len(fresh)]
            with self._metrics_lock:
                self.metrics["model_group_calls"] += 1
                self.metrics["model_calls"] += len(group)
            for r, j in enumerate(fresh):
                rows[j] = computed[r]
                if raw_cache is not None:
                    raw_cache[(group, idxs[j])] = computed[r]
        return np.stack(rows)                                # (B, K)

    def _window_features(self, requests, idxs: list[int], fresh: list[int],
                         dim: int) -> np.ndarray:
        """Assemble the (len(fresh), dim) model-input matrix.

        Fast path: when every row already carries >= dim features of the
        right dtype, ONE stack+slice replaces the per-row enrich calls —
        the per-row Python otherwise dominates the model stage under the
        async engine (GIL contention with the other stage threads).
        """
        try:
            feats = np.stack([requests[idxs[j]].features for j in fresh])
            if feats.dtype == np.float32 and feats.ndim == 2 \
                    and feats.shape[1] >= dim:
                return feats[:, :dim]
        except ValueError:
            pass  # ragged rows: fall through to per-row enrichment
        return np.stack([
            self.features.enrich(requests[idxs[j]].intent,
                                 requests[idxs[j]].features, dim)
            for j in fresh
        ])

    def apply_transforms(self, raws: np.ndarray, pred_names: list[str],
                         plane: _ControlPlane | None = None
                         ) -> tuple[np.ndarray, Any, np.ndarray]:
        """Stage 2: the whole window through ONE banked T^C/A/T^Q kernel call.

        The bank is resolved from the stage-time ``plane`` snapshot — a
        calibration publish landing between stage 1 and stage 2 is picked up
        here wholesale (raw expert scores are generation-independent), and
        every row of the window scores under exactly one bank generation.
        Returns (scores, bank, tenant_idx); the bank's ``generation`` is the
        window's provenance stamp.
        """
        plane = self._plane if plane is None else plane
        bank_names = tuple(sorted(set(pred_names)))  # canonical cache key
        entry = self._bank_for(bank_names, plane)
        row_of = {n: r for r, n in enumerate(bank_names)}
        tenant_idx = np.asarray([row_of[n] for n in pred_names], np.int32)
        if entry.tiered is not None:
            # tiered topology: slot-remapped banked dispatch against the
            # bounded device view; cold rows stage through the victim cache
            # (normally prefetched by the engine before this stage runs)
            scores, gen = entry.tiered.dispatch(raws, tenant_idx)
            self.bump_metric("kernel_dispatches")
            self.bump_metric("tier_dispatches")
            if isinstance(entry.tiered, ShardedTieredBankStore):
                self.bump_metric("shard_dispatches")
            return scores, _TieredWindowBank(entry.tiered, gen), tenant_idx
        bank = entry.bank
        b = len(tenant_idx)
        if entry.sharded is not None and self._sharded_dispatch is not None:
            # sharded topology: bucket by owning shard, one shard_map launch
            # of the banked kernel per window (the dispatcher pads per
            # shard, so no outer shape-bucket pad is needed here)
            scores = self._sharded_dispatch(raws, tenant_idx, entry.sharded)
            self.bump_metric("kernel_dispatches")
            self.bump_metric("shard_dispatches")
            return scores, bank, tenant_idx
        pad = _shape_bucket(b) - b
        if pad:  # bucketed kernel shape, same reasoning as run_models
            kraws = np.concatenate(
                [raws, np.zeros((pad,) + raws.shape[1:], raws.dtype)])
            # edge-pad the tenant vector so an otherwise-uniform tail block
            # keeps the kernel's scalar-prefetch fast path (rows sliced off)
            kidx = np.concatenate(
                [tenant_idx, np.full(pad, tenant_idx[-1], np.int32)])
        else:
            kraws, kidx = raws, tenant_idx
        if self.config.fused_kernel:
            scores = ops.score_pipeline_banked(
                jnp.asarray(kraws, jnp.float32), jnp.asarray(kidx),
                bank.betas, bank.weights,
                bank.src_quantiles, bank.ref_quantiles)
            # serving-side skip-rate accounting: banked_skip_stats mirrors
            # the kernel's own blocking (pow-2 block, edge-padded tail), so
            # feeding it the UNPADDED tenant vector reports exactly the
            # uniform-block fast-path coverage this dispatch just got
            stats = ops.banked_skip_stats(tenant_idx)
            with self._metrics_lock:
                self.metrics["skip_blocks_uniform"] += stats["uniform_blocks"]
                self.metrics["skip_blocks_total"] += stats["blocks"]
        else:
            scores = bank(jnp.asarray(kraws, jnp.float32),
                          jnp.asarray(kidx))
        self.bump_metric("kernel_dispatches")
        return np.asarray(scores)[:b], bank, tenant_idx

    def track(self, requests: list[ScoringRequest], idxs: list[int],
              pred_names: list[str], raws: np.ndarray, bank: TransformBank,
              tenant_idx: np.ndarray) -> None:
        """Stage 3: batched per-(tenant, predictor) reservoir updates.

        Tracks the T^Q INPUT distribution — the posterior-corrected weighted
        aggregate through the window's OWN bank snapshot; fitting a refreshed
        T^Q on raw means would mismatch the pipeline (the bug class the
        paper's Sec.-3.1 update avoids).  Order-insensitive, so the async
        engine may run it a stage behind the response path.
        """
        if not self.config.track_quantiles:
            return
        keys = [(requests[i].intent.tenant, pred_names[j])
                for j, i in enumerate(idxs)]
        if self._tracker is not None:
            # device-fused mode: dense banks stage score -> transform ->
            # track as ONE device dispatch (the aggregate never syncs to
            # host); tiered stores compute pre_quantile through host-paged
            # rows, so only the scatter-append fuses.  Host estimators
            # materialize at the calibration plane's pull boundary.
            with self._estimator_lock:
                if isinstance(bank, TransformBank):
                    staged = self._tracker.append_fused(
                        keys, raws, tenant_idx, bank)
                    if not staged:
                        agg = np.asarray(bank.pre_quantile(
                            jnp.asarray(raws, jnp.float32),
                            jnp.asarray(tenant_idx)))
                else:
                    agg = np.asarray(bank.pre_quantile(
                        jnp.asarray(raws, jnp.float32),
                        jnp.asarray(tenant_idx)))
                    staged = self._tracker.append_agg(keys, agg)
                if not staged:
                    # one stream outsized the whole staging plane: its
                    # staged history was drained first (arrival order), so
                    # an eager update here keeps per-stream sequences exact
                    self._update_streams(keys, agg)
            self.bump_metric("track_staged_windows", int(staged))
            return
        agg = np.asarray(bank.pre_quantile(
            jnp.asarray(raws, jnp.float32), jnp.asarray(tenant_idx)))
        # one batched reservoir update per (tenant, predictor) stream,
        # serialized with estimator checkpoints (see _estimator_lock)
        with self._estimator_lock:
            self._update_streams(keys, agg)

    def _update_streams(self, keys: list[tuple[str, str]],
                        agg: np.ndarray) -> None:
        """Eager host tracking (caller holds ``_estimator_lock``): one
        batched reservoir update per stream present in the window."""
        by_stream: dict[tuple[str, str], list[int]] = {}
        for j, key in enumerate(keys):
            by_stream.setdefault(key, []).append(j)
        for key, rows in by_stream.items():
            self._stream_estimator(key).update(agg[rows])

    def _stream_estimator(self, key: tuple[str, str]
                          ) -> StreamingQuantileEstimator:
        """Get-or-create under ``_estimator_lock`` — the single construction
        site, so eager tracking and device-tracker drains seed identically."""
        est = self._estimators.get(key)
        if est is None:
            est = StreamingQuantileEstimator(
                self.config.quantile_capacity, seed=stream_seed(key),
                recent_capacity=self.config.recent_capacity)
            self._estimators[key] = est
        return est

    def _apply_tracked(self, key: tuple[str, str],
                       chunks: list[np.ndarray]) -> None:
        """Device-tracker materialization callback (runs under
        ``_estimator_lock``): replay staged windows as the separate update
        calls they were (see the bitwise contract in quantile_track.py)."""
        self._stream_estimator(key).apply_chunks(chunks)

    def _sync_tracker_locked(self) -> None:
        """Materialize staged device samples (caller holds the lock) —
        every calibration host-pull boundary funnels through this."""
        if self._tracker is not None:
            self._tracker.sync()

    # -------------------------------------------------------- sync data path
    def score_batch(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        """Scores a mixed-tenant batch: requests are grouped by model group
        (shared expert-model set); each group costs one model executable
        call plus ONE tenant-indexed banked kernel dispatch, whatever mix of
        tenants and predictors the group contains.

        This is the synchronous driver: it runs the three dispatch stages
        back-to-back per group against ONE plane snapshot for the whole
        batch (live + shadows), so even a refresh landing mid-flight from
        another thread cannot mix generations.  ``serving/engine.py``
        pipelines the same stages across windows instead.
        """
        plane = self._plane  # dispatch-time snapshot
        resolutions = [self.routing.resolve(r.intent) for r in requests]
        by_group: dict[tuple[str, ...], list[int]] = {}
        for i, res in enumerate(resolutions):
            key = plane.predictors[res.live].model_names
            by_group.setdefault(key, []).append(i)

        # per-call raw-score cache: (model group, request index) -> (K,) row.
        # Live and shadow dispatches sharing a model group reuse expert
        # outputs instead of re-running the models (shadow dedup).
        raw_cache: dict[tuple[tuple[str, ...], int], np.ndarray] = {}
        responses: list[ScoringResponse | None] = [None] * len(requests)
        for idxs in by_group.values():
            t0 = time.perf_counter()  # per-dispatch latency, not cumulative
            pred_names = [resolutions[i].live for i in idxs]
            raws = self.run_models(requests, idxs, pred_names, raw_cache,
                                   plane)
            scores, bank, tenant_idx = self.apply_transforms(
                raws, pred_names, plane)
            latency_ms = (time.perf_counter() - t0) * 1000.0
            built = self.build_responses(requests, idxs, pred_names, scores,
                                         raws, bank, self.routing.version,
                                         latency_ms)
            for i, resp in zip(idxs, built):
                responses[i] = resp
            self.track(requests, idxs, pred_names, raws, bank, tenant_idx)

        # shadow evaluations (never affect the response)
        self._run_shadows(requests, resolutions, raw_cache, plane)
        self.bump_metric("requests", len(requests))
        return responses  # type: ignore[return-value]

    def _run_shadows(self, requests, resolutions,
                     raw_cache: dict | None = None,
                     plane: _ControlPlane | None = None) -> None:
        # shadow rows are (request, shadow-predictor) pairs, grouped by the
        # shadow's model group and dispatched through the same staged path.
        # ``raw_cache`` carries the live dispatches' expert outputs: a shadow
        # sharing its request's live model group reuses them (no re-run).
        plane = self._plane if plane is None else plane
        by_group: dict[tuple[str, ...], tuple[list[int], list[str]]] = {}
        for i, res in enumerate(resolutions):
            for s in res.shadows:
                key = plane.predictors[s].model_names
                idxs, names = by_group.setdefault(key, ([], []))
                idxs.append(i)
                names.append(s)
        for idxs, shadow_names in by_group.values():
            raws = self.run_models(requests, idxs, shadow_names, raw_cache,
                                   plane)
            scores, _, _ = self.apply_transforms(raws, shadow_names, plane)
            self.write_shadow_records(requests, idxs, shadow_names, scores,
                                      raws, self.routing.version)

    # --------------------------------------------------------------- refresh
    def estimator_streams(self) -> dict[tuple[str, str],
                                        StreamingQuantileEstimator]:
        """Live (tenant, predictor) -> estimator map (control-plane view).

        Streams whose predictor has since been decommissioned are excluded —
        the calibration controller must never refit a dead pipeline.  The
        scan copies the dict first: the track stage may insert a stream for
        a newly seen (tenant, predictor) from another thread mid-scan.
        Under device tracking this is a host-pull boundary: staged samples
        materialize first, so the scan never reads a stale estimator."""
        if self._tracker is not None:
            with self._estimator_lock:
                self._sync_tracker_locked()
        return {k: est for k, est in dict(self._estimators).items()
                if k[1] in self.predictors}

    def snapshot_estimator_checkpoints(
        self) -> dict[tuple[str, str], tuple[dict, dict]]:
        """One consistent (tenant, predictor) -> (arrays, meta) snapshot.

        The fleet calibration plane's PULL endpoint: each live stream is
        captured in the exact PR-5 checkpoint serialization (reservoir +
        recent ring + RNG state), taken under the estimator lock so no
        stream pairs arrays with meta from different moments even while the
        track stage keeps appending.  The fleet controller merges these per
        key across replicas (``StreamingQuantileEstimator.merge_checkpoints``)
        and fits once on the union.  Streams of decommissioned predictors
        are excluded, same as :meth:`estimator_streams`.
        """
        live = self.predictors
        with self._estimator_lock:
            self._sync_tracker_locked()
            return {key: (est.checkpoint_arrays(), est.checkpoint_meta())
                    for key, est in self._estimators.items()
                    if key[1] in live}

    # ------------------------------------------------- estimator persistence
    def save_estimators(self, directory: str, step: int = 0) -> str:
        """Checkpoint every (tenant, predictor) estimator stream.

        Uses the ``training/checkpoint.py`` layout (flat npz + json meta):
        reservoir + recent-ring arrays land in ``arrays.npz`` under integer
        stream keys; tenants/predictors and scalar state (seen counts, ring
        pointers, RNG state) ride in ``meta.json``.  A surged replica
        restores this and starts PAST the Eq.-5 gate instead of cold.
        The whole snapshot is taken under the estimator lock, serialized
        with the track stage's reservoir updates — every stream's arrays
        and scalar state (seen count, ring pointer, RNG state) come from
        ONE consistent moment, never a torn mix.  Only the npz/json write
        happens outside the lock.
        """
        from repro.training.checkpoint import save_checkpoint

        with self._estimator_lock:
            self._sync_tracker_locked()
            snaps = [(key, est.checkpoint_arrays(), est.checkpoint_meta())
                     for key, est in sorted(self._estimators.items())]
        tree = {str(i): arrays for i, (_, arrays, _) in enumerate(snaps)}
        meta = {"streams": [
            {"tenant": t, "predictor": p, **m}
            for (t, p), _, m in snaps]}
        return save_checkpoint(directory, step, tree, metadata=meta)

    def restore_estimators(self, directory: str, step: int | None = None
                           ) -> int:
        """Restore streams saved by :meth:`save_estimators`; returns the
        number restored.  Existing streams with the same (tenant,
        predictor) key are replaced wholesale (the checkpoint is the
        warmer state)."""
        from repro.training.checkpoint import (
            latest_step,
            load_arrays,
            load_metadata,
        )

        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {directory}")
        meta = load_metadata(directory, step)
        specs = meta["streams"]
        # raw numpy leaves: the generic restore_checkpoint path round-trips
        # through jax arrays, which truncates float64 reservoirs to float32
        # without x64 enabled
        arrays = load_arrays(directory, step)
        with self._estimator_lock:
            # flush staged device samples into the OLD streams first: they
            # predate the restore decision and die with the replaced state
            # (the checkpoint is the warmer state) — they must never drain
            # into a freshly restored estimator later
            self._sync_tracker_locked()
            for i, m in enumerate(specs):
                est = StreamingQuantileEstimator.from_checkpoint(
                    {"buf": arrays[f"{i}/buf"],
                     "recent": arrays[f"{i}/recent"]}, m)
                self._estimators[(m["tenant"], m["predictor"])] = est
        return len(specs)

    def calibration_ready(self, tenant: str, predictor: str) -> bool:
        """Eq. 5 gate: enough live events for a trustworthy custom T^Q?

        A calibration host-pull boundary: staged device samples for the
        stream materialize before the gate reads the count."""
        key = (tenant, predictor)
        if self._tracker is not None and self._tracker.pending(key):
            with self._estimator_lock:
                self._sync_tracker_locked()
        est = self._estimators.get(key)
        return est is not None and est.ready(
            self.config.refresh_alert_rate, self.config.refresh_rel_error
        )

    def fit_custom_quantile_map(self, tenant: str, predictor: str,
                                ref_quantiles, n_levels: int = 256) -> QuantileMap:
        """Refresh path: fit T^Q_v1 from the live (unlabeled) score stream."""
        import jax.numpy as jnp
        if self._tracker is not None:
            with self._estimator_lock:
                self._sync_tracker_locked()
        est = self._estimators[(tenant, predictor)]
        levels = np.linspace(0.0, 1.0, n_levels)
        src = est.quantiles(levels)
        return QuantileMap(
            src_quantiles=jnp.asarray(src, jnp.float32),
            ref_quantiles=jnp.asarray(np.asarray(ref_quantiles), jnp.float32),
        )

    # ----------------------------------------------------- tiering control
    @property
    def prefetch_enabled(self) -> bool:
        """Whether the engine should prefetch pending windows' bank rows
        (true only under a tiered topology — prefetch is a no-op and pure
        overhead against fully-resident banks)."""
        return self.config.tiering is not None

    def tiered_stores(self) -> dict[tuple[str, ...], TieredBankStore]:
        """Snapshot of the live model-group -> tiered-store map."""
        with self._tier_lock:
            return dict(self._tiered_stores)

    def prefetch_transforms(self, pred_names, plane: Any = None, *,
                            create: bool = False) -> int:
        """Stage a pending window's cold bank rows into the victim cache
        BEFORE its transform stage dispatches (the engine's anti-stall
        hook).  ``create=False`` (the poll path) only touches stores that
        already exist — speculative window contents must not build a
        heavyweight store for a predictor subset that may never dispatch;
        the model stage passes ``create=True`` because ITS names-tuple is
        exactly what the transform stage will use.  Returns rows staged."""
        if self.config.tiering is None or not pred_names:
            return 0
        plane = self._plane if plane is None else plane
        names = tuple(sorted(set(pred_names)))
        if create:
            if any(n not in plane.predictors for n in names):
                return 0
            store = self._bank_for(names, plane).tiered
        else:
            with self._tier_lock:
                store = self._tiered_stores.get(names)
        if store is None:
            return 0
        row_of = {n: r for r, n in enumerate(names)}
        return store.prefetch(
            np.asarray([row_of[n] for n in pred_names], np.int64))

    def rebalance_tiers(self) -> dict[str, dict]:
        """Run one promotion/demotion/admission pass on every tiered store
        (the calibration controllers call this right after a publish so
        newly admitted tenants get real slots).  Returns per-group stats."""
        return {"+".join(k): s.rebalance()
                for k, s in self.tiered_stores().items()}

    def mark_cold_tenants(self, names) -> None:
        """Route these predictors through the cold-start prior until their
        streams re-pass the Eq.-5 gate (new-tenant onboarding: scores come
        from the fitted Beta-mixture default T^Q, not an uncalibrated row).
        Applies to live stores now and to stores built later."""
        names = set(names)
        self._cold_names |= names
        for key, store in self.tiered_stores().items():
            rows = [i for i, n in enumerate(key) if n in names]
            if rows:
                store.mark_cold(rows)

    def warm_tiers_from(self, other: Any) -> int:
        """Adopt a predecessor replica's hotness/admission state (rollout
        surge): for every model group the old replica served, build this
        replica's store, adopt the old hot statistics, and promote — the
        surged replica starts with a warm hot tier instead of paging its
        entire working set through the victim cache.  Returns the number
        of stores warmed."""
        if self.config.tiering is None:
            return 0
        source = getattr(other, "tiered_stores", None)
        if source is None:
            return 0
        plane = self._plane
        warmed = 0
        for names, theirs in source().items():
            if any(n not in plane.predictors for n in names):
                continue
            store = self._bank_for(names, plane).tiered
            if store is None:
                continue
            store.adopt_hotness(theirs.hotness_snapshot())
            store.rebalance()
            warmed += 1
        self._cold_names |= set(getattr(other, "_cold_names", ()))
        return warmed

    def tier_metrics(self) -> dict[str, int]:
        """Tiered-store counters aggregated across model groups."""
        agg: dict[str, int] = {}
        for store in self.tiered_stores().values():
            for k, v in store.metrics.items():
                agg[k] = agg.get(k, 0) + v
        return agg
