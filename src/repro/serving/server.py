"""MuseServer: the scoring data plane (paper Fig. 1).

Request path:  intent -> routing (live + shadows) -> feature enrichment ->
expert models -> T^C -> A -> T^Q -> response; shadow scores go to the sink.

The server is the *data plane*; control-plane operations (deploying
predictors, publishing routing tables, triggering calibration refreshes) are
explicit methods invoked by the rollout controller — never by clients.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.predictor import Predictor, PredictorSpec, deploy_predictor
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.registry import ModelPool
from repro.core.routing import Intent, RoutingTable
from repro.core.transforms import QuantileMap
from repro.serving.shadow import ShadowSink
from repro.serving.types import ScoringRequest, ScoringResponse, ShadowRecord


class FeatureStore:
    """Per-tenant derived-feature lookup (paper's 'Easy Feature Evolution').

    Models may require wider feature vectors than the client payload carries;
    the store supplies the model-specific derived features so new model
    versions deploy without client payload changes.
    """

    def __init__(self) -> None:
        self._store: dict[str, np.ndarray] = {}

    def put(self, tenant: str, derived: np.ndarray) -> None:
        self._store[tenant] = np.asarray(derived, np.float32)

    def enrich(self, intent: Intent, features: np.ndarray, target_dim: int
               ) -> np.ndarray:
        features = np.asarray(features, np.float32)
        if features.shape[-1] >= target_dim:
            return features[..., :target_dim]
        derived = self._store.get(intent.tenant)
        pad_width = target_dim - features.shape[-1]
        if derived is None:
            pad = np.zeros(features.shape[:-1] + (pad_width,), np.float32)
        else:
            reps = -(-pad_width // len(derived))
            pad = np.tile(derived, reps)[:pad_width]
            pad = np.broadcast_to(pad, features.shape[:-1] + (pad_width,))
        return np.concatenate([features, pad], axis=-1)


@dataclasses.dataclass
class ServerConfig:
    track_quantiles: bool = True
    quantile_capacity: int = 131072
    refresh_alert_rate: float = 0.01   # Eq. 5 gating for auto-refresh readiness
    refresh_rel_error: float = 0.2


class MuseServer:
    def __init__(self, routing: RoutingTable,
                 config: ServerConfig | None = None) -> None:
        self.pool = ModelPool()
        self.predictors: dict[str, Predictor] = {}
        self.routing = routing
        self.sink = ShadowSink()
        self.features = FeatureStore()
        self.config = config or ServerConfig()
        # per (tenant, predictor) streaming estimators for calibration refresh
        self._estimators: dict[tuple[str, str], StreamingQuantileEstimator] = {}
        self.metrics: dict[str, float] = {"requests": 0, "shadow_evals": 0}

    # ------------------------------------------------------------------ control
    def deploy(self, spec: PredictorSpec,
               model_factories: Mapping[str, Callable[[], Any]],
               model_costs: Mapping[str, float] | None = None) -> Predictor:
        pred = deploy_predictor(spec, self.pool, model_factories, model_costs)
        self.predictors[spec.name] = pred
        return pred

    def decommission(self, name: str) -> None:
        pred = self.predictors.pop(name)
        pred.release(self.pool)

    def publish_routing(self, table: RoutingTable) -> None:
        """Atomic routing swap — the transparent model switching primitive."""
        missing = [n for n in table.referenced_predictors()
                   if n not in self.predictors]
        if missing:
            raise KeyError(f"routing references undeployed predictors: {missing}")
        self.routing = table

    def swap_transformation(self, predictor_name: str, qm: QuantileMap) -> None:
        """T^Q_v0 -> T^Q_v1 without touching models (Sec. 3.1)."""
        pred = self.predictors[predictor_name]
        self.predictors[predictor_name] = pred.with_updated_pipeline(
            pred.pipeline.with_quantile_map(qm)
        )

    # ------------------------------------------------------------------- data
    def _model_dim(self, pred: Predictor) -> int:
        dims = [h.metadata.get("feature_dim") for h in pred._handles]
        dims = [d for d in dims if d]
        return max(dims) if dims else 0

    def _run(self, pred: Predictor, feats: np.ndarray
             ) -> tuple[np.ndarray, np.ndarray]:
        score, raw = pred.score_with_raw(feats)
        return np.asarray(score), np.asarray(raw)

    def score(self, request: ScoringRequest) -> ScoringResponse:
        return self.score_batch([request])[0]

    def score_batch(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        """Scores a batch sharing one intent-resolution each; groups by live
        predictor so a single executable call serves the group."""
        t0 = time.perf_counter()
        resolutions = [self.routing.resolve(r.intent) for r in requests]
        by_live: dict[str, list[int]] = {}
        for i, res in enumerate(resolutions):
            by_live.setdefault(res.live, []).append(i)

        responses: list[ScoringResponse | None] = [None] * len(requests)
        for live_name, idxs in by_live.items():
            pred = self.predictors[live_name]
            dim = self._model_dim(pred) or len(requests[idxs[0]].features)
            feats = np.stack([
                self.features.enrich(requests[i].intent, requests[i].features, dim)
                for i in idxs
            ])
            scores, raws = self._run(pred, feats)
            latency_ms = (time.perf_counter() - t0) * 1000.0
            for j, i in enumerate(idxs):
                responses[i] = ScoringResponse(
                    request_id=requests[i].request_id,
                    score=float(scores[j]),
                    predictor=live_name,
                    routing_version=self.routing.version,
                    latency_ms=latency_ms,
                    raw_scores=tuple(float(x) for x in np.atleast_1d(raws[j])),
                )
            self._track_quantiles(requests, idxs, raws, pred, live_name)

        # shadow evaluations (never affect the response)
        self._run_shadows(requests, resolutions)
        self.metrics["requests"] += len(requests)
        return responses  # type: ignore[return-value]

    def _track_quantiles(self, requests, idxs, raws, pred: Predictor,
                         live_name: str) -> None:
        if not self.config.track_quantiles:
            return
        # Track the T^Q INPUT distribution: the posterior-corrected weighted
        # aggregate — fitting a refreshed T^Q on raw means would mismatch
        # the pipeline (the bug class the paper's Sec.-3.1 update avoids).
        import jax.numpy as jnp
        agg = np.asarray(pred.pipeline.pre_quantile(jnp.atleast_2d(
            np.asarray(raws, np.float32))))
        for j, i in enumerate(idxs):
            key = (requests[i].intent.tenant, live_name)
            est = self._estimators.get(key)
            if est is None:
                import zlib
                est = StreamingQuantileEstimator(
                    self.config.quantile_capacity,
                    seed=zlib.crc32("/".join(key).encode()))
                self._estimators[key] = est
            est.update(np.asarray([agg[j]]))

    def _run_shadows(self, requests, resolutions) -> None:
        by_shadow: dict[str, list[int]] = {}
        for i, res in enumerate(resolutions):
            for s in res.shadows:
                by_shadow.setdefault(s, []).append(i)
        for shadow_name, idxs in by_shadow.items():
            pred = self.predictors[shadow_name]
            dim = self._model_dim(pred) or len(requests[idxs[0]].features)
            feats = np.stack([
                self.features.enrich(requests[i].intent, requests[i].features, dim)
                for i in idxs
            ])
            scores, raws = self._run(pred, feats)
            for j, i in enumerate(idxs):
                self.sink.write(ShadowRecord(
                    request_id=requests[i].request_id,
                    tenant=requests[i].intent.tenant,
                    predictor=shadow_name,
                    score=float(scores[j]),
                    raw_scores=tuple(float(x) for x in np.atleast_1d(raws[j])),
                    routing_version=self.routing.version,
                ))
                self.metrics["shadow_evals"] += 1

    # --------------------------------------------------------------- refresh
    def calibration_ready(self, tenant: str, predictor: str) -> bool:
        """Eq. 5 gate: enough live events for a trustworthy custom T^Q?"""
        est = self._estimators.get((tenant, predictor))
        return est is not None and est.ready(
            self.config.refresh_alert_rate, self.config.refresh_rel_error
        )

    def fit_custom_quantile_map(self, tenant: str, predictor: str,
                                ref_quantiles, n_levels: int = 256) -> QuantileMap:
        """Refresh path: fit T^Q_v1 from the live (unlabeled) score stream."""
        import jax.numpy as jnp
        est = self._estimators[(tenant, predictor)]
        levels = np.linspace(0.0, 1.0, n_levels)
        src = est.quantiles(levels)
        return QuantileMap(
            src_quantiles=jnp.asarray(src, jnp.float32),
            ref_quantiles=jnp.asarray(np.asarray(ref_quantiles), jnp.float32),
        )
