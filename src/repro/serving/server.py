"""MuseServer: the scoring data plane (paper Fig. 1).

Request path:  intent -> routing (live + shadows) -> feature enrichment ->
expert models -> T^C -> A -> T^Q -> response; shadow scores go to the sink.

A mixed-tenant micro-batch is grouped by *model group* (the predictor's
expert-model set): one model executable call produces raw scores for the
whole group, and one tenant-indexed banked kernel dispatch
(:func:`repro.kernels.ops.score_pipeline_banked`) applies every predictor's
T^C/A/T^Q in a single ``pallas_call`` — no per-predictor Python loop.

The server is the *data plane*; control-plane operations (deploying
predictors, publishing routing tables, triggering calibration refreshes) are
explicit methods invoked by the rollout controller — never by clients.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Callable, Mapping

import jax.numpy as jnp
import numpy as np

from repro.core.predictor import Predictor, PredictorSpec, deploy_predictor
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.registry import ModelPool
from repro.core.routing import Intent, RoutingTable
from repro.core.transforms import QuantileMap, TransformBank
from repro.kernels import ops
from repro.serving.shadow import ShadowSink
from repro.serving.types import ScoringRequest, ScoringResponse, ShadowRecord


class FeatureStore:
    """Per-tenant derived-feature lookup (paper's 'Easy Feature Evolution').

    Models may require wider feature vectors than the client payload carries;
    the store supplies the model-specific derived features so new model
    versions deploy without client payload changes.
    """

    def __init__(self) -> None:
        self._store: dict[str, np.ndarray] = {}

    def put(self, tenant: str, derived: np.ndarray) -> None:
        self._store[tenant] = np.asarray(derived, np.float32)

    def enrich(self, intent: Intent, features: np.ndarray, target_dim: int
               ) -> np.ndarray:
        features = np.asarray(features, np.float32)
        if features.shape[-1] >= target_dim:
            return features[..., :target_dim]
        derived = self._store.get(intent.tenant)
        pad_width = target_dim - features.shape[-1]
        if derived is None:
            pad = np.zeros(features.shape[:-1] + (pad_width,), np.float32)
        else:
            reps = -(-pad_width // len(derived))
            pad = np.tile(derived, reps)[:pad_width]
            pad = np.broadcast_to(pad, features.shape[:-1] + (pad_width,))
        return np.concatenate([features, pad], axis=-1)


@dataclasses.dataclass
class ServerConfig:
    track_quantiles: bool = True
    quantile_capacity: int = 131072
    refresh_alert_rate: float = 0.01   # Eq. 5 gating for auto-refresh readiness
    refresh_rel_error: float = 0.2
    # fused tenant-indexed Pallas dispatch; False falls back to the pure-jnp
    # banked oracle (same semantics, no pallas_call)
    fused_kernel: bool = True


@dataclasses.dataclass(frozen=True)
class _BankEntry:
    """A cached model-group bank pinned to the pipelines it was built from.

    ``pipelines`` is the identity witness: a ``publish_quantile_maps`` /
    redeploy replaces pipeline objects, so a stale entry fails the identity
    check and is rebuilt.  The bank itself carries the generation it was
    published under (see :class:`~repro.core.transforms.TransformBank`)."""

    pipelines: tuple[Any, ...]
    bank: TransformBank


class MuseServer:
    def __init__(self, routing: RoutingTable,
                 config: ServerConfig | None = None) -> None:
        self.pool = ModelPool()
        self.predictors: dict[str, Predictor] = {}
        self.routing = routing
        self.sink = ShadowSink()
        self.features = FeatureStore()
        self.config = config or ServerConfig()
        # per (tenant, predictor) streaming estimators for calibration refresh
        self._estimators: dict[tuple[str, str], StreamingQuantileEstimator] = {}
        # model-group transform banks, keyed by ordered predictor names.
        # The dict REFERENCE is swapped wholesale on a calibration publish
        # (never mutated row-by-row across a publish): a dispatch snapshots
        # it once, so an in-flight window finishes on the old generation and
        # the next window sees the new one — no torn reads.
        self._banks: dict[tuple[str, ...], _BankEntry] = {}
        self._bank_generation = 0
        self.metrics: dict[str, float] = {
            "requests": 0, "shadow_evals": 0, "kernel_dispatches": 0,
            "model_group_calls": 0, "model_calls": 0, "bank_generation": 0}

    @property
    def bank_generation(self) -> int:
        """Monotone counter of atomic calibration publishes."""
        return self._bank_generation

    # ------------------------------------------------------------------ control
    def deploy(self, spec: PredictorSpec,
               model_factories: Mapping[str, Callable[[], Any]],
               model_costs: Mapping[str, float] | None = None) -> Predictor:
        pred = deploy_predictor(spec, self.pool, model_factories, model_costs)
        self.predictors[spec.name] = pred
        return pred

    def decommission(self, name: str) -> None:
        pred = self.predictors.pop(name)
        pred.release(self.pool)
        # drop cached banks referencing the dead predictor's pipeline
        self._banks = {k: v for k, v in self._banks.items() if name not in k}
        # and its estimator streams: a future predictor redeployed under the
        # same name has a different score distribution — refitting T^Q from
        # the dead model's stream would publish a miscalibrated map
        self._estimators = {k: v for k, v in self._estimators.items()
                            if k[1] != name}

    def publish_routing(self, table: RoutingTable) -> None:
        """Atomic routing swap — the transparent model switching primitive."""
        missing = [n for n in table.referenced_predictors()
                   if n not in self.predictors]
        if missing:
            raise KeyError(f"routing references undeployed predictors: {missing}")
        self.routing = table

    def swap_transformation(self, predictor_name: str, qm: QuantileMap) -> None:
        """T^Q_v0 -> T^Q_v1 without touching models (Sec. 3.1)."""
        self.publish_quantile_maps({predictor_name: qm})

    def publish_quantile_maps(self, updates: Mapping[str, QuantileMap]) -> int:
        """Atomically publish refreshed T^Q maps for MANY predictors at once.

        The fleet-wide calibration refresh (Sec. 3.1, `serving/calibration.py`)
        lands here: every updated predictor pipeline AND every affected
        model-group bank is rebuilt first, then the ``predictors`` / ``_banks``
        references are swapped in one step under a bumped generation.  A
        dispatch that already snapshotted the old structures finishes on the
        old parameters; the next window sees the complete new generation —
        a batch can never mix rows from two calibration versions.

        Returns the new bank generation.
        """
        missing = [n for n in updates if n not in self.predictors]
        if missing:
            raise KeyError(f"unknown predictors: {missing}")
        if not updates:
            return self._bank_generation
        gen = self._bank_generation + 1

        new_predictors = dict(self.predictors)
        for name, qm in updates.items():
            pred = new_predictors[name]
            new_predictors[name] = pred.with_updated_pipeline(
                pred.pipeline.with_quantile_map(qm))

        new_banks: dict[tuple[str, ...], _BankEntry] = {}
        for key, entry in self._banks.items():
            touched = {i: updates[n] for i, n in enumerate(key) if n in updates}
            if not touched:
                new_banks[key] = entry
                continue
            pipelines = tuple(new_predictors[n].pipeline for n in key)
            # the with_rows fast path (scatter only the refreshed T^Q rows)
            # is sound only if the cached bank was built from the predictors'
            # CURRENT pipelines; a predictor redeployed in place leaves a
            # stale entry whose other rows carry the dead pipeline's T^C/A —
            # patching and re-pinning it would serve stale parameters forever
            entry_fresh = len(entry.pipelines) == len(key) and all(
                ep is self.predictors[n].pipeline
                for ep, n in zip(entry.pipelines, key))
            bank = None
            if entry_fresh:
                try:
                    bank = entry.bank.with_rows(touched, generation=gen)
                except ValueError:
                    pass  # a refreshed table wider than the bank
            if bank is None:
                bank = TransformBank.from_params(
                    [(p.betas, p.weights, p.src_quantiles, p.ref_quantiles)
                     for p in pipelines], generation=gen)
            new_banks[key] = _BankEntry(pipelines, bank)

        # the publish point: whole-reference swaps, never in-place edits
        self.predictors = new_predictors
        self._banks = new_banks
        self._bank_generation = gen
        self.metrics["bank_generation"] = gen
        return gen

    # ------------------------------------------------------------------- data
    def _model_dim(self, pred: Predictor) -> int:
        dims = [h.metadata.get("feature_dim") for h in pred._handles]
        dims = [d for d in dims if d]
        return max(dims) if dims else 0

    def batch_key(self, intent: Intent) -> str:
        """Micro-batching key: the resolved predictor's model group.

        Requests from different tenants/predictors that share the same
        expert-model set batch together — one executable call plus one
        banked kernel dispatch serves the whole window."""
        pred = self.predictors[self.routing.resolve(intent).live]
        return "+".join(pred.model_names)

    def _bank_for(self, names: tuple[str, ...],
                  predictors: dict[str, Predictor] | None = None,
                  banks: dict[tuple[str, ...], _BankEntry] | None = None,
                  ) -> TransformBank:
        """Build (or fetch) the stacked transform bank for these predictors.

        Cache entries pin the source pipelines; a ``publish_quantile_maps`` /
        redeploy replaces the pipeline object, failing the identity check
        and rebuilding the bank — banks never serve stale parameters.
        ``predictors``/``banks`` are the dispatch-time snapshots; lookups go
        through them so a concurrent publish can't produce a torn read."""
        predictors = self.predictors if predictors is None else predictors
        banks = self._banks if banks is None else banks
        pipelines = tuple(predictors[n].pipeline for n in names)
        cached = banks.get(names)
        if cached is not None and len(cached.pipelines) == len(pipelines) \
                and all(a is b for a, b in zip(cached.pipelines, pipelines)):
            return cached.bank
        bank = TransformBank.from_params(
            [(p.betas, p.weights, p.src_quantiles, p.ref_quantiles)
             for p in pipelines], generation=self._bank_generation)
        banks[names] = _BankEntry(pipelines, bank)
        return bank

    def score(self, request: ScoringRequest) -> ScoringResponse:
        return self.score_batch([request])[0]

    def score_batch(self, requests: list[ScoringRequest]) -> list[ScoringResponse]:
        """Scores a mixed-tenant batch: requests are grouped by model group
        (shared expert-model set); each group costs one model executable
        call plus ONE tenant-indexed banked kernel dispatch, whatever mix of
        tenants and predictors the group contains."""
        # dispatch-time snapshots: a publish swaps these references, so the
        # whole batch (live + shadows) scores against ONE consistent
        # generation even if a refresh lands mid-flight
        predictors = self.predictors
        banks = self._banks
        resolutions = [self.routing.resolve(r.intent) for r in requests]
        by_group: dict[tuple[str, ...], list[int]] = {}
        for i, res in enumerate(resolutions):
            key = predictors[res.live].model_names
            by_group.setdefault(key, []).append(i)

        # per-call raw-score cache: (model group, request index) -> (K,) row.
        # Live and shadow dispatches sharing a model group reuse expert
        # outputs instead of re-running the models (shadow dedup).
        raw_cache: dict[tuple[tuple[str, ...], int], np.ndarray] = {}
        responses: list[ScoringResponse | None] = [None] * len(requests)
        for idxs in by_group.values():
            t0 = time.perf_counter()  # per-dispatch latency, not cumulative
            pred_names = [resolutions[i].live for i in idxs]
            scores, raws, bank, tenant_idx = self._dispatch_banked(
                requests, idxs, pred_names, raw_cache, predictors, banks)
            latency_ms = (time.perf_counter() - t0) * 1000.0
            for j, i in enumerate(idxs):
                responses[i] = ScoringResponse(
                    request_id=requests[i].request_id,
                    score=float(scores[j]),
                    predictor=pred_names[j],
                    routing_version=self.routing.version,
                    latency_ms=latency_ms,
                    raw_scores=tuple(float(x) for x in np.atleast_1d(raws[j])),
                )
            self._track_quantiles(requests, idxs, pred_names, raws, bank,
                                  tenant_idx)

        # shadow evaluations (never affect the response)
        self._run_shadows(requests, resolutions, raw_cache, predictors, banks)
        self.metrics["requests"] += len(requests)
        return responses  # type: ignore[return-value]

    def _dispatch_banked(
        self, requests, idxs: list[int], pred_names: list[str],
        raw_cache: dict[tuple[tuple[str, ...], int], np.ndarray] | None = None,
        predictors: dict[str, Predictor] | None = None,
        banks: dict[tuple[str, ...], _BankEntry] | None = None,
    ) -> tuple[np.ndarray, np.ndarray, TransformBank, np.ndarray]:
        """One model-group dispatch: raw scores from the shared expert models,
        then the whole (possibly multi-predictor) group through one banked
        kernel call.  ``pred_names[j]`` is the predictor for row ``j``."""
        predictors = self.predictors if predictors is None else predictors
        bank_names = tuple(sorted(set(pred_names)))  # canonical cache key
        bank = self._bank_for(bank_names, predictors, banks)
        row_of = {n: r for r, n in enumerate(bank_names)}
        pred0 = predictors[bank_names[0]]
        group = pred0.model_names
        dim = self._model_dim(pred0) or len(requests[idxs[0]].features)
        rows: list[np.ndarray | None] = [None] * len(idxs)
        fresh = list(range(len(idxs)))
        if raw_cache is not None:
            fresh = []
            for j, i in enumerate(idxs):
                hit = raw_cache.get((group, i))
                if hit is None:
                    fresh.append(j)
                else:
                    rows[j] = hit
        if fresh:
            feats = np.stack([
                self.features.enrich(requests[idxs[j]].intent,
                                     requests[idxs[j]].features, dim)
                for j in fresh
            ])
            computed = np.asarray(pred0.raw_scores(feats))   # (len(fresh), K)
            self.metrics["model_group_calls"] += 1
            self.metrics["model_calls"] += len(group)
            for r, j in enumerate(fresh):
                rows[j] = computed[r]
                if raw_cache is not None:
                    raw_cache[(group, idxs[j])] = computed[r]
        raws = np.stack(rows)                                # (B, K)
        tenant_idx = np.asarray([row_of[n] for n in pred_names], np.int32)
        if self.config.fused_kernel:
            scores = ops.score_pipeline_banked(
                jnp.asarray(raws, jnp.float32), jnp.asarray(tenant_idx),
                bank.betas, bank.weights,
                bank.src_quantiles, bank.ref_quantiles)
        else:
            scores = bank(jnp.asarray(raws, jnp.float32),
                          jnp.asarray(tenant_idx))
        self.metrics["kernel_dispatches"] += 1
        return np.asarray(scores), np.asarray(raws), bank, tenant_idx

    def _track_quantiles(self, requests, idxs, pred_names, raws,
                         bank: TransformBank, tenant_idx) -> None:
        if not self.config.track_quantiles:
            return
        # Track the T^Q INPUT distribution: the posterior-corrected weighted
        # aggregate — fitting a refreshed T^Q on raw means would mismatch
        # the pipeline (the bug class the paper's Sec.-3.1 update avoids).
        agg = np.asarray(bank.pre_quantile(
            jnp.asarray(raws, jnp.float32), jnp.asarray(tenant_idx)))
        by_stream: dict[tuple[str, str], list[int]] = {}
        for j, i in enumerate(idxs):
            key = (requests[i].intent.tenant, pred_names[j])
            by_stream.setdefault(key, []).append(j)
        # one batched reservoir update per (tenant, predictor) stream
        for key, rows in by_stream.items():
            est = self._estimators.get(key)
            if est is None:
                est = StreamingQuantileEstimator(
                    self.config.quantile_capacity,
                    seed=zlib.crc32("/".join(key).encode()))
                self._estimators[key] = est
            est.update(agg[rows])

    def _run_shadows(self, requests, resolutions,
                     raw_cache: dict | None = None,
                     predictors: dict[str, Predictor] | None = None,
                     banks: dict[tuple[str, ...], _BankEntry] | None = None,
                     ) -> None:
        # shadow rows are (request, shadow-predictor) pairs, grouped by the
        # shadow's model group and dispatched through the same banked path.
        # ``raw_cache`` carries the live dispatches' expert outputs: a shadow
        # sharing its request's live model group reuses them (no re-run).
        predictors = self.predictors if predictors is None else predictors
        by_group: dict[tuple[str, ...], tuple[list[int], list[str]]] = {}
        for i, res in enumerate(resolutions):
            for s in res.shadows:
                key = predictors[s].model_names
                idxs, names = by_group.setdefault(key, ([], []))
                idxs.append(i)
                names.append(s)
        for idxs, shadow_names in by_group.values():
            scores, raws, _, _ = self._dispatch_banked(
                requests, idxs, shadow_names, raw_cache, predictors, banks)
            for j, i in enumerate(idxs):
                self.sink.write(ShadowRecord(
                    request_id=requests[i].request_id,
                    tenant=requests[i].intent.tenant,
                    predictor=shadow_names[j],
                    score=float(scores[j]),
                    raw_scores=tuple(float(x) for x in np.atleast_1d(raws[j])),
                    routing_version=self.routing.version,
                ))
                self.metrics["shadow_evals"] += 1

    # --------------------------------------------------------------- refresh
    def estimator_streams(self) -> dict[tuple[str, str],
                                        StreamingQuantileEstimator]:
        """Live (tenant, predictor) -> estimator map (control-plane view).

        Streams whose predictor has since been decommissioned are excluded —
        the calibration controller must never refit a dead pipeline."""
        return {k: est for k, est in self._estimators.items()
                if k[1] in self.predictors}

    def calibration_ready(self, tenant: str, predictor: str) -> bool:
        """Eq. 5 gate: enough live events for a trustworthy custom T^Q?"""
        est = self._estimators.get((tenant, predictor))
        return est is not None and est.ready(
            self.config.refresh_alert_rate, self.config.refresh_rel_error
        )

    def fit_custom_quantile_map(self, tenant: str, predictor: str,
                                ref_quantiles, n_levels: int = 256) -> QuantileMap:
        """Refresh path: fit T^Q_v1 from the live (unlabeled) score stream."""
        import jax.numpy as jnp
        est = self._estimators[(tenant, predictor)]
        levels = np.linspace(0.0, 1.0, n_levels)
        src = est.quantiles(levels)
        return QuantileMap(
            src_quantiles=jnp.asarray(src, jnp.float32),
            ref_quantiles=jnp.asarray(np.asarray(ref_quantiles), jnp.float32),
        )
