"""Shadow-scoring sink — the paper's Data Lake for offline evaluation.

Shadow predictors are evaluated on live traffic; their responses are stored
here and never returned to the client (Sec. 2.5.1).  The sink doubles as the
source for offline T^Q fitting and pre-promotion validation (Sec. 3.1).
"""
from __future__ import annotations

import collections
from typing import Iterable

import numpy as np

from repro.serving.types import ShadowRecord


class ShadowSink:
    def __init__(self) -> None:
        self._records: list[ShadowRecord] = []
        self._by_predictor: dict[str, list[ShadowRecord]] = collections.defaultdict(list)

    def write(self, record: ShadowRecord) -> None:
        self._records.append(record)
        self._by_predictor[record.predictor].append(record)

    def write_all(self, records: Iterable[ShadowRecord]) -> None:
        for r in records:
            self.write(r)

    def __len__(self) -> int:
        return len(self._records)

    def records(self, predictor: str | None = None) -> list[ShadowRecord]:
        if predictor is None:
            return list(self._records)
        return list(self._by_predictor.get(predictor, ()))

    def scores(self, predictor: str, tenant: str | None = None) -> np.ndarray:
        recs = self._by_predictor.get(predictor, ())
        return np.array([
            r.score for r in recs if tenant is None or r.tenant == tenant
        ])

    def raw_aggregated_scores(self, predictor: str,
                              tenant: str | None = None) -> np.ndarray:
        """Pre-T^Q aggregated scores — the input for fitting a refreshed T^Q."""
        recs = self._by_predictor.get(predictor, ())
        out = []
        for r in recs:
            if tenant is None or r.tenant == tenant:
                out.append(float(np.mean(r.raw_scores)) if r.raw_scores else r.score)
        return np.array(out)
