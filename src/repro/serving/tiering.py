"""Tiered tenant-bank store: hot device rows, host-paged cold rows, priors.

The fully-resident bank (dense or sharded) is the wall past ~10^5 tenants:
every (tenant, predictor) transform row costs ``(2K+2N)·4`` device bytes
*somewhere*, forever.  This module breaks that coupling with a three-tier
store in which device residency is bounded by CONFIGURATION, not by tenant
count:

  * **hot tier** — the ``hot_capacity`` hottest tenants' rows live in a
    device bank (the same ``TransformBank`` row layout today's banked
    kernel dispatches against) and are only ever moved by an explicit
    control-plane :meth:`TieredBankStore.rebalance`;
  * **victim cache** — a bounded ``victim_capacity``-slot device ring
    where cold tenants' rows are staged on demand (clock eviction).  The
    async engine prefetches pending windows' rows into it
    (:meth:`TieredBankStore.prefetch`) so the dispatch hot path normally
    never blocks on a host read; a miss that *does* reach dispatch is
    staged synchronously and counted as a ``cold_miss_stall``;
  * **cold-start prior** — tenants that have not yet passed the Eq.-5
    sample-size gate (paper Sec. 2.4) score through ONE shared prior row
    (Beta-mixture default quantiles, Eqs. 6–8, ``core/coldstart.py``)
    pinned in the last device slot.  Once a tenant's observed stream
    reaches ``required_sample_size(a, δ, z)`` events, the next
    ``rebalance`` admits it to its own (host-stored) row.

The authoritative copy of EVERY row is the host-memory
:class:`HostBankStore` (numpy — ~272 bytes/row at K=2, N=32, so 10^6
tenants fit in a few hundred MB of RAM); the device bank holds exactly
``hot_capacity + victim_capacity + 1`` rows regardless of tenant count.
A dispatch maps tenant ids to device SLOTS and runs the same fused banked
kernel (``kernels/ops.score_pipeline_banked``) as the dense path — per-row
compute is independent of bank size and row order, so tiered scores match
a dense bank built from the same rows BITWISE on f32 (asserted in
``tests/test_tiering.py``).

Generations and atomicity
-------------------------

The store carries the same generation discipline as the control plane:

  * :meth:`apply_updates` is the publish endpoint.  It writes refreshed
    T^Q tables into the host rows AND scatters every device-resident copy
    (hot, victim, either tier) in ONE locked operation under ONE bumped
    generation — a post-publish read of any tenant, hot or cold or
    freshly promoted, serves the new generation's parameters.  Fenced
    (``generation=``) updates reject non-strictly-newer stamps with
    :class:`~repro.serving.types.StaleGenerationError`, exactly like
    ``MuseServer.publish_quantile_maps``; an empty fenced update is a
    generation fast-forward.
  * :meth:`rebalance` (promotion / demotion / Eq.-5 admission) is fenced
    the other way: a caller may pass the generation its decision was
    computed against, and a stamp OLDER than the store's current
    generation is rejected — a superseded control pass cannot reshuffle
    tiers it no longer understands.  Rebalance never changes row VALUES,
    so it never bumps the generation.

Every read/write of the mutable tier state (slot maps, hotness, seen
counts, the immutable :class:`_TierView` reference) happens under one
internal lock; the view itself is immutable and swapped by reference, so
a dispatch is internally consistent by construction.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.hotness import HotnessTracker
from repro.core.quantiles import required_sample_size
from repro.core.transforms import (
    QuantileMap,
    TransformBank,
    banked_score_pipeline,
    pad_quantile_tables,
)
from repro.kernels import ops
from repro.serving.types import StaleGenerationError


def _shape_bucket(n: int) -> int:
    """Next power of two >= n (same bucketing as the server's dispatch:
    bounded XLA specializations, one per bucket)."""
    b = 1
    while b < n:
        b *= 2
    return b


def prior_bank_row(
    prior: Any,
    ref_quantiles: np.ndarray,
    num_experts: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The shared cold-start device row from a fitted Beta-mixture prior.

    ``prior`` is a :class:`~repro.core.coldstart.BetaMixtureFit` (anything
    with ``.quantiles(levels)``) or a raw source-quantile table.  T^C is
    the identity (beta=1 — the prior already models the *corrected* score
    distribution on the training data) and aggregation is uniform; T^Q
    maps the fitted prior's quantiles onto the reference, i.e. the paper's
    ``T^Q_{v0}`` (Sec. 2.4) as one bank row.
    """
    ref = np.asarray(ref_quantiles, np.float64).ravel()
    if hasattr(prior, "quantiles"):
        src = np.asarray(prior.quantiles(np.linspace(0.0, 1.0, len(ref))))
    else:
        src = np.asarray(prior, np.float64).ravel()
        if len(src) != len(ref):
            src = np.interp(np.linspace(0.0, 1.0, len(ref)),
                            np.linspace(0.0, 1.0, len(src)), src)
    return (np.ones(num_experts, np.float32),
            np.ones(num_experts, np.float32),
            np.maximum.accumulate(src).astype(np.float32),
            np.asarray(ref, np.float32))


@dataclasses.dataclass
class TieringConfig:
    """Capacity + gating knobs for one :class:`TieredBankStore`.

    ``prior`` (optional) is the cold-start row — a
    ``(betas, weights, src_quantiles, ref_quantiles)`` tuple, typically
    from :func:`prior_bank_row`.  Without it the prior slot is the
    identity map and the Eq.-5 admission gate only matters for rows
    explicitly marked cold.
    """

    hot_capacity: int = 1024
    victim_capacity: int = 128
    decay: float = 0.98               # hotness decay per rebalance window
    gate_alert_rate: float = 0.01     # Eq. 5 target alert rate ``a``
    gate_rel_error: float = 0.2       # Eq. 5 relative error ``delta``
    gate_z: float = 1.96              # Eq. 5 confidence (95%)
    fused_kernel: bool = True         # banked Pallas kernel vs jnp oracle
    prior: tuple | None = None

    def __post_init__(self) -> None:
        if self.hot_capacity < 1:
            raise ValueError("hot_capacity must be >= 1")
        if self.victim_capacity < 1:
            raise ValueError("victim_capacity must be >= 1")


class HostBankStore:
    """Host-memory (numpy) authoritative store of EVERY tenant's bank row.

    Plain contiguous float32 arrays — ``(T, K)`` betas/weights and
    ``(T, N)`` quantile tables — written in place only under the owning
    :class:`TieredBankStore`'s lock.  ``admitted`` marks rows past the
    Eq.-5 gate; un-admitted tenants score through the shared prior slot
    regardless of what their host row holds.
    """

    def __init__(self, betas: np.ndarray, weights: np.ndarray,
                 src_quantiles: np.ndarray, ref_quantiles: np.ndarray,
                 admitted: np.ndarray | None = None) -> None:
        # np.array (not asarray): rows handed in may be read-only views of
        # jax buffers, and write_rows mutates these in place
        self.betas = np.array(betas, np.float32, order="C")
        self.weights = np.array(weights, np.float32, order="C")
        self.src_quantiles = np.array(src_quantiles, np.float32, order="C")
        self.ref_quantiles = np.array(ref_quantiles, np.float32, order="C")
        t = self.betas.shape[0]
        for arr, name in ((self.weights, "weights"),
                          (self.src_quantiles, "src_quantiles"),
                          (self.ref_quantiles, "ref_quantiles")):
            if arr.shape[0] != t:
                raise ValueError(f"{name} has {arr.shape[0]} rows, betas {t}")
        self.admitted = (np.ones(t, bool) if admitted is None
                         else np.asarray(admitted, bool).copy())

    # ------------------------------------------------------------- geometry
    @property
    def num_rows(self) -> int:
        return int(self.betas.shape[0])

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    @property
    def num_quantiles(self) -> int:
        return int(self.src_quantiles.shape[-1])

    @property
    def nbytes(self) -> int:
        """Host bytes of the row arrays (the O(total tenants) cost that
        tiering moves OFF the device)."""
        return (self.betas.nbytes + self.weights.nbytes
                + self.src_quantiles.nbytes + self.ref_quantiles.nbytes)

    # ------------------------------------------------------------- builders
    @staticmethod
    def from_rows(
        params: Sequence[tuple],
        admitted: np.ndarray | None = None,
    ) -> "HostBankStore":
        """Stack ragged ``(betas, weights, src_q, ref_q)`` rows, padding the
        expert axis with (beta=1, weight=0) columns and quantile tables
        edge-wise — the same semantics-preserving padding as
        :meth:`TransformBank.from_params`, so a dense bank built from the
        same params is row-for-row identical."""
        bank = TransformBank.from_params(params)
        return HostBankStore(
            np.asarray(bank.betas), np.asarray(bank.weights),
            np.asarray(bank.src_quantiles), np.asarray(bank.ref_quantiles),
            admitted)

    @staticmethod
    def from_bank(bank: TransformBank,
                  admitted: np.ndarray | None = None) -> "HostBankStore":
        return HostBankStore(
            np.asarray(bank.betas), np.asarray(bank.weights),
            np.asarray(bank.src_quantiles), np.asarray(bank.ref_quantiles),
            admitted)

    # --------------------------------------------------------------- access
    def rows(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                             np.ndarray, np.ndarray]:
        ids = np.asarray(ids, np.int64)
        return (self.betas[ids], self.weights[ids],
                self.src_quantiles[ids], self.ref_quantiles[ids])

    def write_rows(
        self,
        updates: Mapping[int, "QuantileMap | tuple"],
    ) -> np.ndarray:
        """In-place T^Q table replacement for the given rows (the publish
        write path — caller holds the tier lock).  Narrow tables are
        edge-padded exactly like the bank ``with_rows`` scatters.  Returns
        the updated row ids."""
        ids = []
        n = self.num_quantiles
        for row, value in sorted(updates.items()):
            if not 0 <= row < self.num_rows:
                raise IndexError(f"row {row} outside store of {self.num_rows}")
            src, ref = pad_quantile_tables(value, n, row=row)
            self.src_quantiles[row] = np.asarray(src)
            self.ref_quantiles[row] = np.asarray(ref)
            ids.append(row)
        return np.asarray(ids, np.int64)

    def dense_bank(self, generation: int = 0) -> TransformBank:
        """The dense bank these rows describe (parity oracle for tests)."""
        return TransformBank(
            betas=jnp.asarray(self.betas), weights=jnp.asarray(self.weights),
            src_quantiles=jnp.asarray(self.src_quantiles),
            ref_quantiles=jnp.asarray(self.ref_quantiles),
            generation=generation)


@dataclasses.dataclass(frozen=True)
class _TierView:
    """One immutable device-bank snapshot a dispatch scores against.

    ``hot_capacity + victim_capacity + 1`` rows: hot slots, victim slots,
    then the pinned prior row.  Swapped by reference under the store lock
    (staging, rebalance, publish); a dispatch that captured a view scores
    every row of its window against exactly one generation.
    """

    betas: Any            # (R, K) jax
    weights: Any          # (R, K)
    src_quantiles: Any    # (R, N)
    ref_quantiles: Any    # (R, N)
    generation: int

    @property
    def nbytes(self) -> int:
        r = int(self.betas.shape[0])
        k = int(self.betas.shape[-1])
        n = int(self.src_quantiles.shape[-1])
        return r * (2 * k + 2 * n) * 4


class TieredBankStore:
    """Hot/victim/prior tiered serving view over a :class:`HostBankStore`.

    See the module docstring for the tier model.  All public methods are
    thread-safe; ``dispatch`` holds the store lock across its kernel
    call(s) so the (slot map, device view) pair it scores with is
    consistent and each window serves under one generation — publishes
    from another thread land before or after a window, never inside it.
    """

    def __init__(self, host: HostBankStore,
                 config: TieringConfig | None = None, *,
                 generation: int = 0) -> None:
        self.host = host
        self.config = config or TieringConfig()
        t = host.num_rows
        self._hot = min(self.config.hot_capacity, t)
        self._victims = self.config.victim_capacity
        self._prior_slot = self._hot + self._victims
        self._gate_n = required_sample_size(
            self.config.gate_alert_rate, self.config.gate_rel_error,
            self.config.gate_z)
        self.tracker = HotnessTracker(t, self.config.decay)
        self._seen = np.zeros(t, np.int64)
        self._slot_of = np.full(t, -1, np.int32)   # -1 = not device-resident
        self._owner = np.full(self._prior_slot, -1, np.int64)
        self._hand = 0                             # victim clock hand
        # identity witness for the serving layer's bank cache (which
        # pipelines this store's host rows were built from); opaque here
        self.source_pipelines: tuple | None = None
        k, n = host.num_experts, host.num_quantiles
        rows = self._prior_slot + 1
        betas = np.ones((rows, k), np.float32)
        weights = np.ones((rows, k), np.float32)
        ident = np.linspace(0.0, 1.0, n, dtype=np.float32)
        src = np.broadcast_to(ident, (rows, n)).copy()
        ref = src.copy()
        if self.config.prior is not None:
            pb, pw, ps, pr = self.config.prior
            betas[-1] = np.asarray(pb, np.float32)
            weights[-1] = np.asarray(pw, np.float32)
            ps, pr = pad_quantile_tables(
                (np.asarray(ps), np.asarray(pr)), n)
            src[-1] = np.asarray(ps)
            ref[-1] = np.asarray(pr)
        self._view = _TierView(
            jnp.asarray(betas), jnp.asarray(weights),
            jnp.asarray(src), jnp.asarray(ref), generation)
        self._lock = threading.Lock()
        self.metrics: dict[str, int] = {
            "dispatches": 0, "events": 0, "hot_hits": 0, "victim_hits": 0,
            "prior_scores": 0, "cold_miss_stalls": 0, "stalled_events": 0,
            "staged_rows": 0, "prefetched_rows": 0, "extra_passes": 0,
            "promotions": 0, "demotions": 0, "admissions": 0, "updates": 0,
        }

    # ------------------------------------------------------------- geometry
    @property
    def num_rows(self) -> int:
        return self.host.num_rows

    @property
    def hot_capacity(self) -> int:
        return self._hot

    @property
    def victim_capacity(self) -> int:
        return self._victims

    @property
    def generation(self) -> int:
        return self._view.generation

    @property
    def gate_samples(self) -> int:
        """Eq.-5 sample count a tenant's stream needs for admission."""
        return self._gate_n

    @property
    def device_bytes(self) -> int:
        """Device-resident bank bytes — a function of CONFIGURED capacity
        (hot + victim + prior row), independent of ``num_rows``."""
        return self._view.nbytes

    @property
    def host_bytes(self) -> int:
        return self.host.nbytes

    def hot_rows(self) -> np.ndarray:
        """Tenant ids currently in the hot tier (unordered)."""
        with self._lock:
            owners = self._owner[:self._hot]
            return owners[owners >= 0].copy()

    def resident_rows(self) -> np.ndarray:
        """Tenant ids device-resident in either tier (unordered)."""
        with self._lock:
            return self._owner[self._owner >= 0].copy()

    # --------------------------------------------------------------- private
    def _effective_slots(self, tid: np.ndarray) -> np.ndarray:
        """Device slot per event: un-admitted -> prior slot; admitted ->
        its resident slot or -1 (needs staging).  Caller holds the lock."""
        slots = self._slot_of[tid].astype(np.int32)
        return np.where(self.host.admitted[tid], slots,
                        np.int32(self._prior_slot))

    def _stage_locked(self, take: np.ndarray,
                      protected: set[int]) -> None:
        """Page ``take`` host rows into victim slots (clock eviction,
        skipping ``protected`` slots).  Caller holds the lock and
        guarantees ``len(take) <= victim_capacity - len(protected)``."""
        assigned: list[int] = []
        chosen: set[int] = set()
        for t in take:
            for _ in range(self._victims):
                s = self._hot + self._hand
                self._hand = (self._hand + 1) % self._victims
                if s not in protected and s not in chosen:
                    break
            else:  # pragma: no cover — caller enforces capacity
                raise RuntimeError("no victim slot available")
            chosen.add(s)
            prev = self._owner[s]
            if prev >= 0:
                self._slot_of[prev] = -1
            self._owner[s] = int(t)
            self._slot_of[int(t)] = s
            assigned.append(s)
        idx = jnp.asarray(assigned, jnp.int32)
        b, w, qs, qr = self.host.rows(np.asarray(take, np.int64))
        v = self._view
        self._view = _TierView(
            v.betas.at[idx].set(jnp.asarray(b)),
            v.weights.at[idx].set(jnp.asarray(w)),
            v.src_quantiles.at[idx].set(jnp.asarray(qs)),
            v.ref_quantiles.at[idx].set(jnp.asarray(qr)),
            v.generation)
        self.metrics["staged_rows"] += len(take)

    def _score_slots(self, raws: np.ndarray, slots: np.ndarray,
                     view: _TierView) -> np.ndarray:
        """One banked kernel call over slot-indexed rows (pow-2 bucketed,
        edge-padded slot vector — identical padding to the dense server
        path, which the bitwise-parity contract depends on)."""
        b = len(slots)
        pad = _shape_bucket(b) - b
        if pad:
            raws = np.concatenate(
                [raws, np.zeros((pad,) + raws.shape[1:], raws.dtype)])
            slots = np.concatenate(
                [slots, np.full(pad, slots[-1], np.int32)])
        impl = ops.score_pipeline_banked if self.config.fused_kernel \
            else banked_score_pipeline
        out = impl(jnp.asarray(raws, jnp.float32),
                   jnp.asarray(slots, jnp.int32),
                   view.betas, view.weights,
                   view.src_quantiles, view.ref_quantiles)
        return np.asarray(out)[:b]

    # -------------------------------------------------------------- serving
    def dispatch(self, expert_scores: np.ndarray, tenant_idx: np.ndarray
                 ) -> tuple[np.ndarray, int]:
        """Score one mixed-tenant window; returns ``(scores, generation)``.

        Hot path (every referenced row device-resident — the prefetched
        steady state): one slot remap + ONE banked kernel call, no host
        reads.  A cold miss stages the row synchronously into the victim
        cache first (counted in ``cold_miss_stalls``/``stalled_events``);
        if a window references more distinct cold tenants than the victim
        cache holds, it is scored in multiple passes (``extra_passes``) —
        correctness never depends on capacity.
        """
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return np.empty(0, np.float32), self._view.generation
        with self._lock:
            self.tracker.record(tid)
            self._seen += np.bincount(tid, minlength=len(self._seen))
            self.metrics["dispatches"] += 1
            self.metrics["events"] += len(tid)
            eff = self._effective_slots(tid)
            self.metrics["prior_scores"] += int(
                np.sum(eff == self._prior_slot))
            self.metrics["hot_hits"] += int(
                np.sum((eff >= 0) & (eff < self._hot)))
            self.metrics["victim_hits"] += int(
                np.sum((eff >= self._hot) & (eff < self._prior_slot)))

            out = np.empty(len(tid), np.float32)
            done = np.zeros(len(tid), bool)
            passes = 0
            while not done.all():
                eff = self._effective_slots(tid)
                ready = ~done & (eff >= 0)
                missing = ~done & (eff < 0)
                if missing.any():
                    miss = np.unique(tid[missing])
                    # victim slots serving THIS pass's ready events must
                    # not be evicted out from under the same kernel call
                    live = np.unique(eff[ready]) if ready.any() else ()
                    protected = {int(s) for s in live
                                 if self._hot <= s < self._prior_slot}
                    room = self._victims - len(protected)
                    if room > 0:
                        take = miss[:room]
                        self._stage_locked(take, protected)
                        self.metrics["cold_miss_stalls"] += len(take)
                        staged_ev = ~done & np.isin(tid, take)
                        self.metrics["stalled_events"] += int(
                            staged_ev.sum())
                        eff = self._effective_slots(tid)
                        ready = ~done & (eff >= 0)
                ev = np.flatnonzero(ready)
                if not len(ev):  # pragma: no cover — room>0 or ready!=[]
                    raise RuntimeError("tiered dispatch made no progress")
                out[ev] = self._score_slots(raws[ev], eff[ev], self._view)
                done[ev] = True
                passes += 1
            if passes > 1:
                self.metrics["extra_passes"] += passes - 1
            return out, self._view.generation

    def prefetch(self, tenant_idx: np.ndarray) -> int:
        """Stage pending windows' cold rows ahead of dispatch (no stall
        accounting, no hotness recording — the dispatch that actually
        serves the window records it).  At most ``victim_capacity`` rows
        are staged per call; returns the number staged."""
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return 0
        with self._lock:
            uniq = np.unique(tid)
            uniq = uniq[self.host.admitted[uniq]]
            miss = uniq[self._slot_of[uniq] < 0]
            if not len(miss):
                return 0
            take = miss[:self._victims]
            self._stage_locked(take, set())
            self.metrics["prefetched_rows"] += len(take)
            return len(take)

    def pre_quantile(self, expert_scores: np.ndarray,
                     tenant_idx: np.ndarray) -> np.ndarray:
        """Per-event T^Q input (corrected weighted aggregate) through the
        rows the dispatch serves — host rows for admitted tenants, the
        prior row otherwise.  Numpy on host arrays: the track stage must
        not pull cold rows onto the device just to fit estimators."""
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        with self._lock:
            adm = self.host.admitted[tid]
            b = self.host.betas[tid]
            w = self.host.weights[tid]
            v = self._view
            pb = np.asarray(v.betas[-1])
            pw = np.asarray(v.weights[-1])
        b = np.where(adm[:, None], b, pb[None, :])
        w = np.where(adm[:, None], w, pw[None, :])
        corrected = (b * raws) / (1.0 - (1.0 - b) * raws)
        w = w / np.sum(w, axis=-1, keepdims=True)
        return np.sum(corrected * w, axis=-1)

    # -------------------------------------------------------------- control
    def rebalance(self, *, generation: int | None = None) -> dict[str, int]:
        """Explicit control-plane promotion/demotion + Eq.-5 admission.

        ``generation`` fences a decision computed against an old view:
        a stamp STRICTLY OLDER than the store's current generation raises
        :class:`StaleGenerationError` (a superseded control pass must not
        reshuffle tiers).  Rebalance moves rows between tiers but never
        changes their values, so the generation itself is unchanged.

        Admission: tenants whose observed stream reached ``gate_samples``
        events leave the prior tier (their host row — the prior's params
        until a calibration publish refreshes them — becomes servable).
        Promotion: the ``hot_capacity`` hottest admitted tenants by
        decayed access count hold the hot slots; everyone else pages
        through the victim cache.  Returns a summary dict.
        """
        with self._lock:
            cur = self._view.generation
            if generation is not None and generation < cur:
                raise StaleGenerationError(generation, cur)
            newly = np.flatnonzero(~self.host.admitted
                                   & (self._seen >= self._gate_n))
            if len(newly):
                self.host.admitted[newly] = True
            self.tracker.tick()
            want = self.tracker.top(self._hot, mask=self.host.admitted)
            want_set = {int(t) for t in want}
            cur_hot = {int(self._owner[s]): s for s in range(self._hot)
                       if self._owner[s] >= 0}
            demote = [t for t in cur_hot if t not in want_set]
            promote = [int(t) for t in want if int(t) not in cur_hot]
            for t in demote:
                self._owner[cur_hot[t]] = -1
                self._slot_of[t] = -1
            free = [s for s in range(self._hot) if self._owner[s] < 0]
            if promote:
                slots: list[int] = []
                for t, s in zip(promote, free):
                    old = self._slot_of[t]
                    if old >= 0:           # leaving the victim cache
                        self._owner[old] = -1
                    self._owner[s] = t
                    self._slot_of[t] = s
                    slots.append(s)
                idx = jnp.asarray(slots, jnp.int32)
                b, w, qs, qr = self.host.rows(np.asarray(promote, np.int64))
                v = self._view
                self._view = _TierView(
                    v.betas.at[idx].set(jnp.asarray(b)),
                    v.weights.at[idx].set(jnp.asarray(w)),
                    v.src_quantiles.at[idx].set(jnp.asarray(qs)),
                    v.ref_quantiles.at[idx].set(jnp.asarray(qr)),
                    v.generation)
            self.metrics["admissions"] += len(newly)
            self.metrics["promotions"] += len(promote)
            self.metrics["demotions"] += len(demote)
            return {"admitted": len(newly), "promoted": len(promote),
                    "demoted": len(demote), "generation": cur}

    def apply_updates(self, updates: Mapping[int, "QuantileMap | tuple"],
                      *, generation: int | None = None) -> int:
        """Publish refreshed T^Q tables into BOTH tiers atomically.

        Host rows are rewritten in place and every device-resident copy
        (hot slot or victim slot) is scattered into a NEW view under the
        new generation, all inside one lock hold — no read anywhere can
        observe the old table after this returns.  Updated rows are marked
        admitted (a published map means the stream passed calibration).
        Fencing matches ``MuseServer.publish_quantile_maps``: with
        ``generation=`` the stamp must be strictly newer (else
        :class:`StaleGenerationError`); an empty fenced update
        fast-forwards the generation; an empty unfenced update is a no-op.
        Returns the store generation after the call.
        """
        with self._lock:
            cur = self._view.generation
            if generation is None:
                if not updates:
                    return cur
                gen = cur + 1
            else:
                if generation <= cur:
                    raise StaleGenerationError(generation, cur)
                gen = generation
            v = self._view
            if updates:
                ids = self.host.write_rows(updates)
                self.host.admitted[ids] = True
                self.metrics["updates"] += len(ids)
                resident = ids[self._slot_of[ids] >= 0]
                if len(resident):
                    idx = jnp.asarray(self._slot_of[resident], jnp.int32)
                    _, _, qs, qr = self.host.rows(resident)
                    self._view = _TierView(
                        v.betas, v.weights,
                        v.src_quantiles.at[idx].set(jnp.asarray(qs)),
                        v.ref_quantiles.at[idx].set(jnp.asarray(qr)),
                        gen)
                    return gen
            self._view = dataclasses.replace(v, generation=gen)
            return gen

    def mark_cold(self, rows: Sequence[int]) -> None:
        """Send rows back behind the Eq.-5 gate: they score through the
        prior slot until their stream re-reaches ``gate_samples`` events
        and a ``rebalance`` re-admits them.  Any device-resident copy is
        evicted (unreachable rows must not hold slots)."""
        ids = np.asarray(list(rows), np.int64)
        if not len(ids):
            return
        with self._lock:
            self.host.admitted[ids] = False
            self._seen[ids] = 0
            resident = ids[self._slot_of[ids] >= 0]
            for t in resident:
                self._owner[self._slot_of[t]] = -1
                self._slot_of[t] = -1

    def seen(self, row: int) -> int:
        """Observed event count for one tenant (the Eq.-5 gate input)."""
        return int(self._seen[row])

    # ---------------------------------------------------------- persistence
    def hotness_snapshot(self) -> dict:
        """Portable hotness/admission state a surged replica adopts so it
        warms up with its predecessor's hot set instead of a cold one."""
        with self._lock:
            return {"tracker": self.tracker.snapshot(),
                    "seen": self._seen.copy(),
                    "admitted": self.host.admitted.copy()}

    def adopt_hotness(self, snap: dict) -> None:
        with self._lock:
            self.tracker.adopt(snap["tracker"])
            seen = np.asarray(snap["seen"], np.int64)
            adm = np.asarray(snap["admitted"], bool)
            n = min(len(seen), len(self._seen))
            self._seen[:n] = seen[:n]
            self.host.admitted[:n] = adm[:n]
