"""Tiered tenant-bank store: hot device rows, host-paged cold rows, priors.

The fully-resident bank (dense or sharded) is the wall past ~10^5 tenants:
every (tenant, predictor) transform row costs ``(2K+2N)·4`` device bytes
*somewhere*, forever.  This module breaks that coupling with a three-tier
store in which device residency is bounded by CONFIGURATION, not by tenant
count:

  * **hot tier** — the ``hot_capacity`` hottest tenants' rows live in a
    device bank (the same ``TransformBank`` row layout today's banked
    kernel dispatches against) and are only ever moved by an explicit
    control-plane :meth:`TieredBankStore.rebalance`;
  * **victim cache** — a bounded ``victim_capacity``-slot device ring
    where cold tenants' rows are staged on demand (clock eviction).  The
    async engine prefetches pending windows' rows into it
    (:meth:`TieredBankStore.prefetch`) so the dispatch hot path normally
    never blocks on a host read; a miss that *does* reach dispatch is
    staged synchronously and counted as a ``cold_miss_stall``;
  * **cold-start prior** — tenants that have not yet passed the Eq.-5
    sample-size gate (paper Sec. 2.4) score through ONE shared prior row
    (Beta-mixture default quantiles, Eqs. 6–8, ``core/coldstart.py``)
    pinned in the last device slot.  Once a tenant's observed stream
    reaches ``required_sample_size(a, δ, z)`` events, the next
    ``rebalance`` admits it to its own (host-stored) row.

The authoritative copy of EVERY row is the host-memory
:class:`HostBankStore` (numpy — ~272 bytes/row at K=2, N=32, so 10^6
tenants fit in a few hundred MB of RAM); the device bank holds exactly
``hot_capacity + victim_capacity + 1`` rows regardless of tenant count.
A dispatch maps tenant ids to device SLOTS and runs the same fused banked
kernel (``kernels/ops.score_pipeline_banked``) as the dense path — per-row
compute is independent of bank size and row order, so tiered scores match
a dense bank built from the same rows BITWISE on f32 (asserted in
``tests/test_tiering.py``).

Generations and atomicity
-------------------------

The store carries the same generation discipline as the control plane:

  * :meth:`apply_updates` is the publish endpoint.  It writes refreshed
    T^Q tables into the host rows AND scatters every device-resident copy
    (hot, victim, either tier) in ONE locked operation under ONE bumped
    generation — a post-publish read of any tenant, hot or cold or
    freshly promoted, serves the new generation's parameters.  Fenced
    (``generation=``) updates reject non-strictly-newer stamps with
    :class:`~repro.serving.types.StaleGenerationError`, exactly like
    ``MuseServer.publish_quantile_maps``; an empty fenced update is a
    generation fast-forward.
  * :meth:`rebalance` (promotion / demotion / Eq.-5 admission) is fenced
    the other way: a caller may pass the generation its decision was
    computed against, and a stamp OLDER than the store's current
    generation is rejected — a superseded control pass cannot reshuffle
    tiers it no longer understands.  Rebalance never changes row VALUES,
    so it never bumps the generation.

Every read/write of the mutable tier state (slot maps, hotness, seen
counts, the immutable :class:`_TierView` reference) happens under one
internal lock; the view itself is immutable and swapped by reference, so
a dispatch is internally consistent by construction.

Overlapped staging
------------------

The engine's anti-stall :meth:`TieredBankStore.prefetch` used to hold
the dispatch lock across its host->device row copy — exactly the stall
an adversarial cold-tenant burst amplifies (every dispatch behind the
lock waits out the copy).  With ``TieringConfig.overlap_staging`` (the
default) prefetch is double-buffered instead: victim slots are RESERVED
under the lock (``_staging``), the staged view is built OUTSIDE it
against the captured immutable view, and the commit re-acquires the
lock, validates that the view reference (and the staged rows'
eligibility) did not change in flight, and swaps by reference.  Any
concurrent mutation that could invalidate the prepared buffer (publish,
rebalance promotion, a dispatch staging into a reserved slot) swaps the
view and therefore fails the identity check; the prefetch then falls
back to a short under-lock restage of whatever is still cold
(``staging_conflicts`` counts these).  Dispatch never waits on a copy
it does not need.

Tiered over sharded
-------------------

:class:`ShardedTieredBankStore` composes this store with the PR-5 mesh
topology: global rows are partitioned over the "tenants" mesh axis by
the same round-robin rule as :class:`ShardedTransformBank`
(``core.transforms.shard_rows``), each shard owns a per-shard
:class:`HostBankStore` plus its own hot/victim/prior
:class:`TieredBankStore`, and a dispatch buckets the window by owning
shard, resolves slots per shard, and launches the banked kernel ONCE
via the sharded dispatcher's ``shard_map`` over the stacked per-shard
views.  Device residency is ``(hot+victims+1)·(2K+2N)·4`` bytes PER
SHARD, independent of tenant count; publishes land in every shard's
host rows and device view under ONE generation (all shard locks held in
order, per-shard generations advance in lockstep).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.hotness import HotnessTracker
from repro.core.quantiles import required_sample_size
from repro.core.transforms import (
    QuantileMap,
    TransformBank,
    banked_score_pipeline,
    pad_quantile_tables,
    shard_rows,
)
from repro.kernels import ops
from repro.serving.types import StaleGenerationError


def _shape_bucket(n: int) -> int:
    """Next power of two >= n (same bucketing as the server's dispatch:
    bounded XLA specializations, one per bucket)."""
    b = 1
    while b < n:
        b *= 2
    return b


def prior_bank_row(
    prior: Any,
    ref_quantiles: np.ndarray,
    num_experts: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The shared cold-start device row from a fitted Beta-mixture prior.

    ``prior`` is a :class:`~repro.core.coldstart.BetaMixtureFit` (anything
    with ``.quantiles(levels)``) or a raw source-quantile table.  T^C is
    the identity (beta=1 — the prior already models the *corrected* score
    distribution on the training data) and aggregation is uniform; T^Q
    maps the fitted prior's quantiles onto the reference, i.e. the paper's
    ``T^Q_{v0}`` (Sec. 2.4) as one bank row.
    """
    ref = np.asarray(ref_quantiles, np.float64).ravel()
    if hasattr(prior, "quantiles"):
        src = np.asarray(prior.quantiles(np.linspace(0.0, 1.0, len(ref))))
    else:
        src = np.asarray(prior, np.float64).ravel()
        if len(src) != len(ref):
            src = np.interp(np.linspace(0.0, 1.0, len(ref)),
                            np.linspace(0.0, 1.0, len(src)), src)
    return (np.ones(num_experts, np.float32),
            np.ones(num_experts, np.float32),
            np.maximum.accumulate(src).astype(np.float32),
            np.asarray(ref, np.float32))


@dataclasses.dataclass
class TieringConfig:
    """Capacity + gating knobs for one :class:`TieredBankStore`.

    ``prior`` (optional) is the cold-start row — a
    ``(betas, weights, src_quantiles, ref_quantiles)`` tuple, typically
    from :func:`prior_bank_row`.  Without it the prior slot is the
    identity map and the Eq.-5 admission gate only matters for rows
    explicitly marked cold.
    """

    hot_capacity: int = 1024          # per store; PER SHARD when composed
    victim_capacity: int = 128
    decay: float = 0.98               # hotness decay per rebalance window
    gate_alert_rate: float = 0.01     # Eq. 5 target alert rate ``a``
    gate_rel_error: float = 0.2       # Eq. 5 relative error ``delta``
    gate_z: float = 1.96              # Eq. 5 confidence (95%)
    fused_kernel: bool = True         # banked Pallas kernel vs jnp oracle
    # prefetch builds its staged view outside the dispatch lock and swaps
    # it in under an identity check (see module docstring); False keeps
    # the old hold-the-lock-across-the-copy behavior (bench comparison)
    overlap_staging: bool = True
    prior: tuple | None = None

    def __post_init__(self) -> None:
        if self.hot_capacity < 1:
            raise ValueError("hot_capacity must be >= 1")
        if self.victim_capacity < 1:
            raise ValueError("victim_capacity must be >= 1")


class HostBankStore:
    """Host-memory (numpy) authoritative store of EVERY tenant's bank row.

    Plain contiguous float32 arrays — ``(T, K)`` betas/weights and
    ``(T, N)`` quantile tables — written in place only under the owning
    :class:`TieredBankStore`'s lock.  ``admitted`` marks rows past the
    Eq.-5 gate; un-admitted tenants score through the shared prior slot
    regardless of what their host row holds.
    """

    def __init__(self, betas: np.ndarray, weights: np.ndarray,
                 src_quantiles: np.ndarray, ref_quantiles: np.ndarray,
                 admitted: np.ndarray | None = None) -> None:
        # np.array (not asarray): rows handed in may be read-only views of
        # jax buffers, and write_rows mutates these in place
        self.betas = np.array(betas, np.float32, order="C")
        self.weights = np.array(weights, np.float32, order="C")
        self.src_quantiles = np.array(src_quantiles, np.float32, order="C")
        self.ref_quantiles = np.array(ref_quantiles, np.float32, order="C")
        t = self.betas.shape[0]
        for arr, name in ((self.weights, "weights"),
                          (self.src_quantiles, "src_quantiles"),
                          (self.ref_quantiles, "ref_quantiles")):
            if arr.shape[0] != t:
                raise ValueError(f"{name} has {arr.shape[0]} rows, betas {t}")
        self.admitted = (np.ones(t, bool) if admitted is None
                         else np.asarray(admitted, bool).copy())

    # ------------------------------------------------------------- geometry
    @property
    def num_rows(self) -> int:
        return int(self.betas.shape[0])

    @property
    def num_experts(self) -> int:
        return int(self.betas.shape[-1])

    @property
    def num_quantiles(self) -> int:
        return int(self.src_quantiles.shape[-1])

    @property
    def nbytes(self) -> int:
        """Host bytes of the row arrays (the O(total tenants) cost that
        tiering moves OFF the device)."""
        return (self.betas.nbytes + self.weights.nbytes
                + self.src_quantiles.nbytes + self.ref_quantiles.nbytes)

    # ------------------------------------------------------------- builders
    @staticmethod
    def from_rows(
        params: Sequence[tuple],
        admitted: np.ndarray | None = None,
    ) -> "HostBankStore":
        """Stack ragged ``(betas, weights, src_q, ref_q)`` rows, padding the
        expert axis with (beta=1, weight=0) columns and quantile tables
        edge-wise — the same semantics-preserving padding as
        :meth:`TransformBank.from_params`, so a dense bank built from the
        same params is row-for-row identical."""
        bank = TransformBank.from_params(params)
        return HostBankStore(
            np.asarray(bank.betas), np.asarray(bank.weights),
            np.asarray(bank.src_quantiles), np.asarray(bank.ref_quantiles),
            admitted)

    @staticmethod
    def from_bank(bank: TransformBank,
                  admitted: np.ndarray | None = None) -> "HostBankStore":
        return HostBankStore(
            np.asarray(bank.betas), np.asarray(bank.weights),
            np.asarray(bank.src_quantiles), np.asarray(bank.ref_quantiles),
            admitted)

    # --------------------------------------------------------------- access
    def rows(self, ids: np.ndarray) -> tuple[np.ndarray, np.ndarray,
                                             np.ndarray, np.ndarray]:
        ids = np.asarray(ids, np.int64)
        return (self.betas[ids], self.weights[ids],
                self.src_quantiles[ids], self.ref_quantiles[ids])

    def write_rows(
        self,
        updates: Mapping[int, "QuantileMap | tuple"],
    ) -> np.ndarray:
        """In-place T^Q table replacement for the given rows (the publish
        write path — caller holds the tier lock).  Narrow tables are
        edge-padded exactly like the bank ``with_rows`` scatters.  Every
        table is validated/padded BEFORE the first in-place write, so a
        bad row (e.g. a table wider than the store) raises with the host
        arrays untouched — no torn half-published update.  Returns the
        updated row ids."""
        n = self.num_quantiles
        staged = []
        for row, value in sorted(updates.items()):
            if not 0 <= row < self.num_rows:
                raise IndexError(f"row {row} outside store of {self.num_rows}")
            src, ref = pad_quantile_tables(value, n, row=row)
            staged.append((row, np.asarray(src), np.asarray(ref)))
        ids = []
        for row, src, ref in staged:
            self.src_quantiles[row] = src
            self.ref_quantiles[row] = ref
            ids.append(row)
        return np.asarray(ids, np.int64)

    def dense_bank(self, generation: int = 0) -> TransformBank:
        """The dense bank these rows describe (parity oracle for tests)."""
        return TransformBank(
            betas=jnp.asarray(self.betas), weights=jnp.asarray(self.weights),
            src_quantiles=jnp.asarray(self.src_quantiles),
            ref_quantiles=jnp.asarray(self.ref_quantiles),
            generation=generation)


@dataclasses.dataclass(frozen=True)
class _TierView:
    """One immutable device-bank snapshot a dispatch scores against.

    ``hot_capacity + victim_capacity + 1`` rows: hot slots, victim slots,
    then the pinned prior row.  Swapped by reference under the store lock
    (staging, rebalance, publish); a dispatch that captured a view scores
    every row of its window against exactly one generation.
    """

    betas: Any            # (R, K) jax
    weights: Any          # (R, K)
    src_quantiles: Any    # (R, N)
    ref_quantiles: Any    # (R, N)
    generation: int

    @property
    def nbytes(self) -> int:
        r = int(self.betas.shape[0])
        k = int(self.betas.shape[-1])
        n = int(self.src_quantiles.shape[-1])
        return r * (2 * k + 2 * n) * 4


class TieredBankStore:
    """Hot/victim/prior tiered serving view over a :class:`HostBankStore`.

    See the module docstring for the tier model.  All public methods are
    thread-safe; ``dispatch`` holds the store lock across its kernel
    call(s) so the (slot map, device view) pair it scores with is
    consistent and each window serves under one generation — publishes
    from another thread land before or after a window, never inside it.
    """

    def __init__(self, host: HostBankStore,
                 config: TieringConfig | None = None, *,
                 generation: int = 0, hot_slots: int | None = None) -> None:
        self.host = host
        self.config = config or TieringConfig()
        t = host.num_rows
        # hot_slots: explicit hot-tier size override.  The composed
        # sharded store passes the SAME value to every shard so all
        # per-shard views have identical row counts and stack into one
        # (S, R, ·) shard_map operand (uneven shard occupancy would
        # otherwise give shards different R = min(capacity, rows)).
        self._hot = min(self.config.hot_capacity, t) if hot_slots is None \
            else int(hot_slots)
        self._victims = self.config.victim_capacity
        self._prior_slot = self._hot + self._victims
        self._gate_n = required_sample_size(
            self.config.gate_alert_rate, self.config.gate_rel_error,
            self.config.gate_z)
        self.tracker = HotnessTracker(t, self.config.decay)
        self._seen = np.zeros(t, np.int64)
        self._slot_of = np.full(t, -1, np.int32)   # -1 = not device-resident
        self._owner = np.full(self._prior_slot, -1, np.int64)
        self._hand = 0                             # victim clock hand
        # identity witness for the serving layer's bank cache (which
        # pipelines this store's host rows were built from); opaque here
        self.source_pipelines: tuple | None = None
        k, n = host.num_experts, host.num_quantiles
        rows = self._prior_slot + 1
        betas = np.ones((rows, k), np.float32)
        weights = np.ones((rows, k), np.float32)
        ident = np.linspace(0.0, 1.0, n, dtype=np.float32)
        src = np.broadcast_to(ident, (rows, n)).copy()
        ref = src.copy()
        if self.config.prior is not None:
            pb, pw, ps, pr = self.config.prior
            betas[-1] = np.asarray(pb, np.float32)
            weights[-1] = np.asarray(pw, np.float32)
            ps, pr = pad_quantile_tables(
                (np.asarray(ps), np.asarray(pr)), n)
            src[-1] = np.asarray(ps)
            ref[-1] = np.asarray(pr)
        self._view = _TierView(
            jnp.asarray(betas), jnp.asarray(weights),
            jnp.asarray(src), jnp.asarray(ref), generation)
        # RLock: the composed sharded store holds every shard's lock and
        # then calls per-shard methods that re-acquire their own
        self._lock = threading.RLock()
        # victim slots reserved by an in-flight overlapped prefetch (its
        # copy runs OFF the lock); concurrent prefetches avoid these.
        # Dispatch staging deliberately does NOT — a dispatch miss must
        # always make progress, and stealing a reserved slot just fails
        # the prefetch's commit identity check (it restages or drops).
        self._staging: set[int] = set()
        self.metrics: dict[str, int] = {
            "dispatches": 0, "events": 0, "hot_hits": 0, "victim_hits": 0,
            "prior_scores": 0, "cold_miss_stalls": 0, "stalled_events": 0,
            "staged_rows": 0, "prefetched_rows": 0, "extra_passes": 0,
            "staging_conflicts": 0,
            "promotions": 0, "demotions": 0, "admissions": 0, "updates": 0,
        }

    # ------------------------------------------------------------- geometry
    @property
    def num_rows(self) -> int:
        return self.host.num_rows

    @property
    def hot_capacity(self) -> int:
        return self._hot

    @property
    def victim_capacity(self) -> int:
        return self._victims

    @property
    def generation(self) -> int:
        return self._view.generation

    @property
    def gate_samples(self) -> int:
        """Eq.-5 sample count a tenant's stream needs for admission."""
        return self._gate_n

    @property
    def device_bytes(self) -> int:
        """Device-resident bank bytes — a function of CONFIGURED capacity
        (hot + victim + prior row), independent of ``num_rows``."""
        return self._view.nbytes

    @property
    def host_bytes(self) -> int:
        return self.host.nbytes

    def hot_rows(self) -> np.ndarray:
        """Tenant ids currently in the hot tier (unordered)."""
        with self._lock:
            owners = self._owner[:self._hot]
            return owners[owners >= 0].copy()

    def resident_rows(self) -> np.ndarray:
        """Tenant ids device-resident in either tier (unordered)."""
        with self._lock:
            return self._owner[self._owner >= 0].copy()

    # --------------------------------------------------------------- private
    def _effective_slots(self, tid: np.ndarray) -> np.ndarray:
        """Device slot per event: un-admitted -> prior slot; admitted ->
        its resident slot or -1 (needs staging).  Caller holds the lock."""
        slots = self._slot_of[tid].astype(np.int32)
        return np.where(self.host.admitted[tid], slots,
                        np.int32(self._prior_slot))

    def _pick_victim_slots_locked(self, n: int,
                                  protected: set[int]) -> list[int]:
        """Choose ``n`` distinct victim slots by clock, skipping
        ``protected``.  Caller holds the lock and guarantees enough
        unprotected slots exist."""
        chosen: list[int] = []
        taken: set[int] = set()
        for _ in range(n):
            for _ in range(self._victims):
                s = self._hot + self._hand
                self._hand = (self._hand + 1) % self._victims
                if s not in protected and s not in taken:
                    break
            else:  # pragma: no cover — caller enforces capacity
                raise RuntimeError("no victim slot available")
            taken.add(s)
            chosen.append(s)
        return chosen

    def _assign_slots_locked(self, take: np.ndarray,
                             slots: Sequence[int]) -> None:
        """Point the slot maps at the new owners (caller holds the lock;
        the view rows for ``slots`` must already hold ``take``'s data or
        be swapped in the same lock hold)."""
        for t, s in zip(take, slots):
            prev = self._owner[s]
            if prev >= 0:
                self._slot_of[prev] = -1
            self._owner[s] = int(t)
            self._slot_of[int(t)] = s

    def _staged_view(self, view: _TierView, slots: Sequence[int],
                     take: np.ndarray) -> _TierView:
        """A new view with host rows ``take`` scattered into ``slots`` —
        the host->device copy.  Pure function of its inputs against the
        IMMUTABLE ``view``: the overlapped prefetch path builds this
        outside the lock and swaps it in under an identity check (host
        row values only change under ``apply_updates``, which always
        swaps the view reference, so a torn read here is always caught
        at commit)."""
        idx = jnp.asarray(list(slots), jnp.int32)
        b, w, qs, qr = self.host.rows(np.asarray(take, np.int64))
        return _TierView(
            view.betas.at[idx].set(jnp.asarray(b)),
            view.weights.at[idx].set(jnp.asarray(w)),
            view.src_quantiles.at[idx].set(jnp.asarray(qs)),
            view.ref_quantiles.at[idx].set(jnp.asarray(qr)),
            view.generation)

    def _stage_locked(self, take: np.ndarray,
                      protected: set[int]) -> None:
        """Page ``take`` host rows into victim slots (clock eviction,
        skipping ``protected`` slots).  Caller holds the lock and
        guarantees ``len(take) <= victim_capacity - len(protected)``."""
        slots = self._pick_victim_slots_locked(len(take), protected)
        self._assign_slots_locked(take, slots)
        self._view = self._staged_view(self._view, slots, take)
        self.metrics["staged_rows"] += len(take)

    def _score_slots(self, raws: np.ndarray, slots: np.ndarray,
                     view: _TierView) -> np.ndarray:
        """One banked kernel call over slot-indexed rows (pow-2 bucketed,
        edge-padded slot vector — identical padding to the dense server
        path, which the bitwise-parity contract depends on)."""
        b = len(slots)
        pad = _shape_bucket(b) - b
        if pad:
            raws = np.concatenate(
                [raws, np.zeros((pad,) + raws.shape[1:], raws.dtype)])
            # Edge-pad with the LAST event's slot — which may be a live
            # victim slot — and deliberately NOT with ``_prior_slot``:
            # the dense server path edge-pads its tenant vector the same
            # way, and the pad value decides whether the tail block takes
            # the kernel's uniform-block fast path, which the bitwise-
            # parity contract depends on.  Referencing a victim slot here
            # cannot extend that slot's protection window across passes:
            # this padded vector exists only inside the present (lock-
            # held, synchronous) kernel call against the immutable
            # ``view``; pad rows are sliced off on return, and each later
            # pass rebuilds its eviction-protection set from the UNPADDED
            # event slots (``_resolve_pass_locked``).  Evicting the pad-
            # referenced row in a later pass is therefore safe — the
            # multi-pass parity test in tests/test_tiering.py pins this.
            assert 0 <= slots[-1] <= self._prior_slot
            slots = np.concatenate(
                [slots, np.full(pad, slots[-1], np.int32)])
        impl = ops.score_pipeline_banked if self.config.fused_kernel \
            else banked_score_pipeline
        out = impl(jnp.asarray(raws, jnp.float32),
                   jnp.asarray(slots, jnp.int32),
                   view.betas, view.weights,
                   view.src_quantiles, view.ref_quantiles)
        return np.asarray(out)[:b]

    # -------------------------------------------------------------- serving
    def dispatch(self, expert_scores: np.ndarray, tenant_idx: np.ndarray
                 ) -> tuple[np.ndarray, int]:
        """Score one mixed-tenant window; returns ``(scores, generation)``.

        Hot path (every referenced row device-resident — the prefetched
        steady state): one slot remap + ONE banked kernel call, no host
        reads.  A cold miss stages the row synchronously into the victim
        cache first (counted in ``cold_miss_stalls``/``stalled_events``);
        if a window references more distinct cold tenants than the victim
        cache holds, it is scored in multiple passes (``extra_passes``) —
        correctness never depends on capacity.
        """
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return np.empty(0, np.float32), self._view.generation
        with self._lock:
            self._record_window_locked(tid)
            out = np.empty(len(tid), np.float32)
            done = np.zeros(len(tid), bool)
            passes = 0
            while not done.all():
                eff, ready = self._resolve_pass_locked(tid, done)
                ev = np.flatnonzero(ready)
                if not len(ev):  # pragma: no cover — room>0 or ready!=[]
                    raise RuntimeError("tiered dispatch made no progress")
                out[ev] = self._score_slots(raws[ev], eff[ev], self._view)
                done[ev] = True
                passes += 1
            if passes > 1:
                self.metrics["extra_passes"] += passes - 1
            return out, self._view.generation

    def _record_window_locked(self, tid: np.ndarray) -> None:
        """Per-window accounting: hotness, Eq.-5 seen counts, tier-hit
        metrics.  Caller holds the lock.  ``np.add.at`` rather than
        ``self._seen += np.bincount(tid, minlength=T)``: the bincount
        temp is O(total tenants) — an 8 MB int64 allocation per window
        at 10^6 tenants, on the hot path, under the dispatch lock — where
        the unbuffered scatter-add is O(window)."""
        self.tracker.record(tid)
        np.add.at(self._seen, tid, 1)
        self.metrics["dispatches"] += 1
        self.metrics["events"] += len(tid)
        eff = self._effective_slots(tid)
        self.metrics["prior_scores"] += int(
            np.sum(eff == self._prior_slot))
        self.metrics["hot_hits"] += int(
            np.sum((eff >= 0) & (eff < self._hot)))
        self.metrics["victim_hits"] += int(
            np.sum((eff >= self._hot) & (eff < self._prior_slot)))

    def _resolve_pass_locked(self, tid: np.ndarray, done: np.ndarray
                             ) -> tuple[np.ndarray, np.ndarray]:
        """One staging pass of a dispatch window (caller holds the lock):
        stage as many still-missing rows as the victim cache can take
        without evicting slots this pass's ready events reference, then
        return ``(effective slots, ready mask)``.  Shared verbatim by the
        single-store dispatch loop and the composed sharded store's
        joint-pass loop."""
        eff = self._effective_slots(tid)
        ready = ~done & (eff >= 0)
        missing = ~done & (eff < 0)
        if missing.any():
            miss = np.unique(tid[missing])
            # victim slots serving THIS pass's ready events must not be
            # evicted out from under the same kernel call
            live = np.unique(eff[ready]) if ready.any() else ()
            protected = {int(s) for s in live
                         if self._hot <= s < self._prior_slot}
            room = self._victims - len(protected)
            if room > 0:
                take = miss[:room]
                self._stage_locked(take, protected)
                self.metrics["cold_miss_stalls"] += len(take)
                staged_ev = ~done & np.isin(tid, take)
                self.metrics["stalled_events"] += int(staged_ev.sum())
                eff = self._effective_slots(tid)
                ready = ~done & (eff >= 0)
        return eff, ready

    def _prefetch_misses_locked(self, tid: np.ndarray,
                                cap: int) -> np.ndarray:
        """Admitted, non-resident rows referenced by ``tid`` (at most
        ``cap`` of them).  Caller holds the lock."""
        if cap <= 0:
            return np.empty(0, np.int64)
        uniq = np.unique(tid)
        uniq = uniq[self.host.admitted[uniq]]
        miss = uniq[self._slot_of[uniq] < 0]
        return miss[:cap]

    def prefetch(self, tenant_idx: np.ndarray) -> int:
        """Stage pending windows' cold rows ahead of dispatch (no stall
        accounting, no hotness recording — the dispatch that actually
        serves the window records it).  At most ``victim_capacity`` rows
        are staged per call; returns the number staged.

        With ``overlap_staging`` (default) the host->device copy runs
        OFF the dispatch lock: slots are reserved under the lock, the
        staged view is built outside it against the captured immutable
        view, and the commit validates the view reference before the
        swap (see the module docstring).  A concurrent publish/rebalance/
        dispatch-staging invalidates the prepared buffer — the commit
        then restages whatever is still cold under the lock
        (``staging_conflicts``)."""
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return 0
        if not self.config.overlap_staging:
            # legacy path: hold the lock across the whole copy (kept for
            # the bench's before/after p99 comparison)
            with self._lock:
                take = self._prefetch_misses_locked(tid, self._victims)
                if not len(take):
                    return 0
                self._stage_locked(take, set())
                self.metrics["prefetched_rows"] += len(take)
                return len(take)
        with self._lock:
            room = self._victims - len(self._staging)
            take = self._prefetch_misses_locked(tid, room)
            if not len(take):
                return 0
            slots = self._pick_victim_slots_locked(len(take), self._staging)
            self._staging.update(slots)
            v0 = self._view
        try:
            # the expensive part — host gather + device scatter — runs
            # with NO lock held: dispatches proceed concurrently
            staged = self._staged_view(v0, slots, take)
        except BaseException:
            with self._lock:
                self._staging.difference_update(slots)
            raise
        with self._lock:
            self._staging.difference_update(slots)
            fresh = (self._view is v0
                     and bool(np.all(self._slot_of[take] < 0))
                     and bool(np.all(self.host.admitted[take])))
            if fresh:
                # nothing swapped the view while the copy was in flight,
                # and every staged row is still cold+admitted (mark_cold
                # can flip eligibility without a view swap): commit
                self._assign_slots_locked(take, slots)
                self._view = staged
                self.metrics["staged_rows"] += len(take)
                self.metrics["prefetched_rows"] += len(take)
                return len(take)
            # conflict: drop the prepared buffer, restage what is still
            # cold under the lock (rare — counted for the bench)
            self.metrics["staging_conflicts"] += 1
            take = take[(self._slot_of[take] < 0)
                        & self.host.admitted[take]]
            take = take[:max(self._victims - len(self._staging), 0)]
            if not len(take):
                return 0
            self._stage_locked(take, set(self._staging))
            self.metrics["prefetched_rows"] += len(take)
            return len(take)

    def pre_quantile(self, expert_scores: np.ndarray,
                     tenant_idx: np.ndarray) -> np.ndarray:
        """Per-event T^Q input (corrected weighted aggregate) through the
        rows the dispatch serves — host rows for admitted tenants, the
        prior row otherwise.  Numpy on host arrays: the track stage must
        not pull cold rows onto the device just to fit estimators."""
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        with self._lock:
            adm = self.host.admitted[tid]
            b = self.host.betas[tid]
            w = self.host.weights[tid]
            v = self._view
            pb = np.asarray(v.betas[-1])
            pw = np.asarray(v.weights[-1])
        b = np.where(adm[:, None], b, pb[None, :])
        w = np.where(adm[:, None], w, pw[None, :])
        corrected = (b * raws) / (1.0 - (1.0 - b) * raws)
        w = w / np.sum(w, axis=-1, keepdims=True)
        return np.sum(corrected * w, axis=-1)

    # -------------------------------------------------------------- control
    def rebalance(self, *, generation: int | None = None) -> dict[str, int]:
        """Explicit control-plane promotion/demotion + Eq.-5 admission.

        ``generation`` fences a decision computed against an old view:
        a stamp STRICTLY OLDER than the store's current generation raises
        :class:`StaleGenerationError` (a superseded control pass must not
        reshuffle tiers).  Rebalance moves rows between tiers but never
        changes their values, so the generation itself is unchanged.

        Admission: tenants whose observed stream reached ``gate_samples``
        events leave the prior tier (their host row — the prior's params
        until a calibration publish refreshes them — becomes servable).
        Promotion: the ``hot_capacity`` hottest admitted tenants by
        decayed access count hold the hot slots; everyone else pages
        through the victim cache.  Returns a summary dict.
        """
        with self._lock:
            cur = self._view.generation
            if generation is not None and generation < cur:
                raise StaleGenerationError(generation, cur)
            newly = np.flatnonzero(~self.host.admitted
                                   & (self._seen >= self._gate_n))
            if len(newly):
                self.host.admitted[newly] = True
            self.tracker.tick()
            want = self.tracker.top(self._hot, mask=self.host.admitted)
            want_set = {int(t) for t in want}
            cur_hot = {int(self._owner[s]): s for s in range(self._hot)
                       if self._owner[s] >= 0}
            demote = [t for t in cur_hot if t not in want_set]
            promote = [int(t) for t in want if int(t) not in cur_hot]
            for t in demote:
                self._owner[cur_hot[t]] = -1
                self._slot_of[t] = -1
            free = [s for s in range(self._hot) if self._owner[s] < 0]
            if promote:
                slots: list[int] = []
                for t, s in zip(promote, free):
                    old = self._slot_of[t]
                    if old >= 0:           # leaving the victim cache
                        self._owner[old] = -1
                    self._owner[s] = t
                    self._slot_of[t] = s
                    slots.append(s)
                idx = jnp.asarray(slots, jnp.int32)
                b, w, qs, qr = self.host.rows(np.asarray(promote, np.int64))
                v = self._view
                self._view = _TierView(
                    v.betas.at[idx].set(jnp.asarray(b)),
                    v.weights.at[idx].set(jnp.asarray(w)),
                    v.src_quantiles.at[idx].set(jnp.asarray(qs)),
                    v.ref_quantiles.at[idx].set(jnp.asarray(qr)),
                    v.generation)
            self.metrics["admissions"] += len(newly)
            self.metrics["promotions"] += len(promote)
            self.metrics["demotions"] += len(demote)
            return {"admitted": len(newly), "promoted": len(promote),
                    "demoted": len(demote), "generation": cur}

    def apply_updates(self, updates: Mapping[int, "QuantileMap | tuple"],
                      *, generation: int | None = None) -> int:
        """Publish refreshed T^Q tables into BOTH tiers atomically.

        Host rows are rewritten in place and every device-resident copy
        (hot slot or victim slot) is scattered into a NEW view under the
        new generation, all inside one lock hold — no read anywhere can
        observe the old table after this returns.  Updated rows are marked
        admitted (a published map means the stream passed calibration).
        Fencing matches ``MuseServer.publish_quantile_maps``: with
        ``generation=`` the stamp must be strictly newer (else
        :class:`StaleGenerationError`); an empty fenced update
        fast-forwards the generation; an empty unfenced update is a no-op.
        Returns the store generation after the call.
        """
        with self._lock:
            cur = self._view.generation
            if generation is None:
                if not updates:
                    return cur
                gen = cur + 1
            else:
                if generation <= cur:
                    raise StaleGenerationError(generation, cur)
                gen = generation
            v = self._view
            if updates:
                ids = self.host.write_rows(updates)
                self.host.admitted[ids] = True
                self.metrics["updates"] += len(ids)
                resident = ids[self._slot_of[ids] >= 0]
                if len(resident):
                    idx = jnp.asarray(self._slot_of[resident], jnp.int32)
                    _, _, qs, qr = self.host.rows(resident)
                    self._view = _TierView(
                        v.betas, v.weights,
                        v.src_quantiles.at[idx].set(jnp.asarray(qs)),
                        v.ref_quantiles.at[idx].set(jnp.asarray(qr)),
                        gen)
                    return gen
            self._view = dataclasses.replace(v, generation=gen)
            return gen

    def mark_cold(self, rows: Sequence[int]) -> None:
        """Send rows back behind the Eq.-5 gate: they score through the
        prior slot until their stream re-reaches ``gate_samples`` events
        and a ``rebalance`` re-admits them.  Any device-resident copy is
        evicted (unreachable rows must not hold slots)."""
        ids = np.asarray(list(rows), np.int64)
        if not len(ids):
            return
        with self._lock:
            self.host.admitted[ids] = False
            self._seen[ids] = 0
            resident = ids[self._slot_of[ids] >= 0]
            for t in resident:
                self._owner[self._slot_of[t]] = -1
                self._slot_of[t] = -1

    def seen(self, row: int) -> int:
        """Observed event count for one tenant (the Eq.-5 gate input)."""
        return int(self._seen[row])

    # ---------------------------------------------------------- persistence
    def hotness_snapshot(self) -> dict:
        """Portable hotness/admission state a surged replica adopts so it
        warms up with its predecessor's hot set instead of a cold one."""
        with self._lock:
            return {"tracker": self.tracker.snapshot(),
                    "seen": self._seen.copy(),
                    "admitted": self.host.admitted.copy()}

    def adopt_hotness(self, snap: dict) -> None:
        with self._lock:
            self.tracker.adopt(snap["tracker"])
            seen = np.asarray(snap["seen"], np.int64)
            adm = np.asarray(snap["admitted"], bool)
            n = min(len(seen), len(self._seen))
            self._seen[:n] = seen[:n]
            self.host.admitted[:n] = adm[:n]


class ShardedTieredBankStore:
    """Per-shard hot/victim/prior tiers over a row-partitioned host store.

    The tiered-over-sharded topology (module docstring, "Tiered over
    sharded"): global rows partition over the tenant mesh axis by the
    SAME round-robin rule as :class:`~repro.core.transforms.
    ShardedTransformBank` (``shard_rows``), each shard owning a
    :class:`HostBankStore` slice and a full :class:`TieredBankStore`
    (hot slots, victim clock, pinned prior row, all PER SHARD — device
    residency is ``(hot+victims+1)·(2K+2N)·4`` bytes per shard
    regardless of tenant count).  The public surface mirrors
    :class:`TieredBankStore` addressed by GLOBAL row ids, so the serving
    layer (publish, rebalance, prefetch, warm start, mark_cold) treats
    both interchangeably; hotness snapshots are global-indexed, so a
    rollout can warm a composed store from a single-tier predecessor and
    vice versa.

    A dispatch buckets events by owning shard, runs every shard's
    staging pass, packs one ``(S, Bs, K)`` slot-remapped batch
    (edge-padded per shard, identically to the pure-sharded dispatcher),
    and launches the banked kernel ONCE via the dispatcher's
    ``shard_map`` over the stacked per-shard views — per-row compute is
    the identical kernel of the dense path, so composed scores match the
    dense bank BITWISE on f32.  Cross-shard operations (dispatch,
    publish, rebalance) take every shard's lock in shard order, so a
    publish lands in all shards' host rows and device views under ONE
    generation and per-shard generations advance in lockstep.
    """

    def __init__(self, host: HostBankStore, num_shards: int,
                 config: TieringConfig | None = None, *,
                 dispatcher: Any = None, mesh: Any = None,
                 generation: int = 0,
                 shard_of: np.ndarray | None = None) -> None:
        self.config = config or TieringConfig()
        t = host.num_rows
        assign, local, counts = shard_rows(t, num_shards, shard_of)
        self.shard_of = assign
        self.local_of = local
        self.row_counts = counts
        self.global_of = [np.flatnonzero(assign == s)
                          for s in range(num_shards)]
        # every shard gets the SAME hot-slot count (even the underfull
        # ones) so the per-shard views stack into one (S, R, ·) operand
        hot_slots = min(self.config.hot_capacity,
                        max(int(counts.max()) if counts.size else 1, 1))
        self.shards: list[TieredBankStore] = []
        for s in range(num_shards):
            g = self.global_of[s]
            sub = HostBankStore(
                host.betas[g], host.weights[g],
                host.src_quantiles[g], host.ref_quantiles[g],
                admitted=host.admitted[g])
            self.shards.append(TieredBankStore(
                sub, self.config, generation=generation,
                hot_slots=hot_slots))
        if dispatcher is None:
            # deferred: serving.server imports this module at the top
            from repro.serving.server import ShardedBankDispatcher
            if mesh is None:
                from repro.launch.mesh import make_tenant_mesh
                mesh = make_tenant_mesh(num_shards)
            dispatcher = ShardedBankDispatcher(
                mesh, fused=self.config.fused_kernel)
        self.dispatcher = dispatcher
        # identity witness for the serving layer's bank cache (same
        # contract as TieredBankStore.source_pipelines)
        self.source_pipelines: tuple | None = None
        # stacked-view cache: restacking S × R rows costs a device copy
        # per dispatch; keyed on the per-shard view IDENTITIES (strong
        # refs — any staging/publish/rebalance swaps a view and misses)
        self._stacked_key: tuple | None = None
        self._stacked: tuple | None = None
        self.joint_metrics: dict[str, int] = {
            "dispatches": 0, "extra_passes": 0}

    # ------------------------------------------------------------- geometry
    @property
    def num_rows(self) -> int:
        return int(self.shard_of.shape[0])

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def hot_capacity(self) -> int:
        return self.shards[0].hot_capacity

    @property
    def victim_capacity(self) -> int:
        return self.shards[0].victim_capacity

    @property
    def generation(self) -> int:
        # all shards agree by construction (lockstep publishes)
        return self.shards[0].generation

    @property
    def gate_samples(self) -> int:
        return self.shards[0].gate_samples

    @property
    def per_shard_device_bytes(self) -> int:
        """Device-resident bank bytes on ONE shard — a function of
        configured capacity, independent of tenant count."""
        return self.shards[0].device_bytes

    @property
    def device_bytes(self) -> int:
        return sum(st.device_bytes for st in self.shards)

    @property
    def host_bytes(self) -> int:
        return sum(st.host_bytes for st in self.shards)

    @property
    def metrics(self) -> dict[str, int]:
        """Aggregated counters: composed-level ``dispatches`` /
        ``extra_passes`` (joint windows and joint passes) plus every
        per-shard counter summed; the per-shard window counts land under
        ``shard_windows`` so they don't double-count dispatches."""
        agg = dict(self.joint_metrics)
        for st in self.shards:
            for k, v in st.metrics.items():
                if k == "dispatches":
                    k = "shard_windows"
                elif k == "extra_passes":
                    continue  # composed passes counted jointly
                agg[k] = agg.get(k, 0) + v
        return agg

    def hot_rows(self) -> np.ndarray:
        """GLOBAL tenant ids currently in any shard's hot tier."""
        return np.concatenate(
            [self.global_of[s][st.hot_rows()]
             for s, st in enumerate(self.shards)] or
            [np.empty(0, np.int64)])

    def resident_rows(self) -> np.ndarray:
        """GLOBAL tenant ids device-resident in any shard, either tier."""
        return np.concatenate(
            [self.global_of[s][st.resident_rows()]
             for s, st in enumerate(self.shards)] or
            [np.empty(0, np.int64)])

    def dense_bank(self, generation: int = 0) -> TransformBank:
        """The dense global bank the per-shard host rows describe
        (parity oracle for tests — same contract as
        :meth:`HostBankStore.dense_bank`)."""
        k = self.shards[0].host.num_experts
        n = self.shards[0].host.num_quantiles
        t = self.num_rows
        betas = np.empty((t, k), np.float32)
        weights = np.empty((t, k), np.float32)
        src = np.empty((t, n), np.float32)
        ref = np.empty((t, n), np.float32)
        for s, st in enumerate(self.shards):
            g = self.global_of[s]
            betas[g] = st.host.betas
            weights[g] = st.host.weights
            src[g] = st.host.src_quantiles
            ref[g] = st.host.ref_quantiles
        return TransformBank(
            betas=jnp.asarray(betas), weights=jnp.asarray(weights),
            src_quantiles=jnp.asarray(src), ref_quantiles=jnp.asarray(ref),
            generation=generation)

    # --------------------------------------------------------------- private
    @contextlib.contextmanager
    def _locked(self):
        """Hold every shard's lock, acquired in shard order (the one
        global lock order — no deadlock against per-shard paths)."""
        with contextlib.ExitStack() as stack:
            for st in self.shards:
                stack.enter_context(st._lock)
            yield

    def _stacked_views(self, views: Sequence[_TierView]) -> tuple:
        key = tuple(views)
        if self._stacked is None or self._stacked_key is None \
                or len(self._stacked_key) != len(key) \
                or not all(a is b for a, b in zip(self._stacked_key, key)):
            self._stacked = (
                jnp.stack([v.betas for v in key]),
                jnp.stack([v.weights for v in key]),
                jnp.stack([v.src_quantiles for v in key]),
                jnp.stack([v.ref_quantiles for v in key]))
            self._stacked_key = key
        return self._stacked

    def _bucket(self, tid: np.ndarray
                ) -> tuple[np.ndarray, list[np.ndarray]]:
        """Local ids + per-shard event-index buckets for one window."""
        shard_ids = self.shard_of[tid]
        local = self.local_of[tid]
        buckets = [np.flatnonzero(shard_ids == s)
                   for s in range(self.num_shards)]
        return local, buckets

    # -------------------------------------------------------------- serving
    def dispatch(self, expert_scores: np.ndarray, tenant_idx: np.ndarray
                 ) -> tuple[np.ndarray, int]:
        """Score one mixed-tenant window across all shards; returns
        ``(scores, generation)``.

        Hot path: per-shard slot remap + ONE ``shard_map`` launch of the
        banked kernel over the stacked per-shard views.  Cold misses
        stage per shard exactly like the single store; a window that
        overflows some shard's victim cache runs joint multi-pass rounds
        (every shard's pass dispatches in the same launch)."""
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return np.empty(0, np.float32), self.generation
        local, buckets = self._bucket(tid)
        k = raws.shape[-1]
        s_count = self.num_shards
        with self._locked():
            gen = self.shards[0]._view.generation
            for s, st in enumerate(self.shards):
                if len(buckets[s]):
                    st._record_window_locked(local[buckets[s]])
            self.joint_metrics["dispatches"] += 1
            out = np.empty(len(tid), np.float32)
            done = [np.zeros(len(b), bool) for b in buckets]
            passes = 0
            while not all(d.all() for d in done):
                ready_evs: list[np.ndarray] = []
                slot_vecs: list[np.ndarray] = []
                views: list[_TierView] = []
                for s, st in enumerate(self.shards):
                    if not len(buckets[s]) or done[s].all():
                        ready_evs.append(np.empty(0, np.int64))
                        slot_vecs.append(np.empty(0, np.int32))
                        views.append(st._view)
                        continue
                    eff, ready = st._resolve_pass_locked(
                        local[buckets[s]], done[s])
                    ev = np.flatnonzero(ready)
                    ready_evs.append(ev)
                    slot_vecs.append(eff[ev].astype(np.int32))
                    views.append(st._view)
                widest = max(len(e) for e in ready_evs)
                if widest == 0:  # pragma: no cover — per-shard progress
                    raise RuntimeError(
                        "tiered+sharded dispatch made no progress")
                bs = _shape_bucket(widest)
                packed = np.zeros((s_count, bs, k), np.float32)
                pidx = np.zeros((s_count, bs), np.int32)
                for s, ev in enumerate(ready_evs):
                    n = len(ev)
                    if n:
                        packed[s, :n] = raws[buckets[s][ev]]
                        pidx[s, :n] = slot_vecs[s]
                        if n < bs:
                            # edge pad per shard — keeps the kernel's
                            # uniform-block fast path, same as the pure-
                            # sharded dispatcher's _pack_bucket
                            pidx[s, n:] = pidx[s, n - 1]
                res = self.dispatcher.run_packed(
                    packed, pidx, *self._stacked_views(views))
                for s, ev in enumerate(ready_evs):
                    n = len(ev)
                    if n:
                        out[buckets[s][ev]] = res[s, :n]
                        done[s][ev] = True
                passes += 1
            if passes > 1:
                self.joint_metrics["extra_passes"] += passes - 1
            return out, gen

    def prefetch(self, tenant_idx: np.ndarray) -> int:
        """Per-shard anti-stall prefetch (each shard's copy overlaps its
        own lock independently); returns total rows staged."""
        tid = np.asarray(tenant_idx, np.int64).ravel()
        if tid.size == 0:
            return 0
        local, buckets = self._bucket(tid)
        staged = 0
        for s, st in enumerate(self.shards):
            if len(buckets[s]):
                staged += st.prefetch(local[buckets[s]])
        return staged

    def pre_quantile(self, expert_scores: np.ndarray,
                     tenant_idx: np.ndarray) -> np.ndarray:
        """Per-event T^Q input through each row's owning shard (row-local
        numpy math — identical values to the single-store path)."""
        raws = np.asarray(expert_scores, np.float32)
        tid = np.asarray(tenant_idx, np.int64).ravel()
        local, buckets = self._bucket(tid)
        out: np.ndarray | None = None
        for s, st in enumerate(self.shards):
            if not len(buckets[s]):
                continue
            vals = st.pre_quantile(raws[buckets[s]], local[buckets[s]])
            if out is None:
                out = np.empty(len(tid), vals.dtype)
            out[buckets[s]] = vals
        return out if out is not None else np.empty(0, np.float32)

    # -------------------------------------------------------------- control
    def rebalance(self, *, generation: int | None = None) -> dict[str, int]:
        """One promotion/demotion/admission pass on EVERY shard under the
        full lock set (generation fencing checked once, against the
        lockstep store generation)."""
        with self._locked():
            cur = self.shards[0]._view.generation
            if generation is not None and generation < cur:
                raise StaleGenerationError(generation, cur)
            agg = {"admitted": 0, "promoted": 0, "demoted": 0}
            for st in self.shards:
                r = st.rebalance()
                agg["admitted"] += r["admitted"]
                agg["promoted"] += r["promoted"]
                agg["demoted"] += r["demoted"]
            return {**agg, "generation": cur}

    def apply_updates(self, updates: Mapping[int, "QuantileMap | tuple"],
                      *, generation: int | None = None) -> int:
        """Publish refreshed T^Q tables (GLOBAL row ids) into every
        shard's host rows AND device-resident copies under ONE
        generation.

        All shard locks are held across the whole publish; every shard's
        ``apply_updates`` lands with the SAME explicit generation
        (untouched shards take an empty fenced fast-forward), so
        per-shard generations can never diverge.  Row ids and table
        widths are validated BEFORE the first shard write — a bad update
        raises with no shard touched (no torn cross-shard publish).
        Fencing semantics match :meth:`TieredBankStore.apply_updates`.
        """
        with self._locked():
            cur = self.shards[0]._view.generation
            if generation is None:
                if not updates:
                    return cur
                gen = cur + 1
            else:
                if generation <= cur:
                    raise StaleGenerationError(generation, cur)
                gen = generation
            n = self.shards[0].host.num_quantiles
            per: list[dict] = [dict() for _ in range(self.num_shards)]
            for row, value in updates.items():
                if not 0 <= row < self.num_rows:
                    raise IndexError(
                        f"row {row} outside store of {self.num_rows}")
                # dry-run pad: raises ValueError on an over-wide table
                # BEFORE any shard is written
                pad_quantile_tables(value, n, row=row)
                per[int(self.shard_of[row])][int(self.local_of[row])] = value
            for s, st in enumerate(self.shards):
                st.apply_updates(per[s], generation=gen)
            return gen

    def mark_cold(self, rows: Sequence[int]) -> None:
        """Send GLOBAL rows back behind the Eq.-5 gate on their owning
        shards."""
        ids = np.asarray(list(rows), np.int64)
        if not len(ids):
            return
        local, buckets = self._bucket(ids)
        for s, st in enumerate(self.shards):
            if len(buckets[s]):
                st.mark_cold(local[buckets[s]])

    def seen(self, row: int) -> int:
        return self.shards[int(self.shard_of[row])].seen(
            int(self.local_of[row]))

    # ---------------------------------------------------------- persistence
    def hotness_snapshot(self) -> dict:
        """GLOBAL-indexed hotness/admission state — the same layout a
        single :class:`TieredBankStore` emits, so rollouts warm start
        across topologies (single-tier <-> sharded-tier)."""
        t = self.num_rows
        scores = np.zeros(t, np.float64)
        seen = np.zeros(t, np.int64)
        adm = np.zeros(t, bool)
        windows = 0
        with self._locked():
            for s, st in enumerate(self.shards):
                g = self.global_of[s]
                scores[g] = st.tracker.scores()
                seen[g] = st._seen
                adm[g] = st.host.admitted
                windows = max(windows, st.tracker.windows)
        return {"tracker": {"num_keys": t, "decay": float(self.config.decay),
                            "scores": scores, "windows": windows},
                "seen": seen, "admitted": adm}

    def adopt_hotness(self, snap: dict) -> None:
        scores = np.asarray(snap["tracker"]["scores"], np.float64)
        seen = np.asarray(snap["seen"], np.int64)
        adm = np.asarray(snap["admitted"], bool)
        windows = int(snap["tracker"].get("windows", 0))
        n = min(len(scores), self.num_rows)
        with self._locked():
            for s, st in enumerate(self.shards):
                g = self.global_of[s]
                valid = g < n
                # rows past the snapshot (size mismatch) keep their local
                # seen/admitted state — the single store's prefix-adopt
                # semantics; tracker scores reset to 0 either way
                sub_scores = np.zeros(len(g), np.float64)
                sub_seen = st._seen.copy()
                sub_adm = st.host.admitted.copy()
                sub_scores[valid] = scores[g[valid]]
                sub_seen[valid] = seen[g[valid]]
                sub_adm[valid] = adm[g[valid]]
                st.adopt_hotness({
                    "tracker": {"num_keys": len(g),
                                "decay": float(self.config.decay),
                                "scores": sub_scores, "windows": windows},
                    "seen": sub_seen, "admitted": sub_adm})
