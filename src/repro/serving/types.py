"""Serving-layer value objects."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Mapping

import numpy as np

from repro.core.routing import Intent

_req_counter = itertools.count()


class StaleGenerationError(RuntimeError):
    """A fenced publish arrived with a generation ≤ the one already served.

    The fleet publish protocol stamps every broadcast with the fleet's
    target generation; a replica that already serves an equal-or-newer
    generation MUST reject the publish (a late ack from a superseded fleet
    pass can otherwise roll a replica's transformations backwards).  The
    tiered bank store (``serving/tiering.py``) enforces the same fence on
    its ``apply_updates``/``rebalance`` control operations, so it lives
    here rather than in ``server.py`` (which re-exports it).
    """

    def __init__(self, requested: int, current: int) -> None:
        super().__init__(
            f"fenced publish at generation {requested} rejected: replica "
            f"already serves generation {current}")
        self.requested = requested
        self.current = current


@dataclasses.dataclass(frozen=True)
class ScoringRequest:
    intent: Intent
    features: np.ndarray                      # (dim,) raw client payload
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    metadata: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ScoringResponse:
    request_id: int
    score: float                              # business-ready (post T^Q)
    predictor: str
    routing_version: str
    latency_ms: float
    raw_scores: tuple[float, ...] = ()        # per-expert raw scores (debug)
    # generation of the TransformBank this response was scored under — the
    # calibration-provenance stamp (every row of a window shares exactly one)
    bank_generation: int = -1


@dataclasses.dataclass(frozen=True)
class ShadowRecord:
    """What lands in the data lake for each shadow evaluation."""

    request_id: int
    tenant: str
    predictor: str
    score: float
    raw_scores: tuple[float, ...]
    routing_version: str
