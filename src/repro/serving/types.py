"""Serving-layer value objects."""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Mapping

import numpy as np

from repro.core.routing import Intent

_req_counter = itertools.count()


@dataclasses.dataclass(frozen=True)
class ScoringRequest:
    intent: Intent
    features: np.ndarray                      # (dim,) raw client payload
    request_id: int = dataclasses.field(default_factory=lambda: next(_req_counter))
    metadata: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ScoringResponse:
    request_id: int
    score: float                              # business-ready (post T^Q)
    predictor: str
    routing_version: str
    latency_ms: float
    raw_scores: tuple[float, ...] = ()        # per-expert raw scores (debug)
    # generation of the TransformBank this response was scored under — the
    # calibration-provenance stamp (every row of a window shares exactly one)
    bank_generation: int = -1


@dataclasses.dataclass(frozen=True)
class ShadowRecord:
    """What lands in the data lake for each shadow evaluation."""

    request_id: int
    tenant: str
    predictor: str
    score: float
    raw_scores: tuple[float, ...]
    routing_version: str
