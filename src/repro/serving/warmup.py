"""Replica warm-up (paper Sec. 3.1.2).

The paper's Java replicas suffer JIT-compilation latency on first requests;
MUSE exercises the real code path with synthetic traffic before marking the
pod ready.  The JAX analogue is exact: the first call through a predictor
triggers XLA compilation (tens-to-hundreds of ms), so a cold replica would
blow the latency SLO on live traffic.  ``warm_up`` pushes synthetic batches
through every predictor the routing table can reach, forcing compilation of
every (predictor, batch-shape) executable before readiness.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.routing import Intent
from repro.serving.types import ScoringRequest


def synthetic_requests(schema_dim: int, batch: int, tenant: str = "__warmup__",
                       seed: int = 0) -> list[ScoringRequest]:
    rng = np.random.default_rng(seed)
    return [
        ScoringRequest(
            intent=Intent(tenant=tenant),
            features=rng.normal(0, 1, schema_dim).astype(np.float32),
        )
        for _ in range(batch)
    ]


def warm_up(server, schema_dim: int, *, batch_sizes: tuple[int, ...] = (1, 8, 64),
            calls_per_shape: int = 2) -> dict[str, float]:
    """Exercise every deployed predictor at every serving batch shape.

    Returns {predictor: seconds_spent} — the Fig.-5 warm-up spike data.
    Bypasses routing (calls predictors directly) so catch-all rules do not
    hide predictors from the warm-up pass.
    """
    timings: dict[str, float] = {}
    for name, pred in server.predictors.items():
        t0 = time.perf_counter()
        for bs in batch_sizes:
            feats = np.random.default_rng(0).normal(0, 1, (bs, schema_dim)).astype(
                np.float32
            )
            for _ in range(calls_per_shape):
                pred(feats)
        timings[name] = time.perf_counter() - t0
    return timings
