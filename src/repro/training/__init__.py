"""Training substrate: optimizer, data pipelines, loop, checkpointing."""
from repro.training.optimizer import AdamW, AdamWState, cosine_schedule
from repro.training.train import StepMetrics, Trainer, TrainState, make_train_step

__all__ = ["AdamW", "AdamWState", "cosine_schedule", "StepMetrics", "Trainer",
           "TrainState", "make_train_step"]
