"""Checkpointing: pytree <-> flat .npz + orjson metadata (no orbax offline).

Layout:  <dir>/<step>/arrays.npz  +  <dir>/<step>/meta.json
Leaves are addressed by '/'-joined pytree key paths, restored into the same
structure, so any params/opt-state/cache pytree round-trips exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

try:
    import orjson
except ModuleNotFoundError:  # stdlib json fallback — same bytes-in/bytes-out
    orjson = None

PyTree = Any


def _json_dumps(obj: Any) -> bytes:
    if orjson is not None:
        return orjson.dumps(obj)
    return json.dumps(obj).encode()


def _json_loads(data: bytes) -> Any:
    if orjson is not None:
        return orjson.loads(data)
    return json.loads(data.decode())


def _flatten_with_paths(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    metadata: dict | None = None) -> str:
    path = os.path.join(directory, str(step))
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    # npz can't serialize ml_dtypes (bf16 etc.) — store raw bits + dtype map.
    dtypes: dict[str, str] = {}
    storable = {}
    for key, arr in flat.items():
        dtypes[key] = str(arr.dtype)
        if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
            arr = arr.view(np.uint8 if arr.dtype.itemsize == 1 else
                           np.uint16 if arr.dtype.itemsize == 2 else np.uint32)
        storable[key] = arr
    np.savez(os.path.join(path, "arrays.npz"), **storable)
    meta = {"step": step, "dtypes": dtypes, **(metadata or {})}
    with open(os.path.join(path, "meta.json"), "wb") as f:
        f.write(_json_dumps(meta))
    return path


def restore_checkpoint(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes preserved)."""
    path = os.path.join(directory, str(step))
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        arrays = dict(npz)
    meta = load_metadata(directory, step)
    dtypes = meta.get("dtypes", {})
    paths_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in paths_like:
        key = "/".join(_path_str(x) for x in p)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        stored_dtype = dtypes.get(key)
        if stored_dtype and str(arr.dtype) != stored_dtype:
            arr = arr.view(jax.numpy.dtype(stored_dtype))  # undo raw-bit view
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(directory: str, step: int) -> dict:
    with open(os.path.join(directory, str(step), "meta.json"), "rb") as f:
        return _json_loads(f.read())


def load_arrays(directory: str, step: int) -> dict[str, np.ndarray]:
    """Raw numpy leaves of a checkpoint, keyed by '/'-joined pytree path.

    Unlike :func:`restore_checkpoint` this never round-trips through jax
    arrays, so float64 leaves (e.g. estimator reservoirs) keep their dtype
    without x64 enabled.  Dtypes stored as raw bit views (bf16 etc.) are
    returned as stored; consult ``load_metadata()['dtypes']`` to undo."""
    with np.load(os.path.join(directory, str(step), "arrays.npz")) as npz:
        return dict(npz)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d) for d in os.listdir(directory) if d.isdigit()]
    return max(steps) if steps else None
