"""Synthetic data pipelines.

Two streams feed the framework:

1. **Fraud-event stream** — the MUSE evaluation substrate.  A documented
   generative process produces (features, label, score-relevant structure)
   with realistic class imbalance (0.2–2% fraud), per-tenant distribution
   shift, and configurable *undersampling* of the majority class (ratio
   ``beta``) so Posterior Correction has a known ground truth to undo.

2. **Token stream** — next-token LM batches for the architecture zoo's
   training path (deterministic PRNG; infinite iterator of (tokens, labels)).

Both are numpy-side (host) generators, double-buffered into device arrays by
the train loop — the usual host-bound pipeline shape.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


# ---------------------------------------------------------------------------
# Fraud events
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TenantProfile:
    """Per-tenant generative parameters (drives cross-tenant score shift)."""

    name: str
    fraud_rate: float = 0.005
    # class-conditional feature means are drawn from N(0, spread) per tenant
    feature_shift: float = 0.0
    amount_scale: float = 100.0
    seed: int = 0


@dataclasses.dataclass
class FraudEventStream:
    """Synthetic fraud-detection events.

    Features: d-dim Gaussian mixture; fraud events are shifted by a direction
    vector, so a linear-logit "model" has known Bayes posterior — this lets
    tests verify Posterior Correction against closed-form truth.
    """

    profile: TenantProfile
    dim: int = 16
    _rng: np.random.Generator = dataclasses.field(init=False)

    def __post_init__(self) -> None:
        self._rng = np.random.default_rng(self.profile.seed)
        # stable hash: hash() is PYTHONHASHSEED-randomized per process, which
        # would make tenant fraud directions (and every downstream number)
        # non-reproducible across runs
        import zlib
        base_rng = np.random.default_rng(zlib.crc32(self.profile.name.encode()))
        self.direction = base_rng.normal(0, 1, self.dim)
        self.direction /= np.linalg.norm(self.direction)
        self.separation = 2.2  # class separation along `direction`

    def sample(self, n: int) -> tuple[np.ndarray, np.ndarray]:
        """Returns (features (n, dim), labels (n,))."""
        p = self.profile
        y = (self._rng.random(n) < p.fraud_rate).astype(np.int64)
        x = self._rng.normal(0, 1, (n, self.dim)) + p.feature_shift
        x += y[:, None] * self.separation * self.direction[None, :]
        return x.astype(np.float32), y

    def sample_undersampled(self, n_target: int, beta: float
                            ) -> tuple[np.ndarray, np.ndarray]:
        """Training set with the majority (negative) class undersampled at
        ratio ``beta`` = P(keep negative) — the paper's Sec. 2.3.1 setup."""
        xs, ys = [], []
        total = 0
        while total < n_target:
            x, y = self.sample(4 * n_target)
            keep = (y == 1) | (self._rng.random(len(y)) < beta)
            xs.append(x[keep])
            ys.append(y[keep])
            total += int(keep.sum())
        x = np.concatenate(xs)[:n_target]
        y = np.concatenate(ys)[:n_target]
        return x, y

    def bayes_posterior(self, x: np.ndarray) -> np.ndarray:
        """Closed-form P(y=1 | x) for this generative process."""
        p = self.profile
        proj = x @ self.direction
        mu0 = p.feature_shift * self.direction.sum()
        # log-likelihood ratio of the two unit-variance Gaussians along `direction`
        llr = self.separation * (proj - mu0) - 0.5 * self.separation**2
        prior = np.log(p.fraud_rate / (1 - p.fraud_rate))
        return 1.0 / (1.0 + np.exp(-(llr + prior)))


def logistic_expert_scores(x: np.ndarray, w: np.ndarray, b: float) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-(x @ w + b)))


def fit_logistic_expert(x: np.ndarray, y: np.ndarray, *, steps: int = 300,
                        lr: float = 0.5, seed: int = 0
                        ) -> tuple[np.ndarray, float]:
    """Tiny logistic-regression 'expert model' trained by full-batch GD.

    Trained on *undersampled* data it learns the biased posterior — exactly
    the bias T^C must remove.
    """
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.01, x.shape[1])
    b = 0.0
    for _ in range(steps):
        p = 1.0 / (1.0 + np.exp(-(x @ w + b)))
        g = p - y
        w -= lr * (x.T @ g / len(y) + 1e-4 * w)
        b -= lr * float(g.mean())
    return w, b


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM batches: (tokens, next-token labels).

    A Zipfian unigram mixed with short-range induction patterns so the loss
    has learnable structure (models improve measurably within ~100 steps).
    """

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        ranks = np.arange(1, self.vocab_size + 1)
        probs = 1.0 / ranks**1.1
        probs /= probs.sum()
        while True:
            toks = rng.choice(self.vocab_size, size=(self.batch_size,
                                                     self.seq_len + 1), p=probs)
            # induction: repeat a random earlier span in 30% of rows
            for i in range(self.batch_size):
                if rng.random() < 0.3:
                    span = rng.integers(4, max(5, self.seq_len // 4))
                    start = rng.integers(0, self.seq_len // 2)
                    dest = rng.integers(self.seq_len // 2,
                                        self.seq_len + 1 - span)
                    toks[i, dest : dest + span] = toks[i, start : start + span]
            yield toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32)
