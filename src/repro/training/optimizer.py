"""AdamW + schedules, pure JAX (no optax dependency in this environment).

Optimizer state is a pytree mirroring the params, so pjit shards it with the
same rules as the parameters (ZeRO-style: moments inherit param shardings).
``moment_dtype`` allows bf16 first/second moments — the memory optimization
recorded in EXPERIMENTS.md §Perf for the 400B-class models.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class AdamWState(NamedTuple):
    step: Array     # () int32
    mu: PyTree      # first moments
    nu: PyTree      # second moments


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable[[Array], Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32

    def init(self, params: PyTree) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def _lr(self, step: Array) -> Array:
        if callable(self.learning_rate):
            return jnp.asarray(self.learning_rate(step), jnp.float32)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads: PyTree, state: AdamWState, params: PyTree
               ) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        # global-norm clipping
        if self.grad_clip_norm > 0:
            gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads))
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g.astype(jnp.float32)).astype(self.moment_dtype),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * jnp.square(g.astype(jnp.float32))
                          ).astype(self.moment_dtype),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1) -> Callable[[Array], Array]:
    def lr(step: Array) -> Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr
