"""Training loop: jitted train_step + host-side data feed + checkpoints.

``make_train_step`` is the same function the multi-pod dry-run lowers — one
definition serves CPU smoke tests, the examples, and the 512-chip compile.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState

Array = jax.Array
PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: AdamWState


class StepMetrics(NamedTuple):
    loss: Array
    ce_loss: Array
    moe_aux: Array
    grad_norm: Array


def make_loss_fn(model: Model, *, aux_weight: float = 0.01,
                 remat: bool = True, compute_dtype=jnp.bfloat16,
                 attn_impl: str = "reference", act_pspec=None,
                 cast_params_bf16: bool = False):
    def loss_fn(params: PyTree, tokens: Array, labels: Array
                ) -> tuple[Array, tuple[Array, Array]]:
        if cast_params_bf16:
            # Cast the f32 master weights once at step entry so FSDP weight
            # all-gathers (and the backward's mirrored reduce-scatters) move
            # bf16 — half the collective bytes of gathering f32 masters.
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p,
                params,
            )
        if model.cfg.embeds_input:
            # frontend stub: embed via the token table then detach semantics
            # (tokens stand in for precomputed frame/patch features).
            out = model.forward(
                params, embeds=None, tokens=tokens, remat=remat,
                compute_dtype=compute_dtype, attn_impl=attn_impl,
                act_pspec=act_pspec,
            )
        else:
            out = model.forward(params, tokens=tokens, remat=remat,
                                compute_dtype=compute_dtype,
                                attn_impl=attn_impl, act_pspec=act_pspec)
        logits = out.logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
        loss = ce + aux_weight * out.moe_aux
        return loss, (ce, out.moe_aux)

    return loss_fn


def make_train_step(model: Model, optimizer: AdamW, *, aux_weight: float = 0.01,
                    remat: bool = True, compute_dtype=jnp.bfloat16,
                    attn_impl: str = "reference", act_pspec=None,
                    cast_params_bf16: bool = False
                    ) -> Callable[[TrainState, Array, Array],
                                  tuple[TrainState, StepMetrics]]:
    loss_fn = make_loss_fn(model, aux_weight=aux_weight, remat=remat,
                           compute_dtype=compute_dtype, attn_impl=attn_impl,
                           act_pspec=act_pspec,
                           cast_params_bf16=cast_params_bf16)

    def train_step(state: TrainState, tokens: Array, labels: Array
                   ) -> tuple[TrainState, StepMetrics]:
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, tokens, labels
        )
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        new_params, new_opt = optimizer.update(grads, state.opt, state.params)
        return TrainState(new_params, new_opt), StepMetrics(loss, ce, aux, gnorm)

    return train_step


@dataclasses.dataclass
class Trainer:
    model: Model
    optimizer: AdamW
    aux_weight: float = 0.01
    remat: bool = True
    compute_dtype: Any = jnp.bfloat16
    checkpoint_dir: str | None = None
    checkpoint_every: int = 0

    def init_state(self, rng) -> TrainState:
        params = self.model.init(rng)
        return TrainState(params=params, opt=self.optimizer.init(params))

    def fit(self, state: TrainState,
            batches: Iterator[tuple[np.ndarray, np.ndarray]],
            num_steps: int, log_every: int = 10,
            log_fn=print) -> tuple[TrainState, list[dict]]:
        step_fn = jax.jit(make_train_step(
            self.model, self.optimizer, aux_weight=self.aux_weight,
            remat=self.remat, compute_dtype=self.compute_dtype,
        ), donate_argnums=(0,))
        history: list[dict] = []
        t0 = time.perf_counter()
        for step in range(1, num_steps + 1):
            tokens, labels = next(batches)
            state, metrics = step_fn(state, jnp.asarray(tokens),
                                     jnp.asarray(labels))
            if step % log_every == 0 or step == num_steps:
                rec = {
                    "step": step,
                    "loss": float(metrics.loss),
                    "ce": float(metrics.ce_loss),
                    "moe_aux": float(metrics.moe_aux),
                    "grad_norm": float(metrics.grad_norm),
                    "elapsed_s": time.perf_counter() - t0,
                }
                history.append(rec)
                log_fn(f"step {rec['step']:>5d}  loss {rec['loss']:.4f}  "
                       f"ce {rec['ce']:.4f}  gnorm {rec['grad_norm']:.3f}")
            if (self.checkpoint_dir and self.checkpoint_every
                    and step % self.checkpoint_every == 0):
                from repro.training import checkpoint as ckpt
                ckpt.save_checkpoint(self.checkpoint_dir, step, state.params,
                                     {"loss": float(metrics.loss)})
        return state, history
