#!/usr/bin/env bash
# Test runner: CPU-hosted multi-device JAX + src-layout imports.
#
#   ./test.sh                fast suite (excludes -m slow campaigns, the
#                            -m concurrency threaded tests AND the -m sharded
#                            multi-device campaign, so the -x pass stays
#                            single-threaded and deterministic)
#   ./test.sh --slow         only the slow scenario tests
#   ./test.sh --concurrency  only the threaded reader/writer + engine tests
#   ./test.sh --sharded      only the multi-device sharded-bank parity campaign
#   ./test.sh --fleet        only the multi-replica fleet-calibration campaigns
#   ./test.sh --adversarial  the attack-campaign + audit-trail suite (fast
#                            subset also rides the default lane; the multi-day
#                            replay itself is additionally marked slow)
#   ./test.sh --tracking     only the fused device quantile-tracking
#                            campaign (bitwise host/device estimator parity,
#                            host-pull boundaries, seed-framing regressions;
#                            single-device, so it also rides the default lane)
#   ./test.sh --tiering      only the tiered-bank-store campaigns (random
#                            promote/demote/publish property tests, engine
#                            prefetch, rollout warm start, and the
#                            tiered-over-sharded composed campaigns — those
#                            skip themselves below the needed device count,
#                            so the fast subsets that ride the default lane
#                            unmarked keep tier-1 green on 1 device)
#   ./test.sh --all          everything (what CI tier-1 runs)
#   ./test.sh [pytest args...]   extra args forwarded to pytest
set -euo pipefail
cd "$(dirname "$0")"

# 8 virtual CPU devices so mesh/sharding tests exercise real multi-device
# paths without a TPU (standard jax_pallas CI idiom).
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

case "${1:-}" in
  --slow)        shift; exec python -m pytest -q -m slow "$@" ;;
  --concurrency) shift; exec python -m pytest -q -m concurrency "$@" ;;
  --sharded)     shift; exec python -m pytest -q -m sharded "$@" ;;
  --fleet)       shift; exec python -m pytest -q -m fleet "$@" ;;
  --adversarial) shift; exec python -m pytest -q -m adversarial "$@" ;;
  --tiering)     shift; exec python -m pytest -q -m tiering "$@" ;;
  --tracking)    shift; exec python -m pytest -q -m tracking "$@" ;;
  --all)         shift; exec python -m pytest -q "$@" ;;
  *)             exec python -m pytest -q -m "not slow and not concurrency and not sharded and not fleet and not tiering" "$@" ;;
esac
