"""Deterministic fallback for `hypothesis` when it is not installed.

The CI image does not ship `hypothesis`; rather than skip the five
property-test modules wholesale, install a miniature deterministic stand-in
exposing exactly the API subset the suite uses: ``given``, ``settings`` and
``strategies.{integers, floats, booleans, sampled_from, lists}``.  Each
``@given`` test runs a bounded seeded sweep of drawn examples (boundary
values first), so the properties are still exercised — just without
shrinking or the full example budget.  When the real hypothesis is
importable it is used untouched.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

try:
    import hypothesis  # noqa: F401  (real library wins when present)
except ModuleNotFoundError:
    # Examples per @given test. Enough to hit every boundary value plus a
    # seeded random sweep while keeping suite runtime close to the seed's.
    _MAX_EXAMPLES_CAP = 12

    class _Strategy:
        """A draw rule plus the boundary examples emitted first."""

        def __init__(self, draw, edges=()):
            self.draw = draw
            self._edges = tuple(edges)

        def example(self, rng: random.Random, i: int):
            if i < len(self._edges):
                return self._edges[i]
            return self.draw(rng)

    def _integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         edges=(min_value, max_value))

    def _floats(min_value: float, max_value: float, **_: object) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         edges=(min_value, max_value))

    def _booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, edges=(False, True))

    def _sampled_from(elements) -> _Strategy:
        pool = list(elements)
        return _Strategy(lambda rng: rng.choice(pool), edges=pool[:2])

    def _lists(elements: _Strategy, *, min_size: int = 0,
               max_size: int | None = None, unique: bool = False) -> _Strategy:
        def draw(rng: random.Random):
            hi = max_size if max_size is not None else min_size + 5
            size = rng.randint(min_size, hi)
            out: list = []
            for _ in range(100):
                if len(out) >= size:
                    break
                v = elements.draw(rng)
                if unique and v in out:
                    continue
                out.append(v)
            return out

        return _Strategy(draw)

    def _settings(**kwargs):
        def decorate(func):
            func._mini_hypothesis_settings = dict(kwargs)
            return func

        return decorate

    def _given(*pos_strategies, **kw_strategies):
        def decorate(func):
            conf = getattr(func, "_mini_hypothesis_settings", {})
            n_examples = min(conf.get("max_examples", _MAX_EXAMPLES_CAP),
                             _MAX_EXAMPLES_CAP)
            sig = inspect.signature(func)
            mapping = dict(kw_strategies)
            if pos_strategies:
                # hypothesis semantics: positional strategies fill the test's
                # parameters from the right (after self / fixtures).
                free = [n for n in sig.parameters if n not in mapping]
                mapping.update(zip(free[-len(pos_strategies):], pos_strategies))
            remaining = [p for n, p in sig.parameters.items()
                         if n not in mapping]

            @functools.wraps(func)
            def wrapper(*args, **kwargs):
                rng = random.Random(zlib.crc32(func.__qualname__.encode()))
                for i in range(n_examples):
                    drawn = {n: s.example(rng, i) for n, s in mapping.items()}
                    func(*args, **{**kwargs, **drawn})

            # pytest must see only the non-strategy params (fixtures/self);
            # drop the wraps-installed __wrapped__ so nothing unwraps back to
            # the full strategy-bearing signature.
            del wrapper.__wrapped__
            wrapper.__signature__ = sig.replace(parameters=remaining)
            return wrapper

        return decorate

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda condition: bool(condition)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
