"""Async banked dispatch engine: the concurrency test campaign.

Proves the ROADMAP's "Async banked dispatch" + "Refresh under live
concurrency" items: the stage-pipelined engine preserves the synchronous
path's semantics (parity, 1:1 request/response mapping, per-key ordering,
per-dispatch latency), and the PR-2 atomic ``TransformBank`` swap survives
genuinely overlapping dispatches — a ``refresh_fleet`` publish landing
mid-stream never produces a torn read, and the bank generations any one
stream observes are monotone.

Threaded tests are marked ``concurrency`` (isolated from the fast ``-x``
pass via ``./test.sh --concurrency``); the end-to-end soak is additionally
``slow``.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import StreamingQuantileEstimator, required_sample_size
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule, ShadowRule
from repro.core.transforms import QuantileMap, score_pipeline
from repro.serving import (
    AsyncDispatchEngine,
    CalibrationController,
    MicroBatcher,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    RollingUpdate,
    ServerBatcher,
    ServerConfig,
)
from repro.serving.types import ScoringRequest

DIM = 8
TOL = 1e-5
REF = np.linspace(0.0, 1.0, 64) ** 2  # smooth, front-loaded reference


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2, 3)}


def _req(tenant, seed):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


def _fleet(n_tenants=4, *, shadow=False, n_groups=1) -> MuseServer:
    """One predictor per tenant; predictors alternate between ``n_groups``
    model groups ({m1,m2} vs {m1,m2,m3}) so multi-key batching is real."""
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    shadows = (ShadowRule(Condition(tenants=("t0",)), ("p-sh",)),) \
        if shadow else ()
    server = MuseServer(
        RoutingTable(rules, shadows, version="v1"),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5))
    for i in range(n_tenants):
        group = ("m1", "m2") if n_groups == 1 or i % 2 == 0 \
            else ("m1", "m2", "m3")
        betas = (0.2, 0.4) if len(group) == 2 else (0.2, 0.4, 0.1)
        server.deploy(PredictorSpec(f"p{i}", group, betas,
                                    (1.0,) * len(group),
                                    QuantileMap.identity(64)), FACTORIES)
    if shadow:
        server.deploy(PredictorSpec("p-sh", ("m1", "m2"), (0.5, 0.9),
                                    (2.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


def _policy(**kw) -> RefreshPolicy:
    base = dict(alert_rate=0.05, rel_error=0.5, n_levels=64)
    base.update(kw)
    return RefreshPolicy(**base)


def _inject(server, tenant, pred, n=5000, seed=0):
    """A gate-passing estimator stream big enough that concurrent live
    tracking cannot move its distribution (refresh validation stays green)."""
    rng = np.random.default_rng(seed)
    est = StreamingQuantileEstimator(capacity=131072, seed=seed)
    est.update(rng.uniform(0, 1, n))
    server._estimators[(tenant, pred)] = est
    return est


def _pipeline_registry(server):
    return {n: p.pipeline for n, p in server.predictors.items()}


def _assert_consistent(responses, registry):
    """Every response's score must reproduce from the pipelines of the ONE
    generation it is stamped with — any torn read diverges."""
    for resp in responses:
        pipe = registry[resp.bank_generation][resp.predictor]
        want = float(score_pipeline(
            jnp.asarray(resp.raw_scores, jnp.float32), pipe.betas,
            pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
        assert resp.score == pytest.approx(want, abs=TOL), \
            (resp.request_id, resp.predictor, resp.bank_generation)


def _assert_monotone_generations(responses):
    """Per stream (tenant), in completion order, generations never step back."""
    seen: dict[str, int] = {}
    for resp in responses:
        tenant = resp.predictor  # one predictor per tenant in _fleet
        last = seen.get(tenant, -1)
        assert resp.bank_generation >= last, \
            (tenant, last, resp.bank_generation)
        seen[tenant] = resp.bank_generation


class TestEngineParity:
    def test_pipelined_scores_match_sync_path(self):
        sync, pipe = _fleet(4), _fleet(4)
        reqs = [_req(f"t{i % 4}", i) for i in range(40)]
        want = {r.request_id: r.score for r in sync.score_batch(reqs)}
        engine = AsyncDispatchEngine(pipe, max_batch=8, max_wait_ms=1e9)
        futs = [engine.submit(r) for r in reqs]
        out = engine.drain()
        engine.close()
        assert sorted(r.request_id for r in out) == \
            sorted(r.request_id for r in reqs)
        for resp in out:
            assert resp.score == pytest.approx(want[resp.request_id], abs=TOL)
            assert resp.bank_generation == 0
        assert all(f.done() for f in futs)
        # exactly one model-group call + one kernel dispatch per window —
        # the pipelining adds no extra executions
        assert pipe.metrics["model_group_calls"] == len(engine.window_log)
        assert pipe.metrics["kernel_dispatches"] == len(engine.window_log)
        assert pipe.metrics["requests"] == len(reqs)

    def test_score_batch_facade_preserves_request_order(self):
        sync, pipe = _fleet(3), _fleet(3)
        engine = AsyncDispatchEngine(pipe, max_batch=8, max_wait_ms=1e9)
        reqs = [_req(f"t{i % 3}", 50 + i) for i in range(20)]
        want = sync.score_batch(reqs)
        got = engine.score_batch(reqs)
        engine.close()
        assert [r.request_id for r in got] == [r.request_id for r in reqs]
        np.testing.assert_allclose([r.score for r in got],
                                   [r.score for r in want], atol=TOL)

    def test_self_scheduling_poll_flushes_aged_windows(self):
        server = _fleet(2)
        engine = AsyncDispatchEngine(server, max_batch=100,
                                     max_wait_ms=10.0).start()
        try:
            futs = [engine.submit(_req("t0", i)) for i in range(3)]
            # no manual poll()/flush()/drain(): the armed timer must flush
            # the aged-out window and resolve the futures on its own.
            # Generous bound: the 8-device lanes pay first-trace costs here
            resps = [f.result(timeout=60.0) for f in futs]
            assert [r.request_id for r in resps] == \
                [f.result().request_id for f in futs]
        finally:
            engine.close()

    def test_shadow_dedup_through_engine(self):
        server = _fleet(2, shadow=True)
        engine = AsyncDispatchEngine(server, max_batch=4, max_wait_ms=1e9)
        reqs = [_req("t0", 70 + i) for i in range(4)]
        out = engine.score_batch(reqs)
        engine.close()
        # live + shadow share {m1,m2}: ONE model-group call, TWO kernel
        # dispatches, raw scores reused by the shadow rows
        assert server.metrics["model_group_calls"] == 1
        assert server.metrics["kernel_dispatches"] == 2
        recs = server.sink.records("p-sh")
        assert len(recs) == 4
        by_id = {r.request_id: r for r in out}
        for rec in recs:
            assert rec.raw_scores == by_id[rec.request_id].raw_scores

    def test_latency_is_per_dispatch_not_cumulative(self):
        server = _fleet(2)
        engine = AsyncDispatchEngine(server, max_batch=16, max_wait_ms=1e9)
        engine.score_batch([_req("t0", i) for i in range(16)])  # warm/compile
        engine.take_completed()
        engine.window_log.clear()
        futs = [engine.submit(_req(f"t{i % 2}", 100 + i)) for i in range(48)]
        out = engine.drain()
        engine.close()
        assert len(out) == len(futs) == 48
        lats = [w["latency_ms"] for w in engine.window_log]
        assert len(lats) == 3 and all(l > 0 for l in lats)
        # a cumulative (stale-t0) latency would make the last window carry
        # roughly the sum of all three dispatch times
        assert max(lats) < 0.8 * sum(lats)
        # each response reports ITS window's dispatch latency
        per_window = {round(w["latency_ms"], 9): w["size"]
                      for w in engine.window_log}
        for resp in out:
            assert round(resp.latency_ms, 9) in per_window

    def test_submit_after_close_raises(self):
        engine = AsyncDispatchEngine(_fleet(1), max_batch=4, max_wait_ms=1e9)
        engine.close()
        with pytest.raises(RuntimeError):
            engine.submit(_req("t0", 0))


class TestOrderingProperties:
    """Property-style ordering invariants (hypothesis shim)."""

    @settings(max_examples=10)
    @given(st.integers(2, 6), st.integers(5, 40), st.integers(1, 4))
    def test_microbatcher_flushes_map_one_to_one_per_key(
            self, max_batch, n, n_keys):
        mb = MicroBatcher(max_batch=max_batch, max_wait_ms=1e9)
        submitted: dict[str, list[int]] = {}
        flushed: dict[str, list[int]] = {}
        key_of: dict[int, str] = {}
        for i in range(n):
            key = f"k{i % n_keys}"
            r = _req(key, i)
            key_of[r.request_id] = key
            submitted.setdefault(key, []).append(r.request_id)
            out = mb.add(key, r)
            if out is not None:
                assert len(out) == max_batch  # size trigger is exact
                for rr in out:
                    assert key_of[rr.request_id] == key
                flushed.setdefault(key, []).extend(
                    rr.request_id for rr in out)
        for key, batch in mb.flush_all():
            flushed.setdefault(key, []).extend(r.request_id for r in batch)
        # 1:1 per key AND submission order preserved within each key
        assert flushed == submitted
        assert mb.pending_count == 0

    @settings(max_examples=12)
    @given(st.floats(0.5, 50.0), st.floats(0.0, 100.0))
    def test_age_flush_fires_deterministically(self, wait_ms, advance_ms):
        if abs(advance_ms - wait_ms) < 1e-6:
            return  # exact-boundary draws are fp-ambiguous by construction
        t = [0.0]
        mb = MicroBatcher(max_batch=100, max_wait_ms=wait_ms,
                          clock=lambda: t[0])
        mb.add("a", _req("a", 0))
        t[0] = advance_ms / 1000.0
        expired = mb.expired()
        if advance_ms > wait_ms:
            assert len(expired) == 1 and len(expired[0][1]) == 1
            assert mb.pending_count == 0
        else:
            assert expired == [] and mb.pending_count == 1

    @settings(max_examples=5)
    @given(st.integers(1, 4), st.integers(6, 20))
    def test_server_batcher_responses_map_one_to_one(self, max_batch, n):
        server = _fleet(3)
        sb = ServerBatcher(server, MicroBatcher(max_batch=max_batch,
                                                max_wait_ms=1e9))
        reqs = [_req(f"t{i % 3}", i) for i in range(n)]
        got: dict[int, str] = {}

        def record(resps):
            for r in resps:
                assert r.request_id not in got  # no duplicates
                got[r.request_id] = r.predictor

        for r in reqs:
            out = sb.submit(r)
            if out is not None:
                record(out)
        record(sb.drain())
        assert sorted(got) == sorted(r.request_id for r in reqs)  # no drops
        for r in reqs:
            assert got[r.request_id] == f"p{int(r.intent.tenant[1:]) % 3}"

    def test_engine_preserves_per_key_submission_order(self):
        server = _fleet(6, n_groups=2)  # p0/2/4 on {m1,m2}; p1/3/5 on 3-group
        engine = AsyncDispatchEngine(server, max_batch=4, max_wait_ms=1e9)
        reqs = [_req(f"t{i % 6}", 200 + i) for i in range(48)]
        futs = [engine.submit(r) for r in reqs]
        out = engine.drain()
        engine.close()
        assert sorted(r.request_id for r in out) == \
            sorted(r.request_id for r in reqs)
        assert all(f.done() for f in futs)
        assert len(engine.window_log) == 48 // 4
        # within each model-group key, completion order == submission order
        group_of = {f"t{i}": ("even" if i % 2 == 0 else "odd")
                    for i in range(6)}
        submitted = {"even": [], "odd": []}
        for r in reqs:
            submitted[group_of[r.intent.tenant]].append(r.request_id)
        completed = {"even": [], "odd": []}
        for r in out:
            completed[group_of[f"t{r.predictor[1:]}"]].append(r.request_id)
        assert completed == submitted


@pytest.mark.concurrency
class TestReaderWriterEpochSafety:
    """The PR-2 atomic swap under REAL overlap: a traffic thread streams
    windows through the pipelined engine while a writer thread repeatedly
    publishes ``refresh_fleet`` generations."""

    def test_no_torn_reads_and_monotone_generations(self):
        n_t = 8
        server = _fleet(n_t)
        server.score_batch([_req(f"t{i % n_t}", 10_000 + i)
                            for i in range(16)])  # compile before the clock
        for i in range(n_t):
            _inject(server, f"t{i}", f"p{i}", seed=i)
        ctrl = CalibrationController(server, REF, _policy())
        registry = {server.bank_generation: _pipeline_registry(server)}
        # warm the refresh path before the clock starts: the FIRST pass pays
        # one-time trace/compile costs that would otherwise push every
        # in-loop publish past the traffic window
        res0 = ctrl.refresh_fleet()
        assert res0.generation == 1
        registry[1] = _pipeline_registry(server)
        engine = AsyncDispatchEngine(server, max_batch=16, max_wait_ms=1e9)
        reqs = [_req(f"t{i % n_t}", i) for i in range(1280)]

        stop = threading.Event()
        published: list[int] = []

        def writer():
            # repeated atomic publishes while windows are in flight; the
            # registry snapshot is safe: this thread is the only publisher
            while not stop.is_set() and len(published) < 60:
                res = ctrl.refresh_fleet()
                registry[res.generation] = _pipeline_registry(server)
                published.append(res.generation)
                time.sleep(0.002)

        def traffic():
            for r in reqs:
                engine.submit(r)

        wt = threading.Thread(target=writer)
        tt = threading.Thread(target=traffic)
        wt.start()
        tt.start()
        # bounded joins: a wedged thread must FAIL the test, not hang the
        # whole CI lane (the drain is already timeout-bounded)
        tt.join(timeout=300.0)
        assert not tt.is_alive(), "traffic thread wedged"
        responses = engine.drain(timeout=300.0)
        stop.set()
        wt.join(timeout=300.0)
        assert not wt.is_alive(), "refresh writer wedged"
        engine.close()

        # 1:1 delivery despite the concurrent publishes
        assert sorted(r.request_id for r in responses) == \
            sorted(r.request_id for r in reqs)
        # a real publish landed mid-stream...
        assert max(published) >= 3
        assert len({r.bank_generation for r in responses}) >= 2
        # ...yet every response is internally consistent with exactly ONE
        # generation (no torn reads), and per-stream generations are monotone
        _assert_consistent(responses, registry)
        _assert_monotone_generations(responses)

    def test_refresh_scheduled_from_engine_between_stage_boundaries(self):
        n_t = 4
        server = _fleet(n_t)
        server.score_batch([_req(f"t{i % n_t}", 20_000 + i)
                            for i in range(8)])  # compile before the clock
        for i in range(n_t):
            _inject(server, f"t{i}", f"p{i}", seed=10 + i)
        ctrl = CalibrationController(server, REF, _policy())
        registry = {server.bank_generation: _pipeline_registry(server)}
        engine = AsyncDispatchEngine(server, max_batch=8, max_wait_ms=1e9)

        futs, results = [], []
        for k in range(4):
            futs += [engine.submit(_req(f"t{i % n_t}", 500 * k + i))
                     for i in range(16)]
            res = engine.schedule_refresh(ctrl).result(timeout=120.0)
            results.append(res)
            registry[res.generation] = _pipeline_registry(server)
        responses = engine.drain(timeout=120.0)
        engine.close()

        # each scheduled pass ran at its own stage boundary: epochs are
        # strictly increasing and stamped into the results
        assert [r.epoch for r in results] == [1, 2, 3, 4]
        assert engine.epoch == 4
        assert [r.generation for r in results] == [1, 2, 3, 4]
        assert server.bank_generation == 4
        for res in results:
            assert len(res.refreshed) == n_t
        assert sorted(r.request_id for r in responses) == \
            sorted(f.result().request_id for f in futs)
        _assert_consistent(responses, registry)
        _assert_monotone_generations(responses)


# ---------------------------------------------------------------------------
# End-to-end soak: FraudWorld traffic through the engine across a rolling
# model promotion with auto-calibration (paper Sec. 3.1/3.2 + Fig. 5)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.concurrency
class TestEngineSoakScenario:
    """The sync-path PR-2 invariant, now through the pipelined engine AND a
    ``RollingUpdate`` promotion: three tenants serve continuously while the
    ensemble is extended ({m1,m2} -> {m1,m2,m3}) on a surged replica whose
    calibration refresh is scheduled at an engine stage boundary.  Zero
    request ids may be dropped or duplicated, and per-tenant alert rates at
    the fixed client threshold must hold the PR-2 bounds (±1.2pp of target,
    ≤2pp pre-vs-post drift)."""

    def test_soak_across_rolling_promotion_with_auto_calibration(self):
        from repro.experiments.fraud_world import DIM as FDIM
        from repro.experiments.fraud_world import FraudWorld, train_expert
        from repro.serving.drift import realized_alert_rate
        from repro.training.data import FraudEventStream, TenantProfile

        a = 0.02
        B = 320                    # window size == dispatch chunk (one shape)
        per_phase = 3200           # events per tenant per phase (> Eq.-5 gate)
        world = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=17,
                                 client_shift=0.3)
        recent = FraudEventStream(TenantProfile(
            "train-pool", fraud_rate=0.01, feature_shift=0.3, seed=303))
        world.experts["m3"] = train_expert(recent, "m3", 0.02, mask_seed=33)
        old_ens, new_ens = ("m1", "m2"), ("m1", "m2", "m3")

        tenants = [f"bank{i}" for i in range(3)]
        streams = {
            t: FraudEventStream(TenantProfile(
                t, fraud_rate=0.006 + 0.003 * i,
                feature_shift=0.25 + 0.06 * i, seed=500 + i))
            for i, t in enumerate(tenants)
        }
        policy = RefreshPolicy(alert_rate=a, rel_error=0.3)
        qm0 = world.coldstart_quantile_map(old_ens, n_trials=1)

        def build_server(version, ensemble, qms):
            rules = tuple(ScoringRule(Condition(tenants=(t,)), f"p-{t}")
                          for t in tenants)
            server = MuseServer(
                RoutingTable(rules, version=version),
                ServerConfig(refresh_alert_rate=a, refresh_rel_error=0.3))
            for t in tenants:
                server.deploy(
                    world.predictor_spec(f"p-{t}", ensemble, qms[t]),
                    world.model_factories())
            return server

        def make_engine(server):
            # wide facade timeout: the soak's first windows after a replica
            # surge pay fresh XLA traces, slower still on the 8-device lane
            return AsyncDispatchEngine(server, max_batch=B, max_wait_ms=50.0,
                                       facade_timeout_s=300.0).start()

        server_v1 = build_server("v1", old_ens, {t: qm0 for t in tenants})
        replica = Replica(0, server_v1, "v1", ready=True,
                          engine=make_engine(server_v1))
        rs = ReplicaSet([replica])

        submitted: list[int] = []
        collected: list = []

        def serve_phase(n_per_tenant):
            xs = {t: streams[t].sample(n_per_tenant)[0] for t in tenants}
            reqs = [
                ScoringRequest(intent=Intent(tenant=t), features=xs[t][i])
                for i in range(n_per_tenant) for t in tenants
            ]
            submitted.extend(r.request_id for r in reqs)
            phase: list = []
            for i in range(0, len(reqs), B):
                phase.extend(rs.dispatch(reqs[i:i + B]))
            collected.extend(phase)
            return phase

        def rates(resps):
            by_tenant: dict[str, list[float]] = {t: [] for t in tenants}
            for r in resps:
                by_tenant[r.predictor[2:]].append(r.score)
            return {t: realized_alert_rate(np.asarray(s),
                                           world.ref_quantiles, a)
                    for t, s in by_tenant.items()}

        # Phase A: cold-start maps serve through the engine while the live
        # streams fill past the Eq.-5 gate; refresh at a stage boundary.
        serve_phase(per_phase)
        ctrl_v1 = CalibrationController(server_v1, world.ref_quantiles,
                                        policy)
        res1 = replica.engine.schedule_refresh(ctrl_v1).result(timeout=300.0)
        assert len(res1.refreshed) == 3, [r.reasons for r in res1.reports]
        assert res1.epoch == 1
        assert server_v1.bank_generation == 1

        # Phase B: refreshed v1 fleet — the pre-update baseline.
        pre = rates(serve_phase(per_phase))
        for t in tenants:
            assert pre[t] == pytest.approx(a, abs=0.012), (t, pre)

        # Model promotion via rolling update: the surged replica ships the
        # new ensemble with the STALE tenant maps, fills its own streams,
        # and auto-refreshes at an engine stage boundary before the old
        # replica drains.
        def make_server_v2():
            stale = {t: server_v1.predictors[f"p-{t}"].pipeline
                     for t in tenants}
            qms = {t: QuantileMap(stale[t].src_quantiles,
                                  stale[t].ref_quantiles) for t in tenants}
            server = build_server("v2", new_ens, qms)
            # "streams fill" step of the lifecycle: the promoted replica
            # accumulates live-distribution samples past the Eq.-5 gate
            # before its calibrate step (same traffic mix, sync path)
            xs = {t: streams[t].sample(per_phase)[0] for t in tenants}
            fill = [
                ScoringRequest(intent=Intent(tenant=t), features=xs[t][i])
                for i in range(per_phase) for t in tenants
            ]
            for i in range(0, len(fill), B):
                server.score_batch(fill[i:i + B])
            return server

        update = RollingUpdate(
            rs, make_server_v2, "v2", schema_dim=FDIM,
            warmup_batch_sizes=(1, B),
            calibration_factory=lambda srv: CalibrationController(
                srv, world.ref_quantiles, policy),
            engine_factory=make_engine)
        for _ in update.steps():
            serve_phase(B // len(tenants))   # live traffic at every transition
        assert len(update.refreshes) == 1
        res2 = update.refreshes[0]
        assert len(res2.refreshed) == 3, [r.reasons for r in res2.reports]
        assert res2.epoch >= 1              # scheduled via the v2 engine
        assert [r.version for r in rs.replicas] == ["v2"]
        assert rs.replicas[0].server.bank_generation >= 1

        # Phase D: the invariant — post-update alert rates back on target
        # and stable vs the pre-update baseline, served by the refreshed v2
        # engine end to end.
        post_resps = serve_phase(per_phase)
        assert {r.routing_version for r in post_resps} == {"v2"}
        assert all(r.bank_generation >= 1 for r in post_resps)
        post = rates(post_resps)
        for t in tenants:
            assert post[t] == pytest.approx(a, abs=0.012), (t, post)
            assert abs(post[t] - pre[t]) <= 0.02, (t, pre, post)

        # zero dropped / duplicated request ids across the whole campaign
        got = sorted(r.request_id for r in collected)
        assert got == sorted(submitted)
        assert len(set(got)) == len(got)


@pytest.mark.concurrency
class TestPollTimerShutdown:
    """Regression home for the poll-timer shutdown race: ``_arm_poll`` used
    to check ``_running``/``_closed`` OUTSIDE the lock, so ``close()`` could
    cancel the already-fired timer and then lose to the re-arm — a live
    timer polling into shut-down executors.  An exception escaping
    ``poll()`` also silently killed the re-arm chain."""

    def test_close_vs_tick_stress(self):
        """Hammer start -> submit -> close with a sub-millisecond poll
        interval: after close() returns, the tick chain must be provably
        dead (no late re-arm) and no tick may ever have polled into the
        shut-down executors (that surfaces as a tick error)."""
        server = _fleet(2)
        for i in range(25):
            eng = AsyncDispatchEngine(server, max_batch=8, max_wait_ms=0.01,
                                      poll_interval_ms=0.05)
            ticks = []
            orig_poll = eng.poll
            eng.poll = lambda op=orig_poll, t=ticks: (t.append(1), op())[1]
            eng.start()
            # age-out windows so ticks genuinely launch into the executors
            for j in range(4):
                eng.submit(_req(f"t{j % 2}", 1000 * i + j))
            time.sleep(0.0002 * (i % 7))     # vary the close/tick phase
            eng.close()
            assert eng.tick_errors == 0, eng.errors
            assert eng.errors == []
            # the chain must be dead: tick count stabilizes after close
            time.sleep(0.002)
            n1 = len(ticks)
            time.sleep(0.01)                 # ~200 intervals of grace
            assert len(ticks) == n1

    def test_tick_failure_surfaces_in_metric_and_chain_survives(self):
        """An exception escaping poll() is counted (tick_errors + errors),
        and the timer chain keeps re-arming through failures."""
        server = _fleet(1)
        eng = AsyncDispatchEngine(server, poll_interval_ms=1.0)
        boom = RuntimeError("boom")
        calls = []

        def bad_expired():
            calls.append(1)
            raise boom

        eng.batcher.expired = bad_expired
        eng.start()
        deadline = time.monotonic() + 5.0
        while len(calls) < 3 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert len(calls) >= 3               # chain survived the failures
        assert eng.tick_errors >= 3
        assert any(e is boom for _, e in eng.errors)
        del eng.batcher.expired              # restore for a clean close
        eng.close()
        assert eng.tick_errors >= 3
