"""Adversarial drift campaign: attack waves, decision loop, audit trail.

The headline scenario (marked ``slow`` + ``adversarial``) is a multi-day
replay: an :class:`AttackCampaign` drives bursty, tenant-targeted waves of
fast-drifting malicious traffic through a two-replica fleet while
``RollingUpdate`` promotions run mid-campaign.  The claim under test is the
paper's resilience story end-to-end:

  * a STALE transform bank (promotion refits only, fitted on quiet-
    dominated windows, no drift-triggered refresh) provably blows the
    per-tenant alert-rate SLO on every attacked tenant;
  * the drift-ticked closed loop (``CalibrationRefreshController`` routed
    through the fleet plane, ``RefreshPolicy(fit_window="recent")``) keeps
    EVERY tenant within ±1.5pp of the target rate over each wave's steady
    window (wave days after the first — the detection window needs one day
    of attack traffic to alarm, gate and publish);
  * every client decision rides a hash-chained audit log whose ``verify``
    replays each entry bit-for-bit against the exact ``bank_generation``
    it was served under, across ≥2 promotions — and any single-byte
    tamper, splice, truncation or generation mismatch is detected.

Fast satellites (default tier-1 lane, also under ``adversarial``): audit
chain property tests over the hypothesis shim, campaign/world seed-
determinism regressions, decision-loop grace/cooldown/instant-block
semantics, ``ReplicaSet`` stream-floor TTL/LRU eviction, and a small
serve->decide->audit->replay integration pass.
"""
import dataclasses
import itertools
import json
import types

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import PredictorSpec
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.experiments.fraud_world import AttackCampaign, AttackWave, FraudWorld
from repro.serving import (
    AuditLog,
    Decision,
    DecisionLoop,
    DecisionPolicy,
    FleetCalibrationController,
    GenerationLedger,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    RollingUpdate,
    ServerConfig,
    decide,
)
from repro.serving.audit import GENESIS, canonical_payload, chain_digest
from repro.serving.drift import CalibrationRefreshController
from repro.serving.types import ScoringRequest
from repro.training.data import TenantProfile

DIM = 8
ALERT_RATE = 0.05
SLO_BAND = 0.015                       # ±1.5pp around the target alert rate
REF = np.linspace(0.0, 1.0, 64)       # uniform reference distribution R


# ---------------------------------------------------------------------------
# Shared fixtures: per-tenant experts aligned with the campaign's fraud
# directions, so attack waves actually move the score distribution.
# ---------------------------------------------------------------------------

def _direction_expert(d: np.ndarray):
    w = np.asarray(d, np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))), jnp.float32)

    return score


def _factories(campaign: AttackCampaign, tenants: tuple[str, ...]):
    out = {}
    for i, t in enumerate(tenants):
        d = campaign._direction(t)
        out[f"e{i}"] = (lambda d=d: _direction_expert(d))
    return out


def _campaign_server(campaign, tenants, factories, version="v1") -> MuseServer:
    rules = tuple(ScoringRule(Condition(tenants=(t,)), f"p{i}")
                  for i, t in enumerate(tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version=version),
        ServerConfig(quantile_capacity=8192, recent_capacity=512,
                     refresh_alert_rate=ALERT_RATE, refresh_rel_error=0.5))
    for i, t in enumerate(tenants):
        server.deploy(PredictorSpec(f"p{i}", (f"e{i}",), (0.2,), (1.0,),
                                    QuantileMap.identity(64)), factories)
    return server


def _requests(features: np.ndarray, tenant: str, rid) -> list[ScoringRequest]:
    return [ScoringRequest(intent=Intent(tenant=tenant), features=f,
                           request_id=next(rid)) for f in features]


def _decision_record(rng_score, threshold, block_threshold, grace, cooldown,
                     seq=0, gen=1) -> dict:
    """A well-formed decision record whose action agrees with ``decide``."""
    return {
        "request_id": seq, "tenant": "t0", "predictor": "p0",
        "score": float(rng_score), "raw_scores": [float(rng_score)],
        "bank_generation": gen, "threshold": float(threshold),
        "block_threshold": float(block_threshold),
        "action": decide(float(rng_score), float(threshold),
                         float(block_threshold), bool(grace), int(cooldown)),
        "seq": seq, "grace": bool(grace), "cooldown": int(cooldown),
    }


# ---------------------------------------------------------------------------
# Audit chain property tests (hypothesis shim)
# ---------------------------------------------------------------------------

@pytest.mark.adversarial
class TestAuditChainProperties:
    @given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
                    max_size=24),
           st.floats(min_value=0.2, max_value=0.9),
           st.booleans(), st.integers(min_value=0, max_value=3))
    @settings(max_examples=12)
    def test_append_verify_roundtrip(self, scores, threshold, grace, cool):
        log = AuditLog()
        for i, s in enumerate(scores):
            log.append(_decision_record(s, threshold, 0.95, grace, cool,
                                        seq=i))
        v = log.verify(expected_head=log.head(), expected_length=len(log))
        assert v.ok and v.entries == len(scores) and v.head == log.head()

    @given(st.integers(min_value=0, max_value=9),
           st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=12)
    def test_tamper_any_byte_detected(self, entry_idx, byte_pos):
        log = AuditLog()
        for i in range(10):
            log.append(_decision_record(0.1 * i, 0.5, 0.95, False, 0, seq=i))
        e = log.entries[entry_idx]
        pos = byte_pos % len(e.payload)
        flipped = chr((ord(e.payload[pos]) + 1) % 128)
        payload = e.payload[:pos] + flipped + e.payload[pos + 1:]
        log.entries[entry_idx] = dataclasses.replace(e, payload=payload)
        v = log.verify()
        assert not v.ok
        assert any(f.kind == "chain" and f.index == entry_idx
                   for f in v.failures)

    @given(st.integers(min_value=1, max_value=8))
    @settings(max_examples=8)
    def test_truncation_detected(self, n_drop):
        log = AuditLog()
        for i in range(9):
            log.append(_decision_record(0.1 * i, 0.5, 0.95, False, 0, seq=i))
        head, length = log.head(), len(log)
        del log.entries[-n_drop:]
        # the remaining chain is internally consistent — only the out-of-
        # band (head, length) witness catches the amputated tail
        assert log.verify().ok
        v = log.verify(expected_head=head, expected_length=length)
        assert not v.ok
        assert {f.kind for f in v.failures} == {"truncated", "head_mismatch"}

    @given(st.floats(min_value=0.0, max_value=1.0), st.booleans())
    @settings(max_examples=8)
    def test_digest_independent_of_field_order(self, score, grace):
        record = _decision_record(score, 0.5, 0.95, grace, 0)
        shuffled = dict(reversed(list(record.items())))
        assert list(record) != list(shuffled)  # genuinely different order
        assert canonical_payload(record) == canonical_payload(shuffled)
        a, b = AuditLog(), AuditLog()
        a.append(record)
        b.append(shuffled)
        assert a.head() == b.head() != GENESIS

    def test_reordered_entries_break_chain(self):
        log = AuditLog()
        for i in range(6):
            log.append(_decision_record(0.1 * i, 0.5, 0.95, False, 0, seq=i))
        log.entries[2], log.entries[3] = log.entries[3], log.entries[2]
        v = log.verify()
        assert not v.ok and any(f.kind in ("chain", "index")
                                for f in v.failures)


# ---------------------------------------------------------------------------
# Seed determinism regressions
# ---------------------------------------------------------------------------

@pytest.mark.adversarial
class TestSeedDeterminism:
    def test_campaign_streams_and_schedule_bitwise(self):
        names = ("bankA", "bankB", "bankC")
        c1 = AttackCampaign.build(names, n_days=8, n_waves=2, seed=11, dim=DIM)
        c2 = AttackCampaign.build(names, n_days=8, n_waves=2, seed=11, dim=DIM)
        assert c1.waves == c2.waves
        assert c1.schedule() == c2.schedule()
        for t in names:
            for day in (0, 3, 7):
                x1, y1 = c1.sample(t, day, 256)
                x2, y2 = c2.sample(t, day, 256)
                assert x1.dtype == np.float32
                assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
        # order independence: drawing other tenant-days first changes nothing
        c3 = AttackCampaign.build(names, n_days=8, n_waves=2, seed=11, dim=DIM)
        for t in reversed(names):
            c3.sample(t, 5, 64)
        x1, _ = c1.sample("bankA", 3, 256)
        x3, _ = c3.sample("bankA", 3, 256)
        assert np.array_equal(x1, x3)
        # and a different seed genuinely differs
        c4 = AttackCampaign.build(names, n_days=8, n_waves=2, seed=12, dim=DIM)
        x4, _ = c4.sample("bankA", 3, 256)
        assert not np.array_equal(x1, x4)

    def test_fraud_world_experts_bitwise(self):
        w1 = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=17)
        w2 = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=17)
        for name in w1.experts:
            e1, e2 = w1.experts[name], w2.experts[name]
            assert np.array_equal(e1.w, e2.w) and e1.b == e2.b
            assert np.array_equal(e1.feature_mask, e2.feature_mask)
        assert np.array_equal(w1.ref_quantiles, w2.ref_quantiles)
        x1, y1 = w1.client.sample(512)
        x2, y2 = w2.client.sample(512)
        assert np.array_equal(x1, x2) and np.array_equal(y1, y2)


# ---------------------------------------------------------------------------
# Decision-loop semantics
# ---------------------------------------------------------------------------

def _resp(score: float, rid: int, gen: int = 1):
    return types.SimpleNamespace(
        request_id=rid, score=score, predictor="p0", routing_version="v1",
        latency_ms=0.1, raw_scores=(score,), bank_generation=gen)


def _reqs_for(tenant: str, n: int):
    return [ScoringRequest(intent=Intent(tenant=tenant),
                           features=np.zeros(DIM, np.float32), request_id=i)
            for i in range(n)]


@pytest.mark.adversarial
class TestDecisionLoopSemantics:
    def test_grace_observes_then_alerts(self):
        loop = DecisionLoop(DecisionPolicy(alert_rate=0.1, block_rate=0.001,
                                           grace_events=3), REF)
        reqs = _reqs_for("t0", 5)
        resps = [_resp(0.95, i) for i in range(5)]  # all above tau, below block
        actions = [d.action for d in loop.process(reqs, resps)]
        assert actions == ["allow", "allow", "allow", "alert", "alert"]

    def test_instant_block_outranks_grace(self):
        loop = DecisionLoop(DecisionPolicy(alert_rate=0.1, block_rate=0.01,
                                           grace_events=5), REF)
        reqs = _reqs_for("t0", 2)
        decisions = loop.process(reqs, [_resp(0.9999, 0), _resp(0.5, 1)])
        assert decisions[0].action == "block" and decisions[0].grace
        assert decisions[1].action == "allow"

    def test_cooldown_suppresses_alerts_after_block(self):
        loop = DecisionLoop(DecisionPolicy(alert_rate=0.1, block_rate=0.01,
                                           cooldown_events=2), REF)
        reqs = _reqs_for("t0", 4)
        scores = [0.9999, 0.95, 0.95, 0.95]   # block, then 3 alert-worthy
        actions = [d.action for d in
                   loop.process(reqs, [_resp(s, i)
                                       for i, s in enumerate(scores)])]
        assert actions == ["block", "allow", "allow", "alert"]
        st0 = loop.state("t0")
        assert st0.blocks == 1 and st0.alerts == 1

    def test_decisions_keyed_by_request_id_and_replayable(self):
        loop = DecisionLoop(DecisionPolicy(alert_rate=0.1, block_rate=0.001),
                            REF)
        reqs = _reqs_for("t0", 3)
        decisions = loop.process(reqs, [_resp(0.2, 10), _resp(0.97, 11),
                                        _resp(0.4, 12)])
        assert [d.request_id for d in decisions] == [10, 11, 12]
        for d in decisions:   # the recorded state inputs reproduce the action
            assert decide(d.score, d.threshold, d.block_threshold, d.grace,
                          d.cooldown) == d.action


# ---------------------------------------------------------------------------
# ReplicaSet stream-floor TTL / LRU eviction
# ---------------------------------------------------------------------------

class _StubServer:
    def __init__(self, gen: int) -> None:
        self.bank_generation = gen

    def score_batch(self, requests):
        return [types.SimpleNamespace(bank_generation=self.bank_generation,
                                      request_id=r.request_id)
                for r in requests]


@pytest.mark.adversarial
class TestStreamFloorEviction:
    def _set(self, gens, **kw):
        reps = [Replica(i, _StubServer(g), "v1", ready=True)
                for i, g in enumerate(gens)]
        return ReplicaSet(reps, **kw)

    def test_revived_stream_within_ttl_refuses_rollback(self):
        t = [0.0]
        rs = self._set([5], stream_floor_ttl=100.0, clock=lambda: t[0])
        rs.dispatch(_reqs_for("t0", 2), stream="s")
        assert rs.stream_floor("s") == 5
        # the up-to-date replica dies; only an older-generation one remains
        rs.replicas[0] = Replica(1, _StubServer(3), "v1", ready=True)
        t[0] = 50.0   # revived within TTL: floor remembered, rollback refused
        with pytest.raises(RuntimeError, match="generation rollback"):
            rs.dispatch(_reqs_for("t0", 2), stream="s")

    def test_expired_floor_re_fences_from_scratch(self):
        t = [0.0]
        rs = self._set([5], stream_floor_ttl=100.0, clock=lambda: t[0])
        rs.dispatch(_reqs_for("t0", 2), stream="s")
        rs.replicas[0] = Replica(1, _StubServer(3), "v1", ready=True)
        t[0] = 101.0  # past the TTL: the stale floor is forgotten
        assert rs.stream_floor("s") == -1
        resp = rs.dispatch(_reqs_for("t0", 2), stream="s")
        assert resp[0].bank_generation == 3
        assert rs.stream_floor("s") == 3

    def test_ttl_sweep_bounds_the_table(self):
        t = [0.0]
        rs = self._set([1], stream_floor_ttl=10.0, clock=lambda: t[0])
        for i in range(8):
            rs.dispatch(_reqs_for("t0", 1), stream=f"old{i}")
        assert rs.tracked_streams() == 8
        t[0] = 11.0
        rs.dispatch(_reqs_for("t0", 1), stream="fresh")
        assert rs.tracked_streams() == 1  # all idle floors swept

    def test_lru_cap_evicts_coldest_stream_first(self):
        t = [0.0]
        rs = self._set([1], max_tracked_streams=3, clock=lambda: t[0])
        for i, s in enumerate(("a", "b", "c")):
            t[0] = float(i)
            rs.dispatch(_reqs_for("t0", 1), stream=s)
        t[0] = 3.0
        rs.dispatch(_reqs_for("t0", 1), stream="a")  # touch: a is now hottest
        t[0] = 4.0
        rs.dispatch(_reqs_for("t0", 1), stream="d")  # evicts b (coldest)
        assert rs.tracked_streams() == 3
        assert rs.stream_floor("b") == -1
        assert rs.stream_floor("a") == 1 and rs.stream_floor("d") == 1


# ---------------------------------------------------------------------------
# Fast serve -> decide -> audit -> replay integration
# ---------------------------------------------------------------------------

@pytest.mark.adversarial
class TestAuditReplayIntegration:
    def _served_log(self):
        tenants = ("t0",)
        campaign = AttackCampaign.build(tenants, n_days=2, n_waves=0,
                                        promotion_days=(), seed=5, dim=DIM)
        factories = _factories(campaign, tenants)
        server = _campaign_server(campaign, tenants, factories)
        audit, ledger = AuditLog(), GenerationLedger()
        loop = DecisionLoop(DecisionPolicy(alert_rate=0.1, block_rate=0.02,
                                           grace_events=2, cooldown_events=3),
                            REF, audit=audit)
        rid = itertools.count()
        x, _ = campaign.sample("t0", 0, 48)
        resps = server.score_batch(_requests(x, "t0", rid))
        ledger.record_server(server)
        # a mid-stream publish: entries span TWO generations
        server.publish_quantile_maps(
            {"p0": QuantileMap.fit(np.linspace(0, 1, 512),
                                   jnp.asarray(REF, jnp.float32))})
        ledger.record_server(server)
        x2, _ = campaign.sample("t0", 1, 48)
        resps2 = server.score_batch(_requests(x2, "t0", rid))
        loop.process(_requests(x, "t0", iter(range(1000, 1048))), resps)
        loop.process(_requests(x2, "t0", iter(range(2000, 2048))), resps2)
        return audit, ledger

    def test_two_generation_log_replays_bitwise(self):
        audit, ledger = self._served_log()
        assert len(ledger.generations()) == 2
        v = audit.verify(ledger, expected_head=audit.head(),
                         expected_length=len(audit))
        assert v.ok, v.failures
        assert v.replayed == len(audit) == 96

    def test_score_tamper_caught_by_replay_not_just_chain(self):
        audit, ledger = self._served_log()
        # rebuild a log whose entry has a subtly altered score but a VALID
        # chain (attacker re-hashes): only generation replay catches it
        fields = json.loads(audit.entries[7].payload)
        fields["score"] = fields["score"] + 1e-3
        forged = AuditLog()
        forged.append(fields)
        v = forged.verify(ledger)
        assert not v.ok
        assert any(f.kind in ("score_mismatch", "action_mismatch")
                   for f in v.failures)

    def test_generation_mismatch_detected(self):
        audit, ledger = self._served_log()
        fields = json.loads(audit.entries[3].payload)
        fields["bank_generation"] = 999
        forged = AuditLog()
        forged.append(fields)
        v = forged.verify(ledger)
        assert not v.ok
        assert any(f.kind == "unknown_generation" for f in v.failures)

    def test_ledger_refuses_conflicting_rerecord(self):
        _, ledger = self._served_log()
        gen = max(ledger.generations())
        betas, weights, src, ref = ledger.params(gen, "p0")
        with pytest.raises(ValueError, match="ledger conflict"):
            ledger.record(gen, "p0", betas + 1.0, weights, src, ref)


# ---------------------------------------------------------------------------
# The multi-day adversarial replay campaign (slow)
# ---------------------------------------------------------------------------

TENANTS = ("t0", "t1", "t2")
WAVES = (
    AttackWave(name="wave0", targets=("t0",), start_day=3, duration=3,
               fraud_multiplier=24.0, separation_scale=0.6,
               drift_per_day=0.02, boundary_mass=0.25, boundary_scale=0.55),
    AttackWave(name="wave1", targets=("t1",), start_day=7, duration=3,
               fraud_multiplier=24.0, separation_scale=0.6,
               drift_per_day=0.02, boundary_mass=0.3, boundary_scale=0.55),
)
N_DAYS = 10
PROMOTION_DAYS = (2, 6)
WINDOWS_PER_DAY = 8
WINDOW = 256
EVENTS_PER_DAY = WINDOWS_PER_DAY * WINDOW


def _build_campaign() -> AttackCampaign:
    tenants = {t: TenantProfile(t, fraud_rate=0.01,
                                feature_shift=0.25 + 0.05 * i, seed=900 + i)
               for i, t in enumerate(TENANTS)}
    return AttackCampaign(tenants=tenants, waves=WAVES,
                          promotion_days=PROMOTION_DAYS, n_days=N_DAYS,
                          dim=DIM, seed=42)


def _run_campaign(campaign: AttackCampaign, *, drift_refresh: bool,
                  audit: AuditLog | None = None,
                  ledger: GenerationLedger | None = None):
    """Drive the full scripted schedule; returns (records, fleet, ctrl).

    ``records`` is one (tenant, day, action) triple per served event.  The
    stale baseline (``drift_refresh=False``) runs the IDENTICAL traffic,
    promotions and promotion-time refreshes — only the drift-triggered
    closed loop is absent.
    """
    factories = _factories(campaign, TENANTS)

    def make_server():
        return _campaign_server(campaign, TENANTS, factories)

    reps = [Replica(i, make_server(), "v1", ready=True) for i in range(2)]
    rs = ReplicaSet(reps)
    fleet = FleetCalibrationController(
        rs, REF, RefreshPolicy(alert_rate=ALERT_RATE, rel_error=0.5,
                               n_levels=64, fit_window="recent"))
    ctrl = None
    if drift_refresh:
        ctrl = CalibrationRefreshController(
            None, REF, psi_alarm=0.08, window=768, reject_cooldown=2,
            fleet=fleet)
    loop = DecisionLoop(DecisionPolicy(alert_rate=ALERT_RATE,
                                       block_rate=0.001), REF, audit=audit)
    rid = itertools.count()
    records: list[tuple[str, int, str]] = []
    promotions = 0

    for day in range(campaign.n_days):
        if day in campaign.promotion_days:
            ru = RollingUpdate(rs, make_server, f"v{day}", schema_dim=DIM,
                               warmup_batch_sizes=(WINDOW,),
                               fleet_calibration=fleet)
            for _ in ru.steps():
                pass
            promotions += 1
            if ledger is not None:
                ledger.record_replicas(rs)
        for i, t in enumerate(TENANTS):
            x, _ = campaign.sample(t, day, EVENTS_PER_DAY)
            for w in range(WINDOWS_PER_DAY):
                feats = x[w * WINDOW:(w + 1) * WINDOW]
                reqs = _requests(feats, t, rid)
                resps = rs.dispatch(reqs, stream=t)
                if ledger is not None:
                    ledger.record_replicas(rs)
                decisions = loop.process(reqs, resps)
                records += [(t, day, d.action) for d in decisions]
                if ctrl is not None:
                    ctrl.observe(t, resps[0].predictor,
                                 np.asarray([r.score for r in resps]))
                    ctrl.tick()
        if day == 0:
            # initial calibration once the Eq.-5 gate opens (both runs)
            fleet.refresh_fleet()
            if ledger is not None:
                ledger.record_replicas(rs)
    assert promotions == len(campaign.promotion_days)
    return records, fleet, ctrl


def _rate(records, tenant: str, days) -> float:
    evs = [a for (t, d, a) in records if t == tenant and d in days]
    assert evs, f"no events for {tenant} over {days}"
    return sum(a != "allow" for a in evs) / len(evs)


def _steady_days(wave: AttackWave) -> range:
    """The wave's SLO measurement window: its days after the first (the
    closed loop needs ~one day of attack traffic to alarm + gate +
    publish; the stale baseline has no such excuse and violates here)."""
    return range(wave.start_day + 1, wave.start_day + wave.duration)


@pytest.mark.slow
@pytest.mark.adversarial
class TestMultiDayAdversarialReplay:
    def test_campaign_slo_and_audit_replay(self):
        campaign = _build_campaign()

        # ---- stale baseline: no drift-triggered refresh ------------------
        stale_records, _, _ = _run_campaign(campaign, drift_refresh=False)
        for wave in campaign.waves:
            for target in wave.targets:
                rate = _rate(stale_records, target, _steady_days(wave))
                assert rate > ALERT_RATE + SLO_BAND, (
                    f"stale bank unexpectedly held SLO on {target} during "
                    f"{wave.name}: rate={rate:.4f}")

        # ---- drift-ticked run: closed loop + audit trail -----------------
        audit, ledger = AuditLog(), GenerationLedger()
        records, fleet, ctrl = _run_campaign(
            campaign, drift_refresh=True, audit=audit, ledger=ledger)
        assert len(ctrl.refreshes) >= 2  # at least one refresh per wave

        for wave in campaign.waves:
            window = _steady_days(wave)
            for t in TENANTS:
                rate = _rate(records, t, window)
                assert abs(rate - ALERT_RATE) <= SLO_BAND, (
                    f"refreshed run out of SLO for {t} during {wave.name}: "
                    f"rate={rate:.4f}")
        # quiet steady state holds too (skip day 0 pre-calibration, days
        # adjacent to promotions/waves where a refresh is legitimately
        # still converging)
        for t in TENANTS:
            assert abs(_rate(records, t, (1,)) - ALERT_RATE) <= SLO_BAND

        # ---- audit chain verifies + replays end-to-end -------------------
        assert len(audit) == len(TENANTS) * N_DAYS * EVENTS_PER_DAY
        assert len(ledger.generations()) >= 3  # initial + promos + drift
        v = audit.verify(ledger, expected_head=audit.head(),
                         expected_length=len(audit))
        assert v.ok, v.failures[:5]
        assert v.replayed == len(audit)

        # ---- tamper / generation mismatch detection ----------------------
        e = audit.entries[1234]
        pos = len(e.payload) // 2
        tampered = e.payload[:pos] + \
            chr((ord(e.payload[pos]) + 1) % 128) + e.payload[pos + 1:]
        audit.entries[1234] = dataclasses.replace(e, payload=tampered)
        vt = audit.verify()
        assert not vt.ok and any(f.kind == "chain" and f.index == 1234
                                 for f in vt.failures)
        audit.entries[1234] = e
        assert audit.verify(expected_head=audit.head(),
                            expected_length=len(audit)).ok

        fields = json.loads(audit.entries[777].payload)
        fields["bank_generation"] = max(ledger.generations()) + 100
        forged = AuditLog()
        forged.append(fields)
        vg = forged.verify(ledger)
        assert not vg.ok
        assert any(f.kind == "unknown_generation" for f in vg.failures)
