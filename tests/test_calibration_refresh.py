"""Fleet-wide atomic calibration refresh: the full update-lifecycle campaign.

Covers the paper's headline invariant end-to-end (a model update never
shifts a tenant's alert rate once T^Q is refreshed), the control-plane
mechanics (Eq.-5 gating, candidate validation, atomic versioned publish),
property-style invariants of refreshed QuantileMaps, bank-cache staleness,
and the rollout promotion trigger.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import (
    StreamingQuantileEstimator,
    batch_sample_quantiles,
    required_sample_size,
)
from repro.core.routing import (
    Condition,
    Intent,
    RoutingTable,
    ScoringRule,
    ShadowRule,
)
from repro.core.transforms import QuantileMap, score_pipeline
from repro.serving import (
    CalibrationController,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    RollingUpdate,
    ServerConfig,
)
from repro.serving.drift import realized_alert_rate
from repro.serving.types import ScoringRequest

DIM = 8
TOL = 1e-5


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2, 3)}


def _req(tenant, seed):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


def _fleet(n_tenants=3, *, shadow=False, fused=True) -> MuseServer:
    """One predictor per tenant over a shared {m1,m2} model group."""
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    shadows = (ShadowRule(Condition(tenants=("t0",)), ("p-sh",)),) \
        if shadow else ()
    server = MuseServer(
        RoutingTable(rules, shadows, version="v1"),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5,
                     fused_kernel=fused))
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    if shadow:
        server.deploy(PredictorSpec("p-sh", ("m1", "m2"), (0.5, 0.9),
                                    (2.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


def _policy(**kw) -> RefreshPolicy:
    base = dict(alert_rate=0.05, rel_error=0.5, n_levels=64)
    base.update(kw)
    return RefreshPolicy(**base)


def _inject(server, tenant, pred, samples, seed=0):
    est = StreamingQuantileEstimator(capacity=65536, seed=seed)
    est.update(samples)
    server._estimators[(tenant, pred)] = est
    return est


REF = np.linspace(0.0, 1.0, 64) ** 2  # smooth, front-loaded reference


class TestRefreshFleetControlPlane:
    def test_eq5_gate_blocks_thin_streams(self):
        server = _fleet(2)
        rng = np.random.default_rng(0)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 10))
        _inject(server, "t1", "p1", rng.uniform(0, 1, gate // 4))
        ctrl = CalibrationController(server, REF, _policy())
        res = ctrl.refresh_fleet()
        assert [(r.tenant, r.predictor) for r in res.refreshed] == [("t0", "p0")]
        assert [(r.tenant, r.predictor) for r in res.not_ready] == [("t1", "p1")]
        assert res.not_ready[0].reasons == ("eq5_gate",)
        assert server.bank_generation == res.generation == 1

    def test_no_ready_streams_is_a_noop_publish(self):
        server = _fleet(1)
        ctrl = CalibrationController(server, REF, _policy())
        res = ctrl.refresh_fleet()
        assert res.reports == ()
        assert res.generation == server.bank_generation == 0

    def test_degenerate_stream_rejected_others_ship(self):
        server = _fleet(2)
        rng = np.random.default_rng(1)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        _inject(server, "t1", "p1", np.full(gate + 50, 0.37))  # poisoned
        old_qm_p1 = server.predictors["p1"].pipeline.src_quantiles
        ctrl = CalibrationController(server, REF, _policy())
        res = ctrl.refresh_fleet()
        assert [(r.tenant, r.predictor) for r in res.refreshed] == [("t0", "p0")]
        (rej,) = res.rejected
        assert (rej.tenant, rej.predictor) == ("t1", "p1")
        assert "degenerate_support" in rej.reasons
        # the rejected predictor keeps serving its OLD map
        assert server.predictors["p1"].pipeline.src_quantiles is old_qm_p1
        assert server.bank_generation == 1  # healthy stream still published

    def test_poisoned_tenant_vetoes_shared_predictor(self):
        """Two tenants share one predictor; the pooled candidate must
        validate against EVERY tenant stream before it ships."""
        server = _fleet(1)  # p0 serves t0 and (catch-all) t9
        rng = np.random.default_rng(2)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        _inject(server, "t9", "p0", np.full(gate + 50, 0.99), seed=1)
        old = server.predictors["p0"].pipeline.src_quantiles
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        assert res.refreshed == []
        assert {r.status for r in res.reports} == {"rejected"}
        assert server.predictors["p0"].pipeline.src_quantiles is old
        assert server.bank_generation == 0  # nothing published

    def test_healthy_tenant_reported_as_peer_vetoed(self):
        """When the shared predictor is withheld because ONE tenant stream
        fails, streams that passed individually are reported as
        'vetoed_by_peer' — not as their own validation failure."""
        server = _fleet(1)
        rng = np.random.default_rng(12)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 0.5, gate + 50))
        # t9 matches t0's history, but its RECENT traffic shifted outside
        # the pooled support — only t9's own recency check fails
        est = StreamingQuantileEstimator(capacity=256, seed=4,
                                         recent_capacity=2048)
        est.update(rng.uniform(0.0, 0.5, 500_000))
        est.update(rng.uniform(0.8, 0.95, 2048))
        server._estimators[("t9", "p0")] = est
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        assert res.refreshed == []
        by_tenant = {r.tenant: r for r in res.reports}
        assert by_tenant["t0"].reasons == ("vetoed_by_peer",)
        assert "support_coverage_recent" in by_tenant["t9"].reasons
        assert server.bank_generation == 0

    def test_recent_shift_fails_support_coverage(self):
        """A shift that happens AFTER the reservoir filled is nearly
        invisible to the uniform reservoir but dominates the recent window:
        the candidate must be rejected, not published."""
        server = _fleet(2)
        rng = np.random.default_rng(8)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        # t1: long history on [0, 0.4], then a hard shift to [0.7, 0.9]
        est = StreamingQuantileEstimator(capacity=256, seed=3,
                                         recent_capacity=2048)
        est.update(rng.uniform(0.0, 0.4, 500_000))
        est.update(rng.uniform(0.7, 0.9, 2048))
        server._estimators[("t1", "p1")] = est
        old = server.predictors["p1"].pipeline.src_quantiles
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        (rej,) = [r for r in res.rejected if r.tenant == "t1"]
        assert "support_coverage_recent" in rej.reasons
        assert server.predictors["p1"].pipeline.src_quantiles is old
        assert [(r.tenant, r.predictor) for r in res.refreshed] == [("t0", "p0")]

    def test_refresh_only_filter_limits_the_pass(self):
        server = _fleet(2)
        rng = np.random.default_rng(9)
        gate = required_sample_size(0.05, 0.5)
        for i in range(2):
            _inject(server, f"t{i}", f"p{i}",
                    rng.uniform(0, 1, gate + 50), seed=i)
        ctrl = CalibrationController(server, REF, _policy())
        res = ctrl.refresh_fleet(only={("t1", "p1")})
        assert [(r.tenant, r.predictor) for r in res.refreshed] == [("t1", "p1")]
        assert len(res.reports) == 1  # t0 untouched, not even reported

    def test_only_filter_still_validates_predictor_peers(self):
        """refresh_fleet(only={alarmed tenant}) must not bypass the peer
        veto: every live stream of the touched predictor joins the pooled
        refit and validation."""
        server = _fleet(1)  # p0 serves t0 and catch-all t9
        rng = np.random.default_rng(11)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        _inject(server, "t9", "p0", np.full(gate + 50, 0.42), seed=1)
        res = CalibrationController(server, REF, _policy()).refresh_fleet(
            only={("t0", "p0")})
        assert res.refreshed == []          # poisoned peer vetoed the publish
        assert {r.tenant for r in res.reports} == {"t0", "t9"}
        assert server.bank_generation == 0

    def test_decommission_purges_estimator_streams(self):
        """A predictor redeployed under a decommissioned name must NOT be
        refit from the dead model's score stream."""
        server = _fleet(2)
        rng = np.random.default_rng(10)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        server.decommission("p0")
        assert ("t0", "p0") not in server.estimator_streams()
        server.deploy(PredictorSpec("p0", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
        assert server.estimator_streams() == {}
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        assert res.reports == ()  # no stale stream resurfaced

    def test_vectorized_refit_matches_per_stream_quantiles(self):
        rng = np.random.default_rng(3)
        streams = [rng.beta(0.5 + i, 6.0, 500 + 100 * i) for i in range(7)]
        levels = np.linspace(0, 1, 33)
        got = batch_sample_quantiles(streams, levels)
        want = np.stack([np.maximum.accumulate(np.quantile(s, levels))
                         for s in streams])
        np.testing.assert_allclose(got, want, atol=1e-12)

    def test_refresh_aligns_live_streams_to_reference(self):
        """Post-refresh, each tenant's served distribution matches R: the
        realized alert rate at the client threshold hits the target."""
        server = _fleet(3)
        rng = np.random.default_rng(4)
        reqs = [_req(f"t{i % 3}", 1000 + i) for i in range(512)]
        for i in range(0, 512, 128):
            server.score_batch(reqs[i:i + 128])
        # streams were fed by real traffic; force the gate open by topping
        # them up from the same live distribution (the estimators hold the
        # T^Q INPUT aggregate, reproduced here through the bank oracle)
        for (t, p), est in list(server.estimator_streams().items()):
            vals = est.values()
            est.update(rng.choice(vals, 2000))
        ctrl = CalibrationController(server, REF, _policy(alert_rate=0.05))
        res = ctrl.refresh_fleet()
        assert len(res.refreshed) == 3
        scores = [r.score for r in server.score_batch(reqs)]
        rate = realized_alert_rate(np.asarray(scores), REF, 0.05)
        assert rate == pytest.approx(0.05, abs=0.02)


class TestAtomicPublish:
    def test_generation_bumps_and_banks_are_immutable(self):
        server = _fleet(3)
        server.score_batch([_req(f"t{i}", i) for i in range(3)])  # warm bank
        (key,) = server._banks
        old_entry = server._banks[key]
        old_src = np.asarray(old_entry.bank.src_quantiles).copy()
        gate = required_sample_size(0.05, 0.5)
        rng = np.random.default_rng(5)
        for i in range(3):
            _inject(server, f"t{i}", f"p{i}",
                    rng.uniform(0, 1, gate + 50), seed=i)
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        new_entry = server._banks[key]
        assert new_entry is not old_entry
        assert new_entry.bank.generation == res.generation == 1
        assert old_entry.bank.generation == 0
        # the old bank object an in-flight dispatch may hold is untouched
        np.testing.assert_array_equal(
            np.asarray(old_entry.bank.src_quantiles), old_src)
        assert not np.allclose(np.asarray(new_entry.bank.src_quantiles),
                               old_src)

    def test_in_flight_dispatch_scores_on_old_generation(self):
        """A dispatch that snapshotted the old bank keeps its parameters even
        after a publish lands — scoring through the captured bank must
        reproduce pre-publish scores exactly."""
        server = _fleet(2)
        reqs = [_req("t0", 11), _req("t1", 12)]
        pre = [r.score for r in server.score_batch(reqs)]
        (key,) = server._banks
        captured = server._banks[key].bank  # what an in-flight window holds
        gate = required_sample_size(0.05, 0.5)
        rng = np.random.default_rng(6)
        for i in range(2):
            _inject(server, f"t{i}", f"p{i}",
                    rng.uniform(0, 1, gate + 50), seed=i)
        CalibrationController(server, REF, _policy()).refresh_fleet()
        post = [r.score for r in server.score_batch(reqs)]
        assert pre != pytest.approx(post, abs=1e-9)  # publish changed serving
        # replay the in-flight window through the captured old bank
        raws = np.asarray([reqs[0].features, reqs[1].features], np.float32)
        pred0 = server.predictors["p0"]
        raw_scores = np.stack(
            [np.asarray(h.score_fn(raws)) for h in pred0._handles], axis=-1)
        replay = np.asarray(captured(jnp.asarray(raw_scores, jnp.float32),
                                     jnp.asarray([0, 1], jnp.int32)))
        np.testing.assert_allclose(replay, pre, atol=TOL)

    def test_publish_after_in_place_redeploy_rebuilds_bank(self):
        """A predictor redeployed under an existing name leaves a stale
        cached bank; a later publish touching a bank-mate must fully rebuild
        that bank from the CURRENT pipelines, not patch-and-repin the stale
        rows (which would serve the dead pipeline's T^C/A forever)."""
        server = _fleet(2)
        reqs = [_req("t0", 41), _req("t1", 42)]
        server.score_batch(reqs)          # warm the shared (p0,p1) bank
        server.deploy(PredictorSpec("p1", ("m1", "m2"), (0.9, 0.7),
                                    (2.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)          # in-place redeploy, new T^C/A
        qs = jnp.linspace(0, 1, 64)
        server.publish_quantile_maps({"p0": QuantileMap(qs, qs ** 2)})
        resps = server.score_batch(reqs)
        for resp, name in zip(resps, ["p0", "p1"]):
            pipe = server.predictors[name].pipeline
            want = float(score_pipeline(
                jnp.asarray(resp.raw_scores, jnp.float32), pipe.betas,
                pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
            assert resp.score == pytest.approx(want, abs=TOL), name

    def test_recent_ring_keeps_newest_after_bulk_write(self):
        """The recency window must hold the newest samples even when a bulk
        update repositioned the ring (regression: pointer misalignment kept
        old samples and evicted newer ones)."""
        est = StreamingQuantileEstimator(capacity=64, seed=0,
                                         recent_capacity=8)
        est.update(np.arange(20.0))
        est.update(np.array([100.0, 101.0]))
        assert set(est.recent()) == {14.0, 15.0, 16.0, 17.0, 18.0, 19.0,
                                     100.0, 101.0}
        est.update(np.array([200.0]))
        assert 200.0 in est.recent() and 14.0 not in est.recent()

    def test_publish_many_predictors_is_one_generation(self):
        server = _fleet(3)
        qs = jnp.linspace(0, 1, 64)
        updates = {f"p{i}": QuantileMap(qs, qs ** (i + 2)) for i in range(3)}
        gen = server.publish_quantile_maps(updates)
        assert gen == server.bank_generation == 1
        assert server.publish_quantile_maps({}) == 1  # empty = no bump
        with pytest.raises(KeyError):
            server.publish_quantile_maps({"ghost": QuantileMap(qs, qs)})


class TestBankCacheStaleness:
    def test_swap_then_score_never_serves_old_params(self):
        server = _fleet(2, shadow=True)
        reqs = [_req("t0", 21), _req("t1", 22)]
        server.score_batch(reqs)          # warm live + shadow banks
        qs = jnp.linspace(0, 1, 64)
        server.swap_transformation("p0", QuantileMap(qs, qs ** 4))
        server.swap_transformation("p-sh", QuantileMap(qs, jnp.sqrt(qs)))
        resps = server.score_batch(reqs)
        # oracle from the CURRENT pipelines: any staleness diverges
        for resp, (name, row) in zip(resps, [("p0", 0), ("p1", 1)]):
            pipe = server.predictors[name].pipeline
            want = float(score_pipeline(
                jnp.asarray(resp.raw_scores, jnp.float32), pipe.betas,
                pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
            assert resp.score == pytest.approx(want, abs=TOL)
        # interleaved shadow dispatch also sees the swapped shadow T^Q
        rec = server.sink.records("p-sh")[-1]
        pipe = server.predictors["p-sh"].pipeline
        want = float(score_pipeline(
            jnp.asarray(rec.raw_scores, jnp.float32), pipe.betas,
            pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
        assert rec.score == pytest.approx(want, abs=TOL)

    def test_fleet_publish_then_score_serves_new_params(self):
        server = _fleet(2)
        server.score_batch([_req("t0", 31), _req("t1", 32)])
        gate = required_sample_size(0.05, 0.5)
        rng = np.random.default_rng(7)
        for i in range(2):
            _inject(server, f"t{i}", f"p{i}",
                    rng.uniform(0, 1, gate + 50), seed=i)
        CalibrationController(server, REF, _policy()).refresh_fleet()
        resps = server.score_batch([_req("t0", 31), _req("t1", 32)])
        for resp, name in zip(resps, ["p0", "p1"]):
            pipe = server.predictors[name].pipeline
            want = float(score_pipeline(
                jnp.asarray(resp.raw_scores, jnp.float32), pipe.betas,
                pipe.weights, pipe.src_quantiles, pipe.ref_quantiles))
            assert resp.score == pytest.approx(want, abs=TOL)


class TestRefreshedMapProperties:
    """Property-style invariants of refitted maps (hypothesis shim)."""

    @settings(max_examples=8)
    @given(st.integers(0, 10_000), st.floats(0.4, 3.0), st.floats(2.0, 9.0))
    def test_refit_is_monotone_non_decreasing(self, seed, a, b):
        rng = np.random.default_rng(seed)
        src = batch_sample_quantiles(
            [rng.beta(a, b, 2000)], np.linspace(0, 1, len(REF)))[0]
        assert (np.diff(src) >= -1e-12).all()
        qm = QuantileMap(jnp.asarray(src, jnp.float32),
                         jnp.asarray(REF, jnp.float32))
        x = jnp.linspace(0, 1, 257)
        y = np.asarray(qm(x))
        assert (np.diff(y) >= -1e-6).all()   # rank preservation (ROC claim)

    @settings(max_examples=6)
    @given(st.integers(0, 10_000), st.sampled_from([1.0, 2.0]))
    def test_refit_on_reference_traffic_is_identity(self, seed, gamma):
        """T^Q fitted on a stream ALREADY distributed as R must be ~id."""
        rng = np.random.default_rng(seed)
        levels = np.linspace(0, 1, 129)
        ref = levels ** gamma
        samples = np.interp(rng.uniform(0, 1, 6000), levels, ref)
        src = batch_sample_quantiles([samples], levels)[0]
        qm = QuantileMap(jnp.asarray(src, jnp.float32),
                         jnp.asarray(ref, jnp.float32))
        x = np.interp(np.linspace(0.05, 0.95, 61), levels, ref)  # interior
        y = np.asarray(qm(jnp.asarray(x, jnp.float32)))
        np.testing.assert_allclose(y, x, atol=0.06)

    @settings(max_examples=4)
    @given(st.integers(0, 10_000))
    def test_banked_kernel_oracle_parity_after_mid_stream_swap(self, seed):
        """Fused kernel == pure-jnp banked oracle across an atomic swap."""
        fused = _fleet(3, fused=True)
        plain = _fleet(3, fused=False)
        rng = np.random.default_rng(seed)
        reqs = [_req(f"t{i % 3}", int(rng.integers(1 << 30))) for i in range(12)]
        np.testing.assert_allclose(
            [r.score for r in fused.score_batch(reqs)],
            [r.score for r in plain.score_batch(reqs)], atol=TOL)
        qs = jnp.linspace(0, 1, 64)
        updates = {"p0": QuantileMap(qs, qs ** 3),
                   "p2": QuantileMap(qs, jnp.sqrt(qs))}
        assert fused.publish_quantile_maps(updates) == 1
        assert plain.publish_quantile_maps(updates) == 1
        np.testing.assert_allclose(
            [r.score for r in fused.score_batch(reqs)],
            [r.score for r in plain.score_batch(reqs)], atol=TOL)


class TestDriftTickThroughController:
    def test_tick_refreshes_unalarmed_peer_without_crashing(self):
        """One alarmed tenant on a shared predictor widens to its peer: the
        tick must publish once, reset BOTH monitors, and report the peer
        with its own (sub-alarm) PSI — regression for a KeyError on peers
        absent from the alarmed set."""
        from repro.serving.drift import CalibrationRefreshController

        server = _fleet(1)  # p0 serves t0 and catch-all t9
        rng = np.random.default_rng(13)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 1, gate + 50))
        _inject(server, "t9", "p0", rng.uniform(0, 1, gate + 50), seed=1)
        ctl = CalibrationRefreshController(server, REF, window=2000)
        # t0's served distribution drifted hard; t9's matches R
        ctl.observe("t0", "p0", np.full(2000, 0.97))
        levels = np.linspace(0, 1, len(REF))
        ctl.observe("t9", "p0", np.interp(rng.uniform(0, 1, 2000),
                                          levels, REF))
        assert ctl._monitors[("t0", "p0")].drifted()
        assert not ctl._monitors[("t9", "p0")].drifted()
        done = ctl.tick()
        keys = {(t, p) for t, p, _ in done}
        assert keys == {("t0", "p0"), ("t9", "p0")}
        assert server.bank_generation == 1  # one atomic publish for both
        psis = {(t, p): v for t, p, v in done}
        assert psis[("t0", "p0")] > 0.25      # the alarm
        assert psis[("t9", "p0")] < 0.25      # peer reported sub-alarm
        for key in keys:                      # both windows judged fresh
            assert ctl._monitors[key].count == 0

    def test_rejected_alarm_is_recorded_and_backs_off(self):
        """A poisoned stream that trips the alarm but fails validation must
        be visible in `rejections` and must NOT re-run the refit on every
        subsequent tick (cooldown)."""
        from repro.serving.drift import CalibrationRefreshController

        server = _fleet(1)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", np.full(gate + 50, 0.5))  # degenerate
        ctl = CalibrationRefreshController(server, REF, window=2000,
                                           reject_cooldown=3)
        ctl.observe("t0", "p0", np.full(2000, 0.97))  # drifted hard
        assert ctl.tick() == []
        assert server.bank_generation == 0
        assert len(ctl.rejections) == 1
        tenant, pred, reasons = ctl.rejections[0]
        assert (tenant, pred) == ("t0", "p0")
        assert "degenerate_support" in reasons
        # cooldown: the next ticks skip the stream entirely
        for _ in range(2):
            assert ctl.tick() == []
        assert len(ctl.rejections) == 1  # no repeated refit/rejection

    def test_not_ready_peer_outside_support_vetoes_publish(self):
        """A below-gate peer stream is still recalibrated by a publish; if
        its traffic falls outside the candidate's support, the predictor
        must be withheld (support-coverage vote for not-ready peers)."""
        server = _fleet(1)  # p0 serves t0 and catch-all t9
        rng = np.random.default_rng(14)
        gate = required_sample_size(0.05, 0.5)
        _inject(server, "t0", "p0", rng.uniform(0, 0.5, gate + 50))
        _inject(server, "t9", "p0", rng.uniform(0.8, 1.0, gate // 4), seed=1)
        res = CalibrationController(server, REF, _policy()).refresh_fleet()
        assert res.refreshed == []
        assert server.bank_generation == 0
        by_tenant = {r.tenant: r for r in res.reports}
        assert by_tenant["t9"].status == "not_ready"
        assert "support_coverage" in by_tenant["t9"].reasons
        assert by_tenant["t0"].reasons == ("vetoed_by_peer",)


class TestRolloutPromotionTrigger:
    def test_promotion_triggers_fleet_refresh(self):
        gate = required_sample_size(0.05, 0.5)

        def make_server(version="v2"):
            s = _fleet(2)
            s.routing = RoutingTable(s.routing.scoring_rules,
                                     s.routing.shadow_rules, version=version)
            rng = np.random.default_rng(42)
            for i in range(2):
                _inject(s, f"t{i}", f"p{i}",
                        rng.uniform(0, 1, gate + 50), seed=i)
            return s

        replicas = [Replica(i, make_server("v1"), "v1", ready=True)
                    for i in range(2)]
        rs = ReplicaSet(replicas)
        update = RollingUpdate(
            rs, make_server, "v2", schema_dim=DIM,
            warmup_batch_sizes=(1, 2),
            calibration_factory=lambda srv: CalibrationController(
                srv, REF, _policy()))

        def traffic():
            i = 0
            while True:
                yield [_req("t0", i), _req("t1", i + 1)]
                i += 2

        update.run_with_traffic(traffic(), batches_per_transition=1)
        # every promoted replica ran a fleet refresh and published atomically
        assert len(update.refreshes) == 2
        for res in update.refreshes:
            assert len(res.refreshed) == 2
            assert res.generation >= 1
        for r in rs.replicas:
            assert r.server.bank_generation >= 1
        assert sum(e.kind == "calibrate" for e in update.events) == 2


# ---------------------------------------------------------------------------
# End-to-end scenario: live fleet through a model update (paper Sec. 3.1/3.2)
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestModelUpdateScenario:
    """The headline invariant, end to end: three tenants with distinct
    distributions serve live traffic, the ensemble is retrained/extended
    ({m1,m2} -> {m1,m2,m3}) and promoted with its STALE T^Q, then one
    ``refresh_fleet()`` pass refits every tenant from the live stream and
    publishes atomically — per-tenant alert rates at the fixed client
    threshold must match the target before AND after the model update."""

    def test_alert_rates_stable_across_model_update(self):
        from repro.experiments.fraud_world import FraudWorld, train_expert
        from repro.training.data import FraudEventStream, TenantProfile

        # Target alert rate a=2% with Eq.-5 delta=0.3: the gate needs ~2.1k
        # samples and guarantees the realized rate within ±30% relative
        # (95% conf.).  4k samples/phase keeps both the fit and the
        # measurement inside an abs tolerance of 1.2pp with margin.
        a = 0.02
        batch, per_phase = 500, 4000
        world = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=17,
                                 client_shift=0.3)
        # the model update: a third expert trained on recent shifted traffic
        recent = FraudEventStream(TenantProfile(
            "train-pool", fraud_rate=0.01, feature_shift=0.3, seed=303))
        world.experts["m3"] = train_expert(recent, "m3", 0.02, mask_seed=33)
        old, new = ("m1", "m2"), ("m1", "m2", "m3")

        tenants = [f"bank{i}" for i in range(3)]
        streams = {
            t: FraudEventStream(TenantProfile(
                t, fraud_rate=0.006 + 0.003 * i,
                feature_shift=0.25 + 0.06 * i, seed=500 + i))
            for i, t in enumerate(tenants)
        }
        qm0 = world.coldstart_quantile_map(old, n_trials=1)
        rules = tuple(ScoringRule(Condition(tenants=(t,)), f"p-old-{t}")
                      for t in tenants)
        server = MuseServer(RoutingTable(rules, version="v1"),
                            ServerConfig(refresh_alert_rate=a,
                                         refresh_rel_error=0.3))
        for t in tenants:
            server.deploy(world.predictor_spec(f"p-old-{t}", old, qm0),
                          world.model_factories())
        ctrl = CalibrationController(
            server, world.ref_quantiles,
            RefreshPolicy(alert_rate=a, rel_error=0.3))

        def serve_phase(n_per_tenant) -> dict[str, np.ndarray]:
            scores: dict[str, list[float]] = {t: [] for t in tenants}
            for t in tenants:
                x, _ = streams[t].sample(n_per_tenant)
                for i in range(0, n_per_tenant, batch):
                    resps = server.score_batch([
                        ScoringRequest(intent=Intent(tenant=t), features=f)
                        for f in x[i:i + batch]
                    ])
                    scores[t].extend(r.score for r in resps)
            return {t: np.asarray(s) for t, s in scores.items()}

        def rates(scores: dict[str, np.ndarray]) -> dict[str, float]:
            return {t: realized_alert_rate(s, world.ref_quantiles, a)
                    for t, s in scores.items()}

        # Phase A: cold-start maps serve while live streams accumulate past
        # the Eq.-5 gate; then the first fleet refresh customizes every T^Q.
        serve_phase(per_phase)
        res1 = ctrl.refresh_fleet()
        assert len(res1.refreshed) == 3, [r.reasons for r in res1.reports]
        assert server.bank_generation == 1

        # Phase B: refreshed fleet — the pre-update baseline alert rates.
        pre = rates(serve_phase(per_phase))
        for t in tenants:
            assert pre[t] == pytest.approx(a, abs=0.012), (t, pre)

        # Model promotion: new ensemble ships with the OLD tenant maps (the
        # paper's p1.5 stale state) — transparent routing swap, zero model
        # re-provisioning for m1/m2.
        prov_before = server.pool.provision_events
        for t in tenants:
            stale = server.predictors[f"p-old-{t}"].pipeline
            server.deploy(world.predictor_spec(
                f"p-new-{t}", new,
                QuantileMap(stale.src_quantiles, stale.ref_quantiles)),
                world.model_factories())
        assert server.pool.provision_events == prov_before + 1  # only m3
        server.publish_routing(RoutingTable(
            tuple(ScoringRule(Condition(tenants=(t,)), f"p-new-{t}")
                  for t in tenants), version="v2"))

        # Phase C: stale maps serve the new ensemble while the new
        # (tenant, p-new) streams fill; then ONE fleet refresh pass.
        stale_rates = rates(serve_phase(per_phase))
        res2 = ctrl.refresh_fleet()
        refreshed = {(r.tenant, r.predictor) for r in res2.refreshed}
        assert {(t, f"p-new-{t}") for t in tenants} <= refreshed, \
            [r.reasons for r in res2.reports]
        assert server.bank_generation == 2

        # Phase D: the invariant — post-update alert rates back on target,
        # and stable relative to the pre-update baseline.
        post = rates(serve_phase(per_phase))
        for t in tenants:
            assert post[t] == pytest.approx(a, abs=0.012), (t, post)
            assert abs(post[t] - pre[t]) <= 0.02, (t, pre, post, stale_rates)
        # and the post-refresh distributions sit inside the drift bound
        from repro.serving.drift import transformed_stream_psi
        for t, s in serve_phase(per_phase).items():
            assert transformed_stream_psi(s, world.ref_quantiles) < 0.25
