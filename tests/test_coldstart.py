"""Tests for the Beta-mixture cold-start transformation (paper Sec. 2.4)."""
import numpy as np
import jax.numpy as jnp

from repro.core import coldstart
from repro.core.coldstart import (
    BetaMixtureFit,
    beta_mixture_pdf,
    default_quantile_map,
    fit_beta_mixture,
    jensen_shannon_divergence,
    mixture_raw_moments,
    moment_loss,
)
from repro.core.transforms import fraud_reference_quantiles, quantile_map


def _synthetic_scores(n=40_000, w=0.01, seed=0):
    """Bimodal fraud-like score distribution: legit mass near 0, fraud near 1."""
    rng = np.random.default_rng(seed)
    n_pos = rng.binomial(n, w)
    neg = rng.beta(1.2, 18.0, n - n_pos)
    pos = rng.beta(6.0, 2.0, n_pos)
    return np.concatenate([neg, pos]), w


class TestMoments:
    def test_beta_moment_closed_form(self):
        # Beta(2,3): E[X] = 2/5, E[X^2] = 2*3/(5*6) = 0.2
        m = coldstart._beta_raw_moment(2.0, 3.0, 1)
        np.testing.assert_allclose(m, 0.4)
        m2 = coldstart._beta_raw_moment(2.0, 3.0, 2)
        np.testing.assert_allclose(m2, 0.2)

    def test_mixture_moments_vs_monte_carlo(self):
        rng = np.random.default_rng(1)
        w, a0, b0, a1, b1 = 0.3, 1.5, 8.0, 5.0, 2.0
        comp = rng.random(500_000) < w
        samples = np.where(comp, rng.beta(a1, b1, 500_000), rng.beta(a0, b0, 500_000))
        mm = mixture_raw_moments(w, a0, b0, a1, b1)
        emp = np.array([np.mean(samples**r) for r in range(1, 5)])
        np.testing.assert_allclose(mm, emp, rtol=0.02)

    def test_moment_loss_zero_at_truth(self):
        w = 0.2
        params = np.array([1.5, 9.0, 4.0, 1.5])
        mu = mixture_raw_moments(w, *params)
        assert moment_loss(params, w, mu) < 1e-12


class TestJSD:
    def test_identical_distributions(self):
        p = np.array([0.2, 0.3, 0.5])
        assert jensen_shannon_divergence(p, p) < 1e-12

    def test_bounded_by_ln2(self):
        p = np.array([1.0, 0.0])
        q = np.array([0.0, 1.0])
        jsd = jensen_shannon_divergence(p, q)
        assert 0 < jsd <= np.log(2) + 1e-9

    def test_symmetry(self):
        rng = np.random.default_rng(2)
        p, q = rng.random(16), rng.random(16)
        assert abs(jensen_shannon_divergence(p, q) - jensen_shannon_divergence(q, p)) < 1e-12


class TestBetaMixtureFit:
    def test_fit_recovers_bimodal_shape(self):
        scores, w = _synthetic_scores()
        fit = fit_beta_mixture(scores, w, n_trials=4, maxiter=200, seed=0)
        # The fitted mixture should be a decent density model: JSD well below
        # the ln(2) maximum and moments close.
        assert fit.jsd < 0.1, f"JSD too high: {fit.jsd}"
        emp = np.array([np.mean(scores**r) for r in range(1, 5)])
        mm = mixture_raw_moments(fit.w, fit.a0, fit.b0, fit.a1, fit.b1)
        np.testing.assert_allclose(mm, emp, rtol=0.15, atol=5e-3)

    def test_quantiles_monotone_and_bounded(self):
        scores, w = _synthetic_scores(seed=3)
        fit = fit_beta_mixture(scores, w, n_trials=1, maxiter=150, seed=1)
        q = fit.quantiles(np.linspace(0, 1, 64))
        assert (np.diff(q) >= 0).all()
        assert q[0] >= 0 and q[-1] <= 1

    def test_default_quantile_map_aligns_training_distribution(self):
        """T^Q_v0 maps the *training* score distribution approximately onto R.

        This is the cold-start contract: until client data exists, scores on
        data resembling training data should follow the reference distribution.
        """
        scores, w = _synthetic_scores(seed=4)
        fit = fit_beta_mixture(scores, w, n_trials=3, maxiter=200, seed=2)
        ref_q = fraud_reference_quantiles(256)
        qm = default_quantile_map(fit, np.asarray(ref_q))
        mapped = np.asarray(qm(jnp.asarray(scores, jnp.float32)))
        # Compare mapped distribution to reference via per-decile mass.
        levels = np.linspace(0.0, 1.0, 256)
        edges = np.linspace(0.0, 1.0, 11)
        ref_cdf_at_edges = np.interp(edges, np.asarray(ref_q), levels)
        expected = np.diff(ref_cdf_at_edges)
        observed, _ = np.histogram(mapped, bins=edges)
        observed = observed / len(mapped)
        # Cold-start is approximate (smooth prior vs empirical) — generous tol.
        assert np.abs(observed - expected).max() < 0.08
