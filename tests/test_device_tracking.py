"""Fused device quantile tracking: the bitwise-parity campaign.

Proves the ROADMAP's "fuse quantile tracking into the device program" item:
with ``ServerConfig.track_device`` the track stage is one device dispatch
(banked ``pre_quantile`` aggregate + scatter into per-stream staging
buffers, ``kernels/quantile_track.py``) and host estimators materialize
ONLY at the calibration plane's pull boundaries — with state (reservoir,
recent ring, pointers, seen counts AND RNG state) bit-for-bit equal to
eager host tracking, across spill and host-fallback regimes.

Also the regression home for the estimator seed-framing fix
(``stream_seed``): the old ``"/".join`` derivation collided for
``("a/b", "c")`` vs ``("a", "b/c")``, correlating supposedly independent
reservoir acceptance sequences.

Everything here runs on a single CPU device (the staging plane needs one
device, not many), so the ``tracking`` marker rides the default tier-1
lane; ``./test.sh --tracking`` runs the campaign alone.
"""
import zlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import (
    StreamingQuantileEstimator,
    required_sample_size,
)
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.kernels.quantile_track import DeviceQuantileTracker, _segment_plan
from repro.serving import (
    AsyncDispatchEngine,
    CalibrationController,
    MuseServer,
    RefreshPolicy,
    ServerConfig,
)
from repro.serving.server import stream_seed
from repro.serving.types import ScoringRequest

pytestmark = pytest.mark.tracking

DIM = 8
TENANTS = 4
REF = np.linspace(0.0, 1.0, 64) ** 2


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2)}


def _server(track_device: bool, *, staging: int = 4096, capacity: int = 256,
            recent: int = 32, track: bool = True) -> MuseServer:
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(TENANTS)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, (), version="v1"),
        ServerConfig(track_quantiles=track, track_device=track_device,
                     track_staging=staging, quantile_capacity=capacity,
                     recent_capacity=recent, refresh_alert_rate=0.05,
                     refresh_rel_error=0.5))
    for i in range(TENANTS):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


def _req(tenant: str, seed: int) -> ScoringRequest:
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


def _windows(n_mixed: int = 18, w: int = 48, seed: int = 7):
    """A deterministic request stream: mixed-tenant windows plus one large
    single-tenant window (> recent_capacity per stream, so the recent
    ring's bulk-reset branch is exercised, not just the rolling writes)."""
    rng = np.random.default_rng(seed)
    out, k = [], 0
    for _ in range(n_mixed):
        out.append([_req(f"t{rng.integers(0, TENANTS)}", k := k + 1)
                    for _ in range(w)])
    out.append([_req("t0", k := k + 1) for _ in range(3 * 32 + 5)])
    return out


def _drive(server, windows):
    return [server.score_batch(win) for win in windows]


def _assert_snapshots_equal(a: MuseServer, b: MuseServer) -> None:
    ca, cb = (a.snapshot_estimator_checkpoints(),
              b.snapshot_estimator_checkpoints())
    assert ca.keys() == cb.keys()
    for key in ca:
        (arr_a, meta_a), (arr_b, meta_b) = ca[key], cb[key]
        assert meta_a == meta_b, key          # seen/pos/filled/rng_state
        # live prefixes only: checkpoints store full-capacity buffers for
        # static restore shapes, and the tail past filled/recent_filled is
        # uninitialized memory, not state
        nf, nr = meta_a["filled"], meta_a["recent_filled"]
        assert np.array_equal(arr_a["buf"][:nf], arr_b["buf"][:nf]), key
        assert np.array_equal(arr_a["recent"][:nr],
                              arr_b["recent"][:nr]), key


class TestBitwiseParity:
    @pytest.mark.parametrize("staging", [4096, 64, 8])
    def test_checkpoint_state_matches_eager_host_tracking(self, staging):
        """The tentpole contract: reservoir + recent ring + RNG state equal
        bit-for-bit across staging regimes — large staging (no pulls until
        the snapshot), small staging (spill-before-overflow drains), and
        tiny staging (whole windows fall back to the eager host path)."""
        host, dev = _server(False), _server(True, staging=staging)
        windows = _windows()
        _drive(host, windows)
        _drive(dev, windows)
        tracker = dev._tracker
        if staging == 4096:
            assert tracker.pending_total() > 0    # nothing pulled yet
            assert tracker.spills == 0
            assert dev.metrics["track_staged_windows"] > 0
        elif staging == 64:
            assert tracker.spills > 0
            assert dev.metrics["track_staged_windows"] > 0
        else:
            assert tracker.host_fallbacks > 0     # stream share > plane
        _assert_snapshots_equal(host, dev)
        # post-sync everything is materialized; a second pull is stable
        assert tracker.pending_total() == 0
        _assert_snapshots_equal(host, dev)

    def test_scores_unaffected_by_tracking_mode(self):
        """Tracking rides behind the response path: OFF / eager host /
        device-fused must serve identical scores."""
        off = _server(False, track=False)
        host, dev = _server(False), _server(True)
        windows = _windows(n_mixed=6)
        r_off, r_host, r_dev = (_drive(off, windows), _drive(host, windows),
                                _drive(dev, windows))
        for w_off, w_host, w_dev in zip(r_off, r_host, r_dev):
            for a, b, c in zip(w_off, w_host, w_dev):
                assert a.score == b.score == c.score

    def test_quantiles_after_sync_match_eager(self):
        host, dev = _server(False), _server(True)
        windows = _windows(n_mixed=8)
        _drive(host, windows)
        _drive(dev, windows)
        levels = np.linspace(0.01, 0.99, 33)
        eh = host.estimator_streams()
        ed = dev.estimator_streams()      # host-pull boundary: syncs first
        assert eh.keys() == ed.keys() and eh
        for key in eh:
            assert np.array_equal(eh[key].quantiles(levels),
                                  ed[key].quantiles(levels)), key


class TestHostPullBoundaries:
    def test_calibration_ready_sees_staged_samples(self):
        """Eq.-5 gate is a host-pull boundary: staged device samples count
        without any explicit sync by the caller."""
        dev = _server(True)
        gate = required_sample_size(0.05, 0.5)
        n = 0
        while n <= gate:
            w = [_req("t1", n + i) for i in range(64)]
            dev.score_batch(w)
            n += 64
        assert dev._tracker.pending(("t1", "p1")) > 0
        assert dev.calibration_ready("t1", "p1")
        # the gate's pull materialized the stream
        assert dev._estimators[("t1", "p1")].count == n

    def test_save_restore_gate_refresh_ships(self, tmp_path):
        """The PR-5 persistence contract through the device tracker:
        save -> restore on a fresh replica -> Eq.-5 gate passes -> a
        calibration refresh ships a new generation."""
        gate = required_sample_size(0.05, 0.5)
        src = _server(True, capacity=131072, recent=4096)
        rng = np.random.default_rng(3)
        k = 0
        for _ in range((2 * gate) // 64 + 2):
            src.score_batch([_req(f"t{rng.integers(0, 2)}", k := k + 1)
                             for _ in range(64)])
        src.save_estimators(str(tmp_path), step=1)

        dst = _server(True, capacity=131072, recent=4096)
        restored = dst.restore_estimators(str(tmp_path), step=1)
        assert restored >= 2
        _assert_snapshots_equal(src, dst)
        ready = [t for t in ("t0", "t1")
                 if dst.calibration_ready(t, f"p{t[1]}")]
        assert ready                                    # gate passed warm
        policy = RefreshPolicy(alert_rate=0.05, rel_error=0.5, n_levels=64)
        res = CalibrationController(dst, REF, policy).refresh_fleet()
        shipped = {(r.tenant, r.predictor) for r in res.refreshed}
        assert {(t, f"p{t[1]}") for t in ready} <= shipped
        assert dst.bank_generation == res.generation > 0
        # tracking keeps staging against the REFRESHED plane
        dst.score_batch([_req("t0", 10_000 + i) for i in range(48)])
        assert dst._tracker.pending(("t0", "p0")) > 0 \
            or dst.metrics["track_staged_windows"] > 0

    def test_decommission_drops_staged_stream(self):
        """A dead predictor's staged device samples must never materialize
        into a later stream under the same name."""
        dev = _server(True)
        dev.score_batch([_req("t1", i) for i in range(40)])
        assert dev._tracker.pending(("t1", "p1")) == 40
        dev.decommission("p1")
        assert dev._tracker.pending(("t1", "p1")) == 0
        assert ("t1", "p1") not in dev._estimators
        # redeploy under the same name: stream restarts from zero
        dev.deploy(PredictorSpec("p1", ("m1", "m2"), (0.2, 0.4), (1.0, 1.0),
                                 QuantileMap.identity(64)), FACTORIES)
        dev.score_batch([_req("t1", 100 + i) for i in range(16)])
        streams = dev.estimator_streams()
        assert streams[("t1", "p1")].count == 16


class TestTrackerUnit:
    def test_segment_plan_ranks_and_counts(self):
        slots = np.array([2, 0, 2, 2, 0, 5])
        ranks, uniq, incoming = _segment_plan(slots)
        assert ranks.tolist() == [0, 0, 1, 2, 1, 0]   # arrival order kept
        assert uniq.tolist() == [0, 2, 5]
        assert incoming.tolist() == [2, 3, 1]

    def _pair(self, staging: int):
        ests: dict = {}

        def apply(key, chunks):
            ests.setdefault(key, StreamingQuantileEstimator(
                capacity=128, seed=11, recent_capacity=16)).apply_chunks(
                chunks)

        return DeviceQuantileTracker(apply, staging_capacity=staging), ests

    @pytest.mark.parametrize("staging", [512, 16, 2])
    def test_append_agg_replay_matches_eager(self, staging):
        """Tracker-level bitwise parity for the precomputed-aggregate path
        (what tiered stores use), across spill/fallback regimes."""
        tracker, ests = self._pair(staging)
        eager: dict = {}
        rng = np.random.default_rng(0)
        for _ in range(30):
            b = int(rng.integers(1, 12))
            keys = [("t%d" % rng.integers(0, 3), "p") for _ in range(b)]
            # f32 like the serving path: the staging plane is f32, and the
            # eager comparator must see the same values, not f64 parents
            agg = rng.uniform(0, 1, b).astype(np.float32)
            if not tracker.append_agg(keys, agg):
                for key in dict.fromkeys(keys):
                    rows = [j for j, k in enumerate(keys) if k == key]
                    ests.setdefault(key, StreamingQuantileEstimator(
                        capacity=128, seed=11,
                        recent_capacity=16)).update(agg[rows])
            for key in dict.fromkeys(keys):
                rows = [j for j, k in enumerate(keys) if k == key]
                eager.setdefault(key, StreamingQuantileEstimator(
                    capacity=128, seed=11,
                    recent_capacity=16)).update(agg[rows])
        tracker.sync()
        assert ests.keys() == eager.keys() and ests
        for key in eager:
            meta = ests[key].checkpoint_meta()
            assert meta == eager[key].checkpoint_meta()
            assert np.array_equal(ests[key].values(), eager[key].values())
            assert np.array_equal(ests[key].recent(), eager[key].recent())

    def test_empty_window_is_a_noop(self):
        tracker, ests = self._pair(8)
        assert tracker.append_agg([], np.empty(0))
        assert tracker.pending_total() == 0 and not ests

    def test_drop_where_frees_and_reuses_slots(self):
        tracker, ests = self._pair(16)
        tracker.append_agg([("a", "p"), ("b", "p")], np.array([0.1, 0.2]))
        assert tracker.drop_where(lambda k: k[0] == "a") == 1
        assert tracker.pending(("a", "p")) == 0
        tracker.append_agg([("c", "p")], np.array([0.3]))   # reuses slot
        tracker.sync()
        assert set(ests) == {("b", "p"), ("c", "p")}
        assert ests[("c", "p")].count == 1

    def test_slot_growth_preserves_staged_data(self):
        tracker, ests = self._pair(4)
        keys = [(f"t{i}", "p") for i in range(200)]   # forces _grow twice
        agg = (np.arange(200) / 200.0).astype(np.float32)
        tracker.append_agg(keys, agg)
        assert tracker.pending(("t199", "p")) == 1
        tracker.sync()
        assert len(ests) == 200
        assert float(ests[("t42", "p")].values()[0]) == float(agg[42])


class TestEngineIntegration:
    def test_engine_track_lane_launches_fused_program(self):
        """The engine's [track] lane through the device tracker: same
        windows as a synchronous eager server => bitwise-equal estimator
        state after drain (the track stage runs a stage behind, drain is
        the barrier)."""
        host = _server(False)
        dev = _server(True)
        windows = _windows(n_mixed=8, w=32)
        _drive(host, windows)
        # max_batch > the largest driven window: the facade must form the
        # SAME windows the sync server dispatched, or the update-call
        # boundaries (and thus RNG consumption) would legitimately differ
        with AsyncDispatchEngine(dev, max_batch=128) as engine:
            for win in windows:
                engine.score_batch(win)
            engine.drain()
        assert engine.track_errors == 0
        assert dev.metrics["track_staged_windows"] > 0
        _assert_snapshots_equal(host, dev)


class TestSeedFraming:
    def test_stream_seed_collision_regression(self):
        """'/'-joined framing hashed ("a/b","c") and ("a","b/c") to the
        same seed; framed derivation must not."""
        assert stream_seed(("a/b", "c")) != stream_seed(("a", "b/c"))
        assert stream_seed(("a/b", "")) != stream_seed(("a", "b/"))
        assert stream_seed(("t", "p")) == stream_seed(("t", "p"))

    def test_stream_seed_legacy_compat_for_unambiguous_keys(self):
        """Slash-free keys — where the join is injective — keep the legacy
        digest, so fixing the collision does not reshuffle the acceptance
        sequence of every ordinary stream in existing deployments."""
        for key in [("t0", "p0"), ("tenant-a", "fraud_v2"), ("a", ""),
                    ("", "")]:
            assert stream_seed(key) == zlib.crc32("/".join(key).encode())
        # ambiguous keys leave the legacy namespace entirely (0xff-led
        # framing is not valid UTF-8, so no legacy payload can alias it)
        assert stream_seed(("a/b", "c")) != zlib.crc32(b"a/b/c")

    def test_formerly_collided_streams_decorrelated(self):
        """Identical inputs through the two formerly-collided keys must now
        produce different reservoir acceptance sequences."""
        data = np.random.default_rng(5).uniform(0, 1, 4000)
        a = StreamingQuantileEstimator(capacity=64,
                                       seed=stream_seed(("a/b", "c")))
        b = StreamingQuantileEstimator(capacity=64,
                                       seed=stream_seed(("a", "b/c")))
        a.update(data)
        b.update(data)
        assert not np.array_equal(a.values(), b.values())
