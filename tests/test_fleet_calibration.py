"""Fleet-level calibration plane: merged sketches, fenced fleet publish.

Covers the multi-replica invariants the fleet controller exists for:

  * regression — independent per-replica refreshes leave a ``ReplicaSet``
    with DIVERGENT generations behind the load balancer
    (``ReplicaSet.fleet_generation().divergent``); one fleet pass converges
    the same fleet;
  * fencing — a replica rejects any publish not strictly newer than what it
    serves (``StaleGenerationError``): late acks from superseded passes can
    never roll a replica backwards, and empty fenced publishes fast-forward
    lagging/surged replicas;
  * stragglers — a replica that nacks a broadcast keeps serving its complete
    OLD plane (old maps, old generation, internally consistent responses);
  * structured failure — per-replica pull/publish failures become report
    entries (``pull_failures`` / ``nacked``), never a raise, and a fully
    failed pass leaves the fleet generation unchanged;
  * fenced session routing — ``ReplicaSet.dispatch(stream=...)`` keeps each
    client stream's observed ``bank_generation`` monotone across the whole
    fleet, even mid-broadcast (threaded campaign under the fleet marker);
  * accuracy — the fleet fit over merged sketches matches a single-server
    fit over the concatenated stream within the documented rank-error bound.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.predictor import PredictorSpec
from repro.core.quantiles import (
    StreamingQuantileEstimator,
    merge_rank_error_bound,
    required_sample_size,
)
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import QuantileMap
from repro.serving import (
    CalibrationController,
    FleetCalibrationController,
    MuseServer,
    RefreshPolicy,
    Replica,
    ReplicaSet,
    RollingUpdate,
    ServerConfig,
    StaleGenerationError,
)
from repro.serving.types import ScoringRequest

DIM = 8
GATE = required_sample_size(0.05, 0.5)
REF = np.linspace(0.0, 1.0, 64) ** 2


def _linear_model(seed: int, dim: int = DIM):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, dim).astype(np.float32)

    def score(x):
        x = np.asarray(x, np.float32)
        return jnp.asarray(1.0 / (1.0 + np.exp(-(x @ w))))

    return score


FACTORIES = {f"m{i}": (lambda i=i: _linear_model(i)) for i in (1, 2)}


def _server(n_tenants=2, version="v1") -> MuseServer:
    rules = tuple(ScoringRule(Condition(tenants=(f"t{i}",)), f"p{i}")
                  for i in range(n_tenants)) + \
        (ScoringRule(Condition(), "p0"),)
    server = MuseServer(
        RoutingTable(rules, version=version),
        ServerConfig(refresh_alert_rate=0.05, refresh_rel_error=0.5))
    for i in range(n_tenants):
        server.deploy(PredictorSpec(f"p{i}", ("m1", "m2"), (0.2, 0.4),
                                    (1.0, 1.0), QuantileMap.identity(64)),
                      FACTORIES)
    return server


def _policy(**kw) -> RefreshPolicy:
    base = dict(alert_rate=0.05, rel_error=0.5, n_levels=64)
    base.update(kw)
    return RefreshPolicy(**base)


def _inject(server, tenant, pred, samples, seed=0):
    est = StreamingQuantileEstimator(capacity=65536, seed=seed,
                                     recent_capacity=256)
    est.update(samples)
    server._estimators[(tenant, pred)] = est
    return est


def _mk_fleet(n_replicas=3, n_tenants=2):
    reps = [Replica(i, _server(n_tenants), "v1", ready=True)
            for i in range(n_replicas)]
    return ReplicaSet(reps), reps


def _fill(reps, n_tenants=2, per_rep=None, seed=0):
    """Split one well-formed stream per (tenant, pred) across all replicas."""
    per_rep = per_rep if per_rep is not None else GATE // len(reps) + 60
    rng = np.random.default_rng(seed)
    full = {}
    for i in range(n_tenants):
        data = rng.normal(0.5, 0.15, per_rep * len(reps)).clip(0.0, 1.0)
        full[(f"t{i}", f"p{i}")] = data
        for j, rep in enumerate(reps):
            _inject(rep.server, f"t{i}", f"p{i}",
                    data[j * per_rep:(j + 1) * per_rep], seed=31 * j + i)
    return full


def _req(tenant, seed=0):
    rng = np.random.default_rng(seed)
    return ScoringRequest(intent=Intent(tenant=tenant),
                          features=rng.normal(0, 1, DIM).astype(np.float32))


class TestFleetGenerationAudit:
    def test_per_replica_refreshes_diverge_fleet_pass_converges(self):
        """The pre-refactor failure mode, as a pinned regression: refreshing
        each replica with its own CalibrationController leaves the ready set
        divergent (a client bouncing across the LB sees generations go
        backwards); ONE fleet pass over the same fleet converges it."""
        rs, reps = _mk_fleet(3)
        _fill(reps, per_rep=GATE + 60)      # every replica locally ready
        # old world: replica-local refreshes, run on a subset only (exactly
        # what independent drift alarms firing per replica produce)
        CalibrationController(reps[0].server, REF, _policy()).refresh_fleet()
        audit = rs.fleet_generation()
        assert audit.divergent
        assert audit.max_generation == 1 and audit.min_generation == 0
        assert dict(audit.per_replica)[0] == 1

        # new world: one fleet pass, one fenced generation everywhere
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet()
        assert res.acked == ("0", "1", "2") and not res.nacked
        audit = rs.fleet_generation()
        assert not audit.divergent
        assert audit.max_generation == res.fleet_generation > 1

    def test_audit_over_empty_ready_set_falls_back_to_all(self):
        rs, reps = _mk_fleet(2)
        for r in reps:
            r.ready = False
        audit = rs.fleet_generation()
        assert len(audit.per_replica) == 2
        assert audit.min_generation == audit.max_generation == 0


class TestFencedPublish:
    def test_stale_fenced_publish_rejected_and_state_unchanged(self):
        server = _server()
        server.publish_quantile_maps({}, generation=3)
        assert server.bank_generation == 3
        for stale in (1, 3):
            with pytest.raises(StaleGenerationError) as ei:
                server.publish_quantile_maps({}, generation=stale)
            assert ei.value.requested == stale and ei.value.current == 3
        assert server.bank_generation == 3

    def test_empty_fenced_publish_restamps_served_responses(self):
        """A fast-forward re-stamps cached banks too: responses after the
        publish carry the new generation even though no map changed."""
        server = _server()
        r0 = server.score_batch([_req("t0")])[0]
        assert r0.bank_generation == 0
        server.publish_quantile_maps({}, generation=5)
        r1 = server.score_batch([_req("t0")])[0]
        assert r1.bank_generation == 5
        assert r1.score == pytest.approx(r0.score)   # content unchanged

    def test_align_fast_forwards_surged_replica(self):
        rs, reps = _mk_fleet(2)
        fleet = FleetCalibrationController(rs, REF, _policy())
        reps[0].server.publish_quantile_maps({}, generation=4)
        new = Replica(9, _server(), "v2", ready=True)
        assert new.bank_generation == 0
        assert fleet.align(new) == 4
        assert new.bank_generation == 4
        # idempotent: already at (or past) the fleet generation
        assert fleet.align(new) == 4


class TestStragglerSemantics:
    def test_straggler_keeps_complete_old_plane(self):
        rs, reps = _mk_fleet(3)
        _fill(reps)
        straggler = reps[2]
        pre = straggler.server.score_batch([_req("t0"), _req("t1", 1)])
        orig = straggler.server.publish_quantile_maps
        straggler.server.publish_quantile_maps = (
            lambda *a, **k: (_ for _ in ()).throw(ConnectionError("down")))
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet()
        assert res.acked == ("0", "1") and res.nacked == ("2",)
        assert len(res.refreshed) == 2, [r.reasons for r in res.reports]
        # acked replicas moved; the straggler serves its complete OLD plane:
        # old generation AND old (identity) maps — internally consistent
        assert reps[0].bank_generation == res.fleet_generation > 0
        assert straggler.bank_generation == 0
        post = straggler.server.score_batch([_req("t0"), _req("t1", 1)])
        for a, b in zip(pre, post):
            assert b.bank_generation == 0
            assert b.score == pytest.approx(a.score)
        straggler.server.publish_quantile_maps = orig

    def test_late_ack_cannot_publish_stale_lower_generation(self):
        """A straggler that heals and then receives the SUPERSEDED pass's
        publish (the 'late ack') is fenced out by the generation check."""
        rs, reps = _mk_fleet(2)
        _fill(reps)
        straggler = reps[1]
        captured = {}
        orig = straggler.server.publish_quantile_maps

        def failing(updates, *, generation=None):
            captured["updates"], captured["generation"] = updates, generation
            raise ConnectionError("partitioned")

        straggler.server.publish_quantile_maps = failing
        fleet = FleetCalibrationController(rs, REF, _policy())
        res1 = fleet.refresh_fleet()
        assert res1.nacked == ("1",)
        straggler.server.publish_quantile_maps = orig     # partition heals
        # a second fleet pass lands on the healed replica at a HIGHER fence
        _fill(reps, seed=1)
        res2 = fleet.refresh_fleet()
        assert "1" in res2.acked
        assert straggler.bank_generation == res2.fleet_generation \
            > captured["generation"]
        # the late ack: replaying the superseded pass must be rejected,
        # leaving the replica on the newer plane
        with pytest.raises(StaleGenerationError):
            straggler.server.publish_quantile_maps(
                captured["updates"], generation=captured["generation"])
        assert straggler.bank_generation == res2.fleet_generation

    def test_pull_failures_are_structured_and_leave_generation_unchanged(self):
        class _DownServer:
            bank_generation = 0
            predictors = {}

            @staticmethod
            def snapshot_estimator_checkpoints():
                raise TimeoutError("no route to replica")

        rs = ReplicaSet([Replica(i, _DownServer(), "v1", ready=True)
                         for i in range(2)])
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet()       # must not raise
        assert [f.replica_id for f in res.pull_failures] == ["0", "1"]
        assert all("TimeoutError" in f.error for f in res.pull_failures)
        assert not res.refreshed and not res.acked
        assert res.fleet_generation == fleet.fleet_generation() == 0

    def test_partial_pull_failure_excludes_replica_from_broadcast(self):
        rs, reps = _mk_fleet(3)
        _fill(reps, per_rep=GATE + 60)    # two healthy replicas stay ready
        broken = reps[1]
        broken.server.snapshot_estimator_checkpoints = (
            lambda: (_ for _ in ()).throw(OSError("pull refused")))
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet()
        assert [f.replica_id for f in res.pull_failures] == ["1"]
        assert res.acked == ("0", "2") and not res.nacked
        assert len(res.refreshed) == 2
        # the unreachable replica was never sent the broadcast either
        assert broken.bank_generation == 0
        assert reps[0].bank_generation == res.fleet_generation > 0


class TestFencedSessionRouting:
    def _divergent_pair(self):
        rs, reps = _mk_fleet(2)
        reps[1].server.publish_quantile_maps({}, generation=2)
        return rs, reps

    def test_stream_floor_pins_stream_to_newer_replicas(self):
        rs, reps = self._divergent_pair()
        gens = []
        for i in range(8):
            gens.extend(r.bank_generation
                        for r in rs.dispatch([_req("t0", i)], stream="c1"))
        assert gens == sorted(gens)            # monotone per stream
        assert rs.stream_floor("c1") == 2
        # once pinned, only the gen>=2 replica is eligible
        for i in range(4):
            resp = rs.dispatch([_req("t0", i)], stream="c1")
            assert resp[0].bank_generation == 2

    def test_unsatisfiable_floor_raises_instead_of_rollback(self):
        rs, reps = self._divergent_pair()
        while rs.stream_floor("c1") < 2:       # pin the stream at gen 2
            rs.dispatch([_req("t0")], stream="c1")
        reps[1].ready = False                  # only the gen-0 replica left
        with pytest.raises(RuntimeError, match="generation rollback"):
            rs.dispatch([_req("t0")], stream="c1")
        # unfenced dispatch (no stream identity) still serves
        assert rs.dispatch([_req("t0")])[0].bank_generation == 0

    def test_streams_are_independent(self):
        rs, _ = self._divergent_pair()
        while rs.stream_floor("hot") < 2:
            rs.dispatch([_req("t0")], stream="hot")
        assert rs.stream_floor("cold") == -1   # untouched stream unpinned
        rs.dispatch([_req("t0")], stream="cold")
        assert rs.stream_floor("cold") >= 0


class TestMergedFitAccuracy:
    def test_fleet_fit_matches_single_stream_fit_within_bound(self):
        """End-to-end accuracy: the map published from MERGED sketches must
        agree with the map a single server fits on the CONCATENATED stream,
        within the documented merge rank-error bound."""
        rs, reps = _mk_fleet(3)
        full = _fill(reps, per_rep=4 * GATE)   # deep streams: tight bound
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet()
        assert len(res.refreshed) == 2, [r.reasons for r in res.reports]

        solo_srv = _server()
        for (t, p), data in full.items():
            _inject(solo_srv, t, p, data, seed=97)
        solo = CalibrationController(solo_srv, REF, _policy())
        solo_res = solo.refresh_fleet()
        assert len(solo_res.refreshed) == 2

        cap = 65536
        bound = merge_rank_error_bound(cap, cap) + \
            merge_rank_error_bound(len(next(iter(full.values()))))
        for (t, p), data in full.items():
            fleet_q = np.asarray(
                reps[0].server.predictors[p].pipeline.src_quantiles)
            data_sorted = np.sort(data)
            levels = np.linspace(0.0, 1.0, len(fleet_q))
            ranks = np.searchsorted(data_sorted, fleet_q,
                                    side="right") / len(data)
            interior = slice(2, -2)        # endpoint ranks saturate at 0/1
            assert np.max(np.abs(ranks - levels)[interior]) <= \
                max(bound, 0.02)
            solo_q = np.asarray(
                solo_srv.predictors[p].pipeline.src_quantiles)
            solo_ranks = np.searchsorted(data_sorted, solo_q,
                                         side="right") / len(data)
            assert np.max(np.abs(ranks - solo_ranks)[interior]) <= \
                max(2 * bound, 0.02)

    def test_only_filter_widens_to_predictor_on_fleet_path(self):
        rs, reps = _mk_fleet(2, n_tenants=2)
        _fill(reps)
        fleet = FleetCalibrationController(rs, REF, _policy())
        res = fleet.refresh_fleet(only={("t0", "p0")})
        touched = {(r.tenant, r.predictor) for r in res.reports}
        assert ("t1", "p1") not in touched    # other predictor untouched
        assert {(r.tenant, r.predictor) for r in res.refreshed} \
            == {("t0", "p0")}


@pytest.mark.fleet
@pytest.mark.concurrency
class TestFleetCampaigns:
    """Threaded multi-replica campaigns: live traffic through the fenced LB
    while the fleet plane publishes — no client stream may ever observe its
    ``bank_generation`` go backwards, straggler or not."""

    def test_interleaved_readers_never_observe_generation_rollback(self):
        rs, reps = _mk_fleet(3)
        _fill(reps)
        fleet = FleetCalibrationController(rs, REF, _policy())
        for rep in reps:      # warm XLA traces so readers aren't compile-bound
            rep.server.score_batch([_req("t0"), _req("t1", 1)])
        streams = [f"client-{i}" for i in range(4)]
        observed: dict[str, list[int]] = {s: [] for s in streams}
        errors: list[BaseException] = []
        stop = threading.Event()

        def reader(stream: str) -> None:
            i = 0
            try:
                while not stop.is_set():
                    tenant = f"t{i % 2}"
                    for r in rs.dispatch([_req(tenant, i)], stream=stream):
                        observed[stream].append(r.bank_generation)
                    i += 1
            except BaseException as e:  # noqa: BLE001 — assert on main thread
                errors.append(e)

        def writer() -> None:
            try:
                for round_ in range(4):
                    # refill so every pass has ready streams, then one
                    # fenced fleet broadcast; round 2 runs with a straggler
                    _fill(reps, seed=round_ + 10)
                    if round_ == 2:
                        orig = reps[2].server.publish_quantile_maps
                        reps[2].server.publish_quantile_maps = (
                            lambda *a, **k:
                            (_ for _ in ()).throw(ConnectionError("down")))
                        res = fleet.refresh_fleet()
                        assert res.nacked == ("2",)
                        reps[2].server.publish_quantile_maps = orig
                    else:
                        fleet.refresh_fleet()
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=reader, args=(s,))
                   for s in streams]
        wt = threading.Thread(target=writer)
        for t in threads:
            t.start()
        wt.start()
        wt.join(timeout=300)
        stop.set()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        # one more fenced dispatch per stream AFTER the last broadcast: every
        # stream must land on the final fleet generation without rollback
        for s in streams:
            for r in rs.dispatch([_req("t0")], stream=s):
                observed[s].append(r.bank_generation)
        for s, gens in observed.items():
            assert gens, f"stream {s} never served"
            assert gens == sorted(gens), f"rollback observed on {s}"
            assert gens[-1] == fleet.fleet_generation()
        # the straggler healed on the final round: fleet converged
        assert not rs.fleet_generation().divergent

    def test_rolling_promotion_with_fleet_plane_keeps_streams_monotone(self):
        """Rolling update + fleet calibration mid-stream: surged replicas
        are generation-aligned before taking traffic, the promotion refresh
        is ONE fleet pass, and every client stream's generation stays
        monotone across the whole replica churn."""
        rs, reps = _mk_fleet(3)
        _fill(reps)
        fleet = FleetCalibrationController(rs, REF, _policy())
        base = fleet.refresh_fleet()
        assert len(base.refreshed) == 2 and len(base.acked) == 3

        def make_server_v2():
            srv = _server(version="v2")
            _fill([Replica(-1, srv, "v2")], per_rep=GATE + 60, seed=77)
            return srv

        update = RollingUpdate(rs, make_server_v2, "v2", schema_dim=DIM,
                               warmup_batch_sizes=(1, 4),
                               fleet_calibration=fleet)
        observed: dict[str, list[int]] = {"s0": [], "s1": []}

        def serve_some():
            for i, s in enumerate(observed):
                for r in rs.dispatch([_req(f"t{i}", i)], stream=s):
                    observed[s].append(r.bank_generation)

        serve_some()
        for _ in update.steps():
            serve_some()
        serve_some()

        assert [r.version for r in rs.replicas] == ["v2"] * 3
        assert len(update.refreshes) == 3          # one fleet pass per surge
        for s, gens in observed.items():
            assert gens == sorted(gens), f"rollback observed on {s}"
        audit = rs.fleet_generation()
        assert not audit.divergent
        assert audit.max_generation == fleet.fleet_generation()

    def test_fraudworld_lifecycle_with_straggler_and_promotion(self):
        """The ISSUE-6 e2e scenario on FraudWorld traffic: 3 replicas behind
        the fenced LB, fleet refresh with a straggling replica (old plane
        until it acks), heal + reconverge, rolling promotion driven by the
        fleet plane mid-stream — per-stream generations monotone across
        replicas throughout, and post-refresh per-tenant alert rates on
        target (the merged fit is as good as a single-stream fit)."""
        from repro.experiments.fraud_world import DIM as FDIM
        from repro.experiments.fraud_world import FraudWorld
        from repro.serving.drift import realized_alert_rate
        from repro.training.data import FraudEventStream, TenantProfile

        a, B = 0.02, 120
        world = FraudWorld.build(n_experts=2, betas=(0.18, 0.18), seed=17,
                                 client_shift=0.3)
        tenants = ["bank0", "bank1"]
        feeds = {
            t: FraudEventStream(TenantProfile(
                t, fraud_rate=0.006 + 0.003 * i,
                feature_shift=0.25 + 0.06 * i, seed=500 + i))
            for i, t in enumerate(tenants)
        }
        policy = RefreshPolicy(alert_rate=a, rel_error=0.3)
        qm0 = world.coldstart_quantile_map(("m1", "m2"), n_trials=1)

        def build_server(version):
            rules = tuple(ScoringRule(Condition(tenants=(t,)), f"p-{t}")
                          for t in tenants)
            srv = MuseServer(
                RoutingTable(rules, version=version),
                ServerConfig(refresh_alert_rate=a, refresh_rel_error=0.3))
            for t in tenants:
                srv.deploy(world.predictor_spec(f"p-{t}", ("m1", "m2"), qm0),
                           world.model_factories())
            return srv

        reps = [Replica(i, build_server("v1"), "v1", ready=True)
                for i in range(3)]
        rs = ReplicaSet(reps)
        fleet = FleetCalibrationController(rs, world.ref_quantiles, policy)
        observed: dict[str, list[int]] = {t: [] for t in tenants}

        def serve_phase(n_batches):
            out = []
            for _ in range(n_batches):
                for t in tenants:
                    xs = feeds[t].sample(B)[0]
                    reqs = [ScoringRequest(intent=Intent(tenant=t),
                                           features=xs[i]) for i in range(B)]
                    for r in rs.dispatch(reqs, stream=t):
                        observed[t].append(r.bank_generation)
                        out.append(r)
            return out

        # Phase A: cold-start maps serve while the fleet's streams fill past
        # the MERGED Eq.-5 gate (each replica alone stays below it).
        gate = required_sample_size(a, 0.3)
        serve_phase(gate // B + 2)
        for rep in reps:
            for t in tenants:
                est = rep.server._estimators[(t, f"p-{t}")]
                assert not est.ready(a, 0.3)       # no replica ready alone

        # Fleet refresh with a straggler: replicas 0/1 move, 2 keeps its
        # complete old plane and is routed around by the fenced LB.
        straggler = reps[2]
        orig = straggler.server.publish_quantile_maps
        straggler.server.publish_quantile_maps = (
            lambda *args, **kw: (_ for _ in ()).throw(ConnectionError("down")))
        res1 = fleet.refresh_fleet()
        assert len(res1.refreshed) == 2, [r.reasons for r in res1.reports]
        assert res1.nacked == ("2",) and res1.acked == ("0", "1")
        assert straggler.bank_generation == 0
        pre_heal = straggler.server.score_batch(
            [ScoringRequest(intent=Intent(tenant="bank0"),
                            features=feeds["bank0"].sample(1)[0][0])])
        assert pre_heal[0].bank_generation == 0    # old plane, old stamp

        # Heal: the straggler acks the next pass and reconverges.
        straggler.server.publish_quantile_maps = orig
        res2 = fleet.refresh_fleet()
        assert "2" in res2.acked
        assert not rs.fleet_generation().divergent

        # Phase B: refreshed maps on live traffic — the merged fit holds the
        # paper's alert-rate invariant per tenant.
        post = serve_phase(6)
        for t in tenants:
            scores = np.asarray([r.score for r in post
                                 if r.predictor == f"p-{t}"])
            rate = realized_alert_rate(scores, world.ref_quantiles, a)
            assert rate == pytest.approx(a, abs=0.012), (t, rate)

        # Rolling promotion mid-stream, calibrated through the fleet plane.
        update = RollingUpdate(rs, lambda: build_server("v2"), "v2",
                               schema_dim=FDIM, warmup_batch_sizes=(1, B),
                               fleet_calibration=fleet)
        for _ in update.steps():
            serve_phase(1)
        serve_phase(1)

        assert [r.version for r in rs.replicas] == ["v2"] * 3
        for t, gens in observed.items():
            assert gens == sorted(gens), f"rollback observed on {t}"
        assert not rs.fleet_generation().divergent
