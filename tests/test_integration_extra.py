"""Extra integration + property coverage.

* Pallas flash-attention wired INTO the model forward (attn_impl="pallas")
  agrees with the reference path.
* Routing first-match semantics as a hypothesis property.
* serving_config shape adaptation rules.
* Full MUSE pipeline monotonicity as a property (the ranking invariant that
  makes the paper's recall-preservation claim true by construction).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core.routing import Condition, Intent, RoutingTable, ScoringRule
from repro.core.transforms import score_pipeline
from repro.launch.specs import serving_config
from repro.models.model import Model


class TestPallasInModel:
    def test_forward_with_pallas_attention_matches_reference(self):
        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"))
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 160), 0,
                                  cfg.vocab_size)
        out_ref = model.forward(params, tokens=toks, compute_dtype=jnp.float32,
                                attn_impl="reference")
        out_pal = model.forward(params, tokens=toks, compute_dtype=jnp.float32,
                                attn_impl="pallas")
        np.testing.assert_allclose(
            np.asarray(out_pal.logits), np.asarray(out_ref.logits),
            rtol=5e-3, atol=5e-3,
        )

    def test_encoder_with_pallas_attention(self):
        cfg = get_smoke_config("hubert-xlarge")
        model = Model(cfg)
        params = model.init(jax.random.key(2))
        embeds = 0.05 * jax.random.normal(jax.random.key(3),
                                          (1, 192, cfg.d_model))
        out_ref = model.forward(params, embeds=embeds,
                                compute_dtype=jnp.float32,
                                attn_impl="reference")
        out_pal = model.forward(params, embeds=embeds,
                                compute_dtype=jnp.float32,
                                attn_impl="pallas")
        np.testing.assert_allclose(
            np.asarray(out_pal.logits), np.asarray(out_ref.logits),
            rtol=5e-3, atol=5e-3,
        )


class TestServingConfigAdaptation:
    def test_long_500k_dense_gets_window(self):
        assert serving_config("qwen3-8b", "long_500k").sliding_window == 8192
        assert serving_config("llama4-maverick-400b-a17b",
                              "long_500k").sliding_window == 8192

    def test_ssm_hybrid_stay_native(self):
        assert serving_config("xlstm-1.3b", "long_500k").sliding_window == 0
        assert serving_config("jamba-1.5-large-398b",
                              "long_500k").sliding_window == 0

    def test_other_shapes_unchanged(self):
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert serving_config("qwen3-8b", shape).sliding_window == 0


class TestRoutingProperties:
    @given(
        n_rules=st.integers(1, 6),
        tenant_pool=st.lists(st.sampled_from(["a", "b", "c", "d"]),
                             min_size=1, max_size=4, unique=True),
        query=st.sampled_from(["a", "b", "c", "d", "zzz"]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=60, deadline=None)
    def test_first_match_wins_and_deterministic(self, n_rules, tenant_pool,
                                                query, seed):
        rng = np.random.default_rng(seed)
        rules = []
        for i in range(n_rules):
            tenants = tuple(
                t for t in tenant_pool if rng.random() < 0.5
            )
            rules.append(ScoringRule(Condition(tenants=tenants), f"p{i}"))
        rules.append(ScoringRule(Condition(), "catch-all"))
        table = RoutingTable(tuple(rules))
        res1 = table.resolve(Intent(tenant=query))
        res2 = table.resolve(Intent(tenant=query))
        assert res1.live == res2.live  # deterministic
        # first-match: no earlier rule may match
        idx = next(i for i, r in enumerate(rules)
                   if r.target_predictor == res1.live)
        for r in rules[:idx]:
            assert not r.condition.matches(Intent(tenant=query))


class TestPipelineRankingInvariant:
    @given(
        k=st.integers(1, 6),
        n=st.integers(2, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=40, deadline=None)
    def test_full_eq2_pipeline_is_monotone(self, k, n, seed):
        """If every expert ranks x above y, the business score does too —
        the structural reason MUSE updates never change recall."""
        rng = np.random.default_rng(seed)
        base = np.sort(rng.uniform(0.01, 0.99, n))
        scores = jnp.asarray(np.tile(base[:, None], (1, k)), jnp.float32)
        betas = jnp.asarray(rng.uniform(0.02, 1.0, k), jnp.float32)
        weights = jnp.asarray(rng.uniform(0.1, 2.0, k), jnp.float32)
        qs = jnp.asarray(np.sort(rng.uniform(0, 1, 33)), jnp.float32)
        qr = jnp.asarray(np.sort(rng.uniform(0, 1, 33)), jnp.float32)
        out = np.asarray(score_pipeline(scores, betas, weights, qs, qr))
        assert (np.diff(out) >= -1e-5).all()
