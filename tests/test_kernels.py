"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
with hypothesis sweeps over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _tables(n, seed=0):
    rng = np.random.default_rng(seed)
    src = np.sort(rng.uniform(0, 1, n)).astype(np.float32)
    refq = np.sort(rng.uniform(0, 1, n)).astype(np.float32)
    src[0], src[-1] = 0.0, 1.0
    return jnp.asarray(src), jnp.asarray(refq)


class TestQuantileMapKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n_scores,n_q", [(16, 8), (1000, 64), (4096, 256),
                                              (333, 33)])
    def test_matches_oracle(self, dtype, n_scores, n_q):
        rng = np.random.default_rng(1)
        src, refq = _tables(n_q)
        scores = jnp.asarray(rng.uniform(0, 1, n_scores), dtype)
        got = ops.quantile_map(scores, src, refq, block=256)
        want = ref.quantile_map(scores.astype(jnp.float32), src, refq)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_batched_shape(self):
        src, refq = _tables(32)
        scores = jnp.asarray(np.random.default_rng(2).uniform(0, 1, (4, 7, 9)),
                             jnp.float32)
        got = ops.quantile_map(scores, src, refq)
        assert got.shape == (4, 7, 9)
        want = ref.quantile_map(scores, src, refq)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @given(
        n_scores=st.integers(1, 512),
        n_q=st.sampled_from([4, 16, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_sweep(self, n_scores, n_q, seed):
        rng = np.random.default_rng(seed)
        src, refq = _tables(n_q, seed)
        scores = jnp.asarray(rng.uniform(0, 1, n_scores), jnp.float32)
        got = ops.quantile_map(scores, src, refq, block=128)
        want = ref.quantile_map(scores, src, refq)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


class TestScorePipelineKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n,k,nq", [(64, 3, 32), (1000, 8, 256), (7, 1, 8)])
    def test_matches_oracle(self, dtype, n, k, nq):
        rng = np.random.default_rng(3)
        src, refq = _tables(nq)
        scores = jnp.asarray(rng.uniform(0.01, 0.99, (n, k)), dtype)
        betas = jnp.asarray(rng.uniform(0.02, 1.0, k), jnp.float32)
        weights = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
        got = ops.score_pipeline(scores, betas, weights, src, refq, block=128)
        want = ref.score_pipeline(scores.astype(jnp.float32), betas, weights,
                                  src, refq)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    @given(
        n=st.integers(1, 300),
        k=st.integers(1, 9),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_sweep(self, n, k, seed):
        rng = np.random.default_rng(seed)
        src, refq = _tables(64, seed % 1000)
        scores = jnp.asarray(rng.uniform(0.0, 1.0, (n, k)), jnp.float32)
        betas = jnp.asarray(rng.uniform(0.02, 1.0, k), jnp.float32)
        weights = jnp.asarray(rng.uniform(0.1, 2.0, k), jnp.float32)
        got = ops.score_pipeline(scores, betas, weights, src, refq, block=64)
        want = ref.score_pipeline(scores, betas, weights, src, refq)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_monotone_in_expert_scores(self):
        """Pipeline must preserve ordering (paper's ranking invariant)."""
        src, refq = _tables(64)
        k = 3
        base = jnp.linspace(0.01, 0.99, 50)[:, None] * jnp.ones((1, k))
        betas = jnp.asarray([0.2, 0.1, 0.5])
        weights = jnp.ones((k,))
        out = np.asarray(ops.score_pipeline(base, betas, weights, src, refq))
        assert (np.diff(out) >= -1e-6).all()


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize(
        "b,tq,tk,hq,hkv,d,causal,win",
        [
            (2, 128, 128, 4, 2, 64, True, 0),      # GQA causal
            (1, 256, 256, 8, 8, 32, True, 0),      # MHA causal
            (2, 128, 128, 4, 1, 64, False, 0),     # bidirectional (encoder)
            (1, 256, 256, 4, 2, 64, True, 64),     # sliding window
            (1, 100, 100, 2, 2, 32, True, 0),      # non-divisible lengths
        ],
    )
    def test_matches_oracle(self, dtype, b, tq, tk, hq, hkv, d, causal, win):
        rng = jax.random.key(0)
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (b, tq, hq, d), dtype)
        k = jax.random.normal(kk, (b, tk, hkv, d), dtype)
        v = jax.random.normal(kv, (b, tk, hkv, d), dtype)
        got = ops.flash_attention(q, k, v, causal=causal, sliding_window=win,
                                  block_q=64, block_k=64)
        want = ref.flash_attention(q, k, v, causal=causal, sliding_window=win)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **_tol(dtype))

    def test_matches_model_reference_path(self):
        """Kernel == the chunked-jnp attention used inside the models."""
        from repro.models.attention import _gqa_scores_chunked
        rng = jax.random.key(1)
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (2, 96, 4, 32), jnp.float32)
        k = jax.random.normal(kk, (2, 96, 2, 32), jnp.float32)
        v = jax.random.normal(kv, (2, 96, 2, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
        want = _gqa_scores_chunked(q, k, v, causal=True, q_offset=0,
                                   sliding_window=0, chunk=32)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @given(
        tq=st.integers(8, 160),
        hkv=st.sampled_from([1, 2, 4]),
        qpk=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([16, 32, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_sweep(self, tq, hkv, qpk, d, causal, seed):
        rng = jax.random.key(seed)
        kq, kk, kv = jax.random.split(rng, 3)
        hq = hkv * qpk
        q = jax.random.normal(kq, (1, tq, hq, d), jnp.float32)
        k = jax.random.normal(kk, (1, tq, hkv, d), jnp.float32)
        v = jax.random.normal(kv, (1, tq, hkv, d), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
        want = ref.flash_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


class TestDecodeAttentionKernel:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("b,s,hq,hkv,d,valid", [
        (2, 256, 8, 2, 64, 256),
        (1, 512, 4, 4, 32, 300),   # partially filled cache
        (4, 128, 16, 2, 64, 128),
        (1, 100, 2, 1, 32, 77),    # non-divisible
    ])
    def test_matches_oracle(self, dtype, b, s, hq, hkv, d, valid):
        rng = jax.random.key(2)
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (b, hq, d), dtype)
        kc = jax.random.normal(kk, (b, s, hkv, d), dtype)
        vc = jax.random.normal(kv, (b, s, hkv, d), dtype)
        vlen = jnp.full((b,), valid, jnp.int32)
        got = ops.decode_attention(q, kc, vc, vlen, block_s=64)
        want = ref.decode_attention(q, kc, vc, vlen)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_per_row_valid_lengths(self):
        rng = jax.random.key(3)
        kq, kk, kv = jax.random.split(rng, 3)
        b, s, hq, hkv, d = 3, 128, 4, 2, 32
        q = jax.random.normal(kq, (b, hq, d), jnp.float32)
        kc = jax.random.normal(kk, (b, s, hkv, d), jnp.float32)
        vc = jax.random.normal(kv, (b, s, hkv, d), jnp.float32)
        vlen = jnp.asarray([1, 64, 128], jnp.int32)
        got = ops.decode_attention(q, kc, vc, vlen, block_s=32)
        want = ref.decode_attention(q, kc, vc, vlen)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
