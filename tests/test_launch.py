"""Launch-layer tests: sharding rules, HLO cost parser, roofline analytics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.launch import hlo_costs, roofline, shardings
from repro.launch.specs import serving_config


class TestParamPspecRules:
    def test_column_parallel_qkv(self):
        spec = shardings.param_pspec("stack/0/0/mixer/wq/w/", (24, 4096, 4096), 16)
        assert spec == P(None, None, "model")

    def test_row_parallel_wo(self):
        spec = shardings.param_pspec("stack/0/0/mixer/wo/w/", (24, 4096, 4096), 16)
        assert spec == P(None, "model", None)

    def test_replicate_when_not_divisible(self):
        spec = shardings.param_pspec("stack/0/0/mixer/wq/w/", (24, 100, 100), 16)
        assert spec == P(None, None, None)

    def test_moe_expert_parallel(self):
        spec = shardings.param_pspec("stack/0/1/ffn/gate/", (24, 64, 2048, 1024), 16)
        assert spec == P(None, "model", None, None)

    def test_shared_expert_not_expert_sharded(self):
        spec = shardings.param_pspec(
            "stack/0/1/ffn/shared/gate/w/", (24, 5120, 8192), 16)
        assert spec == P(None, None, "model")

    def test_norms_replicated(self):
        spec = shardings.param_pspec("stack/0/0/mixer_norm/scale/", (24, 4096), 16)
        assert spec == P(None, None)

    def test_fsdp_adds_data_axis(self):
        spec = shardings.param_pspec(
            "stack/0/0/mixer/wq/w/", (126, 16384, 16384), 16,
            fsdp_axes=("data",), fsdp_size=16)
        assert spec == P(None, ("data",), "model")

    def test_embedding_vocab_sharded(self):
        spec = shardings.param_pspec("embed/table/", (92544, 2048), 16)
        assert spec == P("model", None)
        # hubert's 504 vocab is not divisible -> replicated
        spec = shardings.param_pspec("embed/table/", (504, 1280), 16)
        assert spec == P(None, None)

    def test_cache_kv_seq_on_model(self):
        spec = shardings.cache_pspec("cache/0/k/", (24, 128, 32768, 8, 128),
                                     128, _mesh_stub())
        assert spec[2] == "model"


def _mesh_stub():
    import os
    return jax.make_mesh((1, 1), ("data", "model"))


class TestHloCostParser:
    def test_while_trip_counts_scale_collective_bytes(self):
        hlo = """
HloModule test

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %init = (s32[], f32[8]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[128]{0} all-gather(%a), replica_groups={}, dimensions={0}
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""
        res = hlo_costs.collect_collectives(hlo)
        # loop all-reduce: 8 floats * 4B * 24 trips; entry all-gather once
        assert res.bytes_by_kind["all-reduce"] == 8 * 4 * 24
        assert res.bytes_by_kind["all-gather"] == 128 * 4
        assert res.count_by_kind["all-reduce"] == 24
        assert res.static_count == 2

    def test_shape_bytes_tuple(self):
        assert hlo_costs._shape_bytes("(f32[2,3], bf16[4])") == 24 + 8
        assert hlo_costs._shape_bytes("pred[16]") == 16

    def test_async_start_done_not_double_counted(self):
        hlo = """
ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %s = f32[8]{0} all-reduce-start(%a), replica_groups={}
  ROOT %d = f32[8]{0} all-reduce-done(%s)
}
"""
        res = hlo_costs.collect_collectives(hlo)
        assert res.count_by_kind.get("all-reduce", 0) == 1


class TestAnalyticCosts:
    def test_dense_flops_close_to_6nd(self):
        cfg = get_config("qwen3-8b")
        shape = SHAPES["train_4k"]
        f = roofline.analytic_flops(cfg, shape, "train")
        model = roofline.model_flops(cfg, shape, "train")
        # analytic = ~8ND (remat) + attention; ratio in [1.1, 2.2]
        assert 1.1 < f / model < 2.2

    def test_decode_flops_tiny_vs_prefill(self):
        cfg = get_config("qwen3-8b")
        f_dec = roofline.analytic_flops(cfg, SHAPES["decode_32k"], "decode")
        f_pre = roofline.analytic_flops(cfg, SHAPES["prefill_32k"], "prefill")
        assert f_dec < f_pre / 1000

    def test_sliding_window_caps_attention_context(self):
        cfg_full = get_config("qwen3-8b")
        cfg_win = serving_config("qwen3-8b", "long_500k")
        assert cfg_win.sliding_window == 8192
        f_full = roofline.analytic_flops(cfg_full, SHAPES["long_500k"], "decode")
        f_win = roofline.analytic_flops(cfg_win, SHAPES["long_500k"], "decode")
        assert f_win < f_full

    def test_moe_active_not_total(self):
        cfg = get_config("olmoe-1b-7b")
        shape = SHAPES["prefill_32k"]
        f = roofline.analytic_flops(cfg, shape, "prefill")
        total_dense_equiv = 2.0 * cfg.param_count() * shape.global_batch * shape.seq_len
        assert f < 0.5 * total_dense_equiv  # top-8 of 64 experts

    def test_hbm_model_decode_dominated_by_params_and_cache(self):
        cfg = get_config("llama3-405b")
        pb, cb = 810e9, 1e12
        hbm = roofline.analytic_hbm_bytes(cfg, SHAPES["decode_32k"], "decode",
                                          param_bytes=pb, cache_bytes=cb)
        assert 0.9 * (pb + cb) < hbm < 1.3 * (pb + cb)


class TestDryrunResults:
    """Validate the recorded dry-run artifacts (deliverables e/g)."""

    def test_all_cells_present_and_sane(self):
        from repro.launch import report
        for pod in ("pod1", "pod2"):
            rows = report.load(pod)
            assert len(rows) == 38, f"{pod}: {len(rows)} cells (expect 38)"
            for r in rows:
                rf = r["roofline"]
                assert rf["compute_s"] >= 0
                assert rf["collective_bytes_per_chip"] >= 0
                assert r["memory_analysis"]["temp_bytes"] is not None
                assert 0.1 < rf["useful_flops_ratio"] <= 1.2, (
                    r["arch"], r["shape"], rf["useful_flops_ratio"])

    def test_decode_cells_memory_or_collective_bound(self):
        from repro.launch import report
        for r in report.load("pod1"):
            if r["kind"] == "decode":
                assert r["roofline"]["bottleneck"] in ("memory", "collective")

    def test_multi_pod_halves_per_chip_flops(self):
        from repro.launch import report
        p1 = {(r["arch"], r["shape"]): r for r in report.load("pod1")}
        p2 = {(r["arch"], r["shape"]): r for r in report.load("pod2")}
        for key in p1:
            f1 = p1[key]["roofline"]["flops_per_chip"]
            f2 = p2[key]["roofline"]["flops_per_chip"]
            # batch-divisible shapes: per-chip flops halve on 2 pods
            if p1[key]["shape"] != "long_500k":
                assert f2 == pytest.approx(f1 / 2, rel=1e-6), key
