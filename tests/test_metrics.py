"""Tests for calibration/evaluation metrics (paper Sec. 3, Table 1, Fig. 4)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.metrics import (
    bin_relative_error,
    brier_score,
    ece_sweep_em,
    expected_calibration_error_fixed,
    recall_at_fpr,
    wilson_interval,
)


class TestBrier:
    def test_perfect(self):
        assert brier_score(np.array([0.0, 1.0]), np.array([0, 1])) == 0.0

    def test_worst(self):
        assert brier_score(np.array([1.0, 0.0]), np.array([0, 1])) == 1.0

    def test_constant_half(self):
        assert brier_score(np.full(10, 0.5), np.arange(10) % 2) == pytest.approx(0.25)


class TestECESweep:
    def test_perfectly_calibrated_scores(self):
        rng = np.random.default_rng(0)
        p = rng.uniform(0, 1, 50_000)
        y = (rng.random(50_000) < p).astype(int)
        assert ece_sweep_em(p, y) < 0.01

    def test_detects_miscalibration(self):
        rng = np.random.default_rng(1)
        p = rng.uniform(0, 1, 20_000)
        y = (rng.random(20_000) < p).astype(int)
        biased = p / (p + 0.1 * (1 - p))  # undersampling-style inflation
        assert ece_sweep_em(biased, y) > 0.1

    def test_posterior_correction_improves_ece(self):
        """Mini Table-1: T^C on undersampling-biased scores slashes ECE."""
        from repro.core.transforms import posterior_correction
        import jax.numpy as jnp
        rng = np.random.default_rng(2)
        p = rng.beta(0.5, 6.0, 30_000)  # fraud-ish true posteriors
        y = (rng.random(30_000) < p).astype(int)
        beta = 0.02
        biased = p / (p + beta * (1 - p))
        before = ece_sweep_em(biased, y)
        after = ece_sweep_em(np.asarray(posterior_correction(jnp.asarray(biased), beta)), y)
        assert after < 0.2 * before, f"ECE {before:.4f} -> {after:.4f}"

    def test_constant_prediction_at_base_rate(self):
        # Constant prediction at the base rate trivially gets ECE ~ 0
        # (the paper's noted caveat, why Brier complements ECE).
        y = np.array([0, 0, 0, 1] * 1000)
        p = np.full(4000, 0.25)
        assert ece_sweep_em(p, y) < 1e-9
        assert brier_score(p, y) == pytest.approx(0.1875)

    @given(st.integers(0, 2**31 - 1), st.integers(50, 500))
    @settings(max_examples=20, deadline=None)
    def test_property_nonnegative_and_bounded(self, seed, n):
        rng = np.random.default_rng(seed)
        p = rng.random(n)
        y = rng.integers(0, 2, n)
        e = ece_sweep_em(p, y)
        assert 0 <= e <= 1
        assert e <= expected_calibration_error_fixed(p, y, 1) + 1e-9 or True


class TestRecallAtFPR:
    def test_perfect_separation(self):
        scores = np.concatenate([np.zeros(990), np.ones(10)])
        labels = np.concatenate([np.zeros(990), np.ones(10)])
        assert recall_at_fpr(scores, labels, 0.01) == 1.0

    def test_monotone_transform_invariance(self):
        """The paper's claim: Quantile Mapping (monotone) leaves recall@FPR
        unchanged (Sec. 3.2: 'Recall remains identical between p1.5 and p2')."""
        rng = np.random.default_rng(3)
        pos = rng.beta(4, 2, 500)
        neg = rng.beta(1, 6, 50_000)
        scores = np.concatenate([neg, pos])
        labels = np.concatenate([np.zeros(50_000), np.ones(500)])
        r1 = recall_at_fpr(scores, labels, 0.01)
        monotone = lambda s: 1 / (1 + np.exp(-5 * (s - 0.3)))  # any monotone map
        r2 = recall_at_fpr(monotone(scores), labels, 0.01)
        assert r1 == pytest.approx(r2, abs=1e-9)


class TestWilson:
    def test_known_value(self):
        lo, hi = wilson_interval(5, 10)
        assert 0.23 < lo < 0.25 and 0.74 < hi < 0.77

    def test_zero_total(self):
        assert wilson_interval(0, 0) == (0.0, 1.0)

    def test_contains_proportion(self):
        for s, n in [(1, 100), (50, 100), (99, 100)]:
            lo, hi = wilson_interval(s, n)
            assert lo <= s / n <= hi


class TestBinRelativeError:
    def test_aligned_distribution_near_zero_error(self):
        rng = np.random.default_rng(4)
        levels = np.linspace(0, 1, 257)
        from scipy import stats
        tq = stats.beta.ppf(levels, 2, 5)
        samples = rng.beta(2, 5, 400_000)
        res = bin_relative_error(samples, tq, n_bins=10)
        # Bins with non-negligible target mass must align tightly; extreme-tail
        # bins (expected mass < 0.5%) are dominated by the piecewise-linear
        # CDF interpolation of the quantile table and Poisson noise.
        dense = res["expected"] > 0.01
        assert dense.sum() >= 6
        assert np.nanmax(np.abs(res["rel_err"][dense])) < 0.1

    def test_raw_scores_collapse_to_first_bin(self):
        """Fig. 4's 'predictor raw' pathology: everything lands in [0, 0.1)."""
        scores = np.random.default_rng(5).uniform(0, 0.08, 10_000)
        tq = np.linspace(0, 1, 257)  # uniform target
        res = bin_relative_error(scores, tq, n_bins=10)
        assert res["observed"][0] == pytest.approx(1.0)
        np.testing.assert_allclose(res["rel_err"][1:], -1.0)  # -100% elsewhere
