"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config (2 layers, d_model <= 512, <= 4 experts), run one forward
and one train step on CPU, assert output shapes + no NaNs.  Decoder archs
additionally verify the prefill -> decode path is *numerically consistent*
with the full forward — the strongest cache/recurrence correctness check.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_config, get_smoke_config
from repro.models.model import Model

B, T = 2, 24


def _inputs(cfg, batch=B, seq=T, key=0):
    rng = jax.random.key(key)
    if cfg.embeds_input:
        return {"embeds": 0.05 * jax.random.normal(rng, (batch, seq, cfg.d_model),
                                                   jnp.float32)}
    return {"tokens": jax.random.randint(rng, (batch, seq), 0, cfg.vocab_size)}


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(42))
    return arch, cfg, model, params


class TestSmokeForward:
    def test_forward_shapes_and_finite(self, arch_setup):
        arch, cfg, model, params = arch_setup
        out = model.forward(params, **_inputs(cfg))
        assert out.logits.shape == (B, T, cfg.vocab_size)
        assert out.risk_score.shape == (B,)
        logits32 = np.asarray(out.logits, dtype=np.float32)
        assert np.isfinite(logits32).all(), f"{arch}: non-finite logits"
        score = np.asarray(out.risk_score)
        assert ((score >= 0) & (score <= 1)).all()

    def test_one_train_step_reduces_loss_and_finite_grads(self, arch_setup):
        arch, cfg, model, params = arch_setup
        inputs = _inputs(cfg)
        labels = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)

        def loss_fn(p):
            out = model.forward(p, **inputs, compute_dtype=jnp.float32)
            logp = jax.nn.log_softmax(out.logits.astype(jnp.float32), axis=-1)
            ce = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], axis=-1))
            return ce + 0.01 * out.moe_aux

        loss0, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss0)), f"{arch}: loss not finite"
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
        lr = 1e-2 / max(float(gnorm), 1.0)
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                  params, grads)
        loss1 = loss_fn(new_params)
        assert float(loss1) < float(loss0), (
            f"{arch}: SGD step did not reduce loss ({loss0} -> {loss1})"
        )

    def test_moe_aux_present_only_for_moe_archs(self, arch_setup):
        arch, cfg, model, params = arch_setup
        out = model.forward(params, **_inputs(cfg))
        has_moe = any(s.ffn == "moe" for s in cfg.layer_pattern)
        if has_moe:
            assert float(out.moe_aux) > 0
        else:
            assert float(out.moe_aux) == 0


class TestPrefillDecodeConsistency:
    def test_decode_matches_forward(self, arch_setup):
        """logits(decode @ pos T | prefill of 0..T-1) == logits(forward)[T]."""
        arch, cfg, model, params = arch_setup
        if not cfg.has_decode:
            pytest.skip("encoder-only: no decode")
        full_inputs = _inputs(cfg, seq=T + 1)
        out_full = model.forward(params, **full_inputs, compute_dtype=jnp.float32)

        prefix = {k: v[:, :T] for k, v in full_inputs.items()}
        last = {k: v[:, T : T + 1] for k, v in full_inputs.items()}
        _, cache = model.prefill(params, **prefix, cache_capacity=T + 1,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
        dec = model.decode_step(params, cache, **last, pos=T,
                                compute_dtype=jnp.float32)
        ref = np.asarray(out_full.logits[:, -1], np.float32)
        got = np.asarray(dec.logits, np.float32)
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{arch}: decode != forward")

    def test_multi_step_decode_matches_forward(self, arch_setup):
        """Three consecutive decode steps track the full forward."""
        arch, cfg, model, params = arch_setup
        if not cfg.has_decode:
            pytest.skip("encoder-only: no decode")
        steps = 3
        total = T + steps
        full_inputs = _inputs(cfg, seq=total, key=7)
        out_full = model.forward(params, **full_inputs, compute_dtype=jnp.float32)

        prefix = {k: v[:, :T] for k, v in full_inputs.items()}
        _, cache = model.prefill(params, **prefix, cache_capacity=total,
                                 compute_dtype=jnp.float32,
                                 cache_dtype=jnp.float32)
        for s in range(steps):
            tok = {k: v[:, T + s : T + s + 1] for k, v in full_inputs.items()}
            dec = model.decode_step(params, cache, **tok, pos=T + s,
                                    compute_dtype=jnp.float32)
            cache = dec.cache
            ref = np.asarray(out_full.logits[:, T + s], np.float32)
            got = np.asarray(dec.logits, np.float32)
            np.testing.assert_allclose(
                got, ref, rtol=3e-3, atol=3e-3,
                err_msg=f"{arch}: decode step {s} diverged",
            )


class TestSlidingWindowVariant:
    def test_sliding_window_decode_matches_windowed_forward(self):
        """The long_500k dense-arch variant: ring-buffer decode == windowed
        full attention."""
        import dataclasses
        cfg = dataclasses.replace(get_smoke_config("qwen3-8b"), sliding_window=8)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        total = 21
        toks = jax.random.randint(jax.random.key(2), (B, total), 0, cfg.vocab_size)
        out_full = model.forward(params, tokens=toks, compute_dtype=jnp.float32)
        _, cache = model.prefill(params, tokens=toks[:, :-1], cache_capacity=total,
                                 compute_dtype=jnp.float32, cache_dtype=jnp.float32)
        # ring buffer capacity is the window, not the sequence
        assert cache[0].k.shape[2] == 8
        dec = model.decode_step(params, cache, tokens=toks[:, -1:], pos=total - 1,
                                compute_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(dec.logits), np.asarray(out_full.logits[:, -1]),
            rtol=2e-3, atol=2e-3,
        )


class TestFullConfigs:
    """The FULL configs are exercised via the dry-run only; here we just
    validate their static structure + analytic parameter counts."""

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_full_config_constructs(self, arch):
        cfg = get_config(arch)
        assert cfg.n_layers % len(cfg.layer_pattern) == 0
        assert cfg.n_heads % cfg.n_kv_heads == 0

    def test_param_counts_match_model_scale(self):
        # name encodes the expected scale: llama3-405b ~ 405e9 params, etc.
        expect = {
            "internlm2-1.8b": (1.5e9, 2.5e9),
            "llama3-405b": (3.6e11, 4.5e11),
            "olmoe-1b-7b": (6.0e9, 8.0e9),
            "qwen2-vl-7b": (6.0e9, 9.0e9),
            "hubert-xlarge": (0.7e9, 1.3e9),
            "deepseek-coder-33b": (2.9e10, 3.7e10),
            "jamba-1.5-large-398b": (3.0e11, 4.4e11),
            "qwen3-8b": (6.5e9, 9.5e9),
            # assigned dims (48L, d=2048, pf=2) give ~2B even with head-wise
            # qkv blocks; the "1.3b" name undershoots its own table.
            "xlstm-1.3b": (1.0e9, 2.5e9),
            "llama4-maverick-400b-a17b": (3.5e11, 4.5e11),
        }
        for arch, (lo, hi) in expect.items():
            n = get_config(arch).param_count()
            assert lo <= n <= hi, f"{arch}: param_count {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"

    def test_active_params_moe(self):
        cfg = get_config("olmoe-1b-7b")
        active = cfg.active_param_count()
        total = cfg.param_count()
        assert active < 0.35 * total  # top-8 of 64 experts
        cfg4 = get_config("llama4-maverick-400b-a17b")
        assert cfg4.active_param_count() < 0.1 * cfg4.param_count()

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_applicable_shapes(self, arch):
        shapes = applicable_shapes(arch)
        assert "train_4k" in shapes and "prefill_32k" in shapes
        if arch == "hubert-xlarge":
            assert "decode_32k" not in shapes and "long_500k" not in shapes
        else:
            assert "decode_32k" in shapes and "long_500k" in shapes
